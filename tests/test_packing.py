"""Tests for First-Fit sequence packing (the paper's technique in the data
pipeline) and the streaming pipeline built on it."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import (
    SequencePacker,
    StreamingPipeline,
    bimodal_documents,
    pack_documents,
    packing_efficiency,
    synthetic_documents,
)

doc_lists = st.lists(
    st.integers(min_value=1, max_value=300), min_size=1, max_size=100
)


def make_docs(lengths, vocab=100, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in lengths]


# ---------------------------------------------------------------------------
# Property tests: packing invariants
# ---------------------------------------------------------------------------


@given(doc_lists, st.sampled_from(["first-fit", "next-fit", "best-fit"]))
@settings(max_examples=100, deadline=None)
def test_packing_preserves_all_tokens(lengths, algorithm):
    S = 128
    docs = make_docs(lengths)
    batches = list(pack_documents(docs, seq_len=S, batch_size=4,
                                  algorithm=algorithm))
    total_in = sum(len(d) for d in docs)
    total_out = sum(b.real_tokens for b in batches)
    assert total_out == total_in


@given(doc_lists)
@settings(max_examples=100, deadline=None)
def test_packing_segments_are_contiguous_and_positions_local(lengths):
    S = 128
    docs = make_docs(lengths)
    for b in pack_documents(docs, seq_len=S, batch_size=4):
        B = b.tokens.shape[0]
        for row in range(B):
            seg = b.segment_ids[row]
            pos = b.positions[row]
            # padding only at the end of each row's used prefix
            used = seg > 0
            if used.any():
                last = np.max(np.nonzero(used))
                assert used[: last + 1].all()
            # segment ids are non-decreasing (contiguous segments)
            nz = seg[used]
            assert (np.diff(nz) >= 0).all()
            # positions restart at 0 within each segment and increment by 1
            for s_id in np.unique(nz):
                p = pos[seg == s_id]
                assert (p == np.arange(len(p))).all()


@given(doc_lists)
@settings(max_examples=50, deadline=None)
def test_labels_are_next_token_within_segment(lengths):
    S = 128
    docs = make_docs(lengths)
    for b in pack_documents(docs, seq_len=S, batch_size=2):
        tok, lab, seg = b.tokens, b.labels, b.segment_ids
        B = tok.shape[0]
        for row in range(B):
            for i in range(S - 1):
                if seg[row, i] > 0 and seg[row, i] == seg[row, i + 1]:
                    assert lab[row, i] == tok[row, i + 1]
                elif seg[row, i] > 0:
                    assert lab[row, i] == -1  # segment boundary: masked


def test_oversized_document_is_split():
    packer = SequencePacker(seq_len=64, batch_size=1)
    doc = np.arange(200, dtype=np.int32)
    packer.feed(doc)
    packer.flush()
    rows = []
    while True:
        b = packer.pop_batch(pad_final=True)
        if b is None:
            break
        rows.append(b)
    total = sum(b.real_tokens for b in rows)
    assert total == 200


def test_first_fit_beats_next_fit_on_bimodal():
    """The quality claim: First-Fit packs tighter than Next-Fit."""
    docs = list(bimodal_documents(100, seed=0, limit=400))
    eff = {}
    for alg in ("first-fit", "next-fit"):
        batches = list(pack_documents(docs, seq_len=2048, batch_size=8,
                                      algorithm=alg))
        eff[alg] = packing_efficiency(batches)
    assert eff["first-fit"] >= eff["next-fit"]
    assert eff["first-fit"] > 0.9  # tight packing on this distribution


def test_packing_beats_padding_baseline():
    """vs the no-packing baseline (one document per row)."""
    docs = list(synthetic_documents(100, mean_len=700, seed=0, limit=300))
    S = 4096
    batches = list(pack_documents(docs, seq_len=S, batch_size=8))
    packed_eff = packing_efficiency(batches)
    pad_eff = sum(min(len(d), S) for d in docs) / (len(docs) * S)
    assert packed_eff > 2 * pad_eff


def test_max_open_rows_bounds_state():
    packer = SequencePacker(seq_len=1 << 20, batch_size=4, max_open_rows=8)
    for d in make_docs([5] * 100):
        packer.feed(d)
    assert packer.open_rows <= 8


# ---------------------------------------------------------------------------
# Streaming pipeline (IRM-instrumented)
# ---------------------------------------------------------------------------


def test_streaming_pipeline_covers_all_documents():
    docs = list(synthetic_documents(50, mean_len=200, seed=1, limit=120))
    pipe = StreamingPipeline(iter(docs), seq_len=512, batch_size=4, prefetch=0)
    total = sum(b.real_tokens for b in pipe)
    assert total == sum(len(d) for d in docs)


def test_streaming_pipeline_prefetch_equivalent():
    docs = list(synthetic_documents(50, mean_len=200, seed=2, limit=80))
    sync = StreamingPipeline(iter(docs), seq_len=512, batch_size=4, prefetch=0)
    pre = StreamingPipeline(iter(docs), seq_len=512, batch_size=4, prefetch=4)
    sync_batches = [b.tokens for b in sync]
    pre_batches = [b.tokens for b in pre]
    assert len(sync_batches) == len(pre_batches)
    for a, b in zip(sync_batches, pre_batches, strict=True):
        np.testing.assert_array_equal(a, b)


def test_streaming_pipeline_profiles_doc_sizes():
    docs = list(synthetic_documents(50, mean_len=300, seed=3, limit=200))
    pipe = StreamingPipeline(iter(docs), seq_len=1024, batch_size=4, prefetch=0)
    list(pipe)
    stats = pipe.stats()
    mean_fill = np.mean([min(1.0, len(d) / 1024) for d in docs])
    # profiled moving average tracks the true mean document fill
    assert stats["mean_doc_fill"] == pytest.approx(mean_fill, rel=0.5)
    assert stats["docs_in"] == len(docs)
