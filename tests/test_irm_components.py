"""Unit tests for the IRM components: profiler, load predictor, queues,
allocator (paper Section V)."""

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.allocator import AllocatorConfig, BinPackingManager, idle_buffer
from repro.core.load_predictor import LoadPredictor, LoadPredictorConfig
from repro.core.profiler import MasterProfiler, ProfilerConfig, WorkerProbe
from repro.core.queues import AllocationQueue, ContainerQueue, HostRequest


# ---------------------------------------------------------------------------
# Worker profiler (V-B.3)
# ---------------------------------------------------------------------------


def test_profiler_default_guess():
    p = MasterProfiler(ProfilerConfig(default_size=0.42))
    assert p.estimate("never-seen") == 0.42
    assert p.num_observations("never-seen") == 0


def test_profiler_moving_average_window():
    p = MasterProfiler(ProfilerConfig(window=4))
    for v in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6):
        p.observe("img", v)
    # window of 4 -> mean of last four values
    assert p.estimate("img") == pytest.approx((0.3 + 0.4 + 0.5 + 0.6) / 4)
    assert p.num_observations("img") == 6


def test_profiler_clamps_to_unit_interval():
    p = MasterProfiler(ProfilerConfig(min_size=0.01, max_size=1.0))
    p.observe("big", 3.7)
    assert p.estimate("big") == 1.0
    p.observe("tiny", 0.0)
    assert p.estimate("tiny") == 0.01


def test_profiler_report_ingest_and_snapshot():
    p = MasterProfiler()
    p.observe_report({"a": 0.5, "b": 0.25})
    assert p.snapshot() == {"a": 0.5, "b": 0.25}
    assert set(p.known_images()) == {"a", "b"}


def test_worker_probe_per_image_means():
    probe = WorkerProbe()
    probe.sample([("a", 0.2), ("a", 0.4), ("b", 1.0)])
    rep = probe.report()
    assert rep["a"] == pytest.approx(0.3)
    assert rep["b"] == pytest.approx(1.0)
    # flushes
    assert probe.report() == {}


@given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_profiler_estimate_bounded_by_window_extremes(vals):
    p = MasterProfiler(ProfilerConfig(window=16))
    for v in vals:
        p.observe("x", v)
    tail = vals[-16:]
    est = p.estimate("x")
    assert min(tail) - 1e-9 <= est or est == p.config.min_size
    assert est <= max(max(tail), p.config.min_size) + 1e-9


# ---------------------------------------------------------------------------
# Load predictor (V-B.4)
# ---------------------------------------------------------------------------


CFG = LoadPredictorConfig(
    queue_low=8, queue_high=64, roc_low=1.0, roc_high=8.0,
    small_increase=2, large_increase=8, read_interval=1.0, cooldown=5.0,
)


def test_case1_queue_very_long():
    lp = LoadPredictor(CFG)
    lp.update(0.0, 0.0)  # establish baseline
    d = lp.update(1.0, 100.0)
    assert d.case == 1 and d.num_pes == 8


def test_case1_roc_very_high():
    lp = LoadPredictor(CFG)
    lp.update(0.0, 0.0)
    d = lp.update(1.0, 10.0)  # roc = 10 >= 8
    assert d.case == 1 and d.num_pes == 8


def test_case2_moderate_roc_and_queue():
    lp = LoadPredictor(CFG)
    lp.update(0.0, 6.0)  # below queue_low: no action, no cooldown
    d = lp.update(4.0, 14.0)  # roc = 2 in [1, 8), queue 14 in [8, 64)
    assert d.case == 2 and d.num_pes == 8


def test_case3_roc_only():
    lp = LoadPredictor(CFG)
    lp.update(0.0, 0.0)
    d = lp.update(2.0, 4.0)  # roc = 2, queue 4 < 8
    assert d.case == 3 and d.num_pes == 2


def test_case4_queue_only():
    lp = LoadPredictor(CFG)
    d = lp.update(0.0, 10.0)  # first read: roc = 0, queue 10 >= 8
    assert d.case == 4 and d.num_pes == 2


def test_no_action_below_thresholds():
    lp = LoadPredictor(CFG)
    lp.update(0.0, 2.0)
    d = lp.update(1.0, 2.0)
    assert d.case == 0 and d.num_pes == 0


def test_cooldown_after_scaleup():
    lp = LoadPredictor(CFG)
    lp.update(0.0, 0.0)
    d = lp.update(1.0, 100.0)
    assert d.num_pes > 0
    # within the 5 s cooldown: no reads, no action
    assert lp.update(3.0, 500.0).num_pes == 0
    # after cooldown: reads again
    d2 = lp.update(6.5, 500.0)
    assert d2.num_pes > 0


def test_read_interval_paced():
    lp = LoadPredictor(CFG)
    lp.update(0.0, 100.0)
    # 0.5 s later: within read_interval -> noop even with a huge queue
    assert lp.update(0.5, 200.0).num_pes == 0


# ---------------------------------------------------------------------------
# Container / allocation queues (V-B.1, V-B.2)
# ---------------------------------------------------------------------------


def test_container_queue_fifo_and_ttl():
    q = ContainerQueue()
    r1, r2 = HostRequest("a", ttl=2), HostRequest("b", ttl=2)
    assert q.push(r1) and q.push(r2)
    assert [r.image for r in q.drain()] == ["a", "b"]

    # TTL requeue decrements and strips placement
    r1.target_worker = 3
    assert q.requeue(r1)
    assert r1.ttl == 1 and r1.target_worker is None
    assert q.requeue(r1) is False  # ttl 0 -> dropped
    assert q.dropped == [r1]


def test_container_queue_refresh_estimates():
    q = ContainerQueue()
    q.push(HostRequest("img", size_estimate=0.5))
    prof = MasterProfiler()
    prof.observe("img", 0.9)
    q.refresh_estimates(prof)
    assert next(iter(q)).size_estimate == pytest.approx(0.9)


def test_push_front_preserves_order():
    q = ContainerQueue()
    a, b = HostRequest("a"), HostRequest("b")
    q.push(HostRequest("c"))
    q.push_front([a, b])
    assert [r.image for r in q.drain()] == ["a", "b", "c"]


def test_allocation_queue_requires_target():
    aq = AllocationQueue()
    with pytest.raises(ValueError):
        aq.push(HostRequest("a"))


def test_allocation_queue_consume_failure_path():
    aq = AllocationQueue()
    cq = ContainerQueue()
    ok = HostRequest("ok", target_worker=0, ttl=3)
    bad = HostRequest("bad", target_worker=9, ttl=3)
    aq.push(ok)
    aq.push(bad)
    started = aq.consume(
        try_start=lambda r: r.target_worker == 0, on_fail=cq.requeue
    )
    assert started == 1
    assert len(aq) == 0
    assert len(cq) == 1
    requeued = cq.drain()[0]
    assert requeued.image == "bad" and requeued.ttl == 2
    assert requeued.target_worker is None  # stripped before requeue


# ---------------------------------------------------------------------------
# Bin-packing manager / allocator (V-B.2)
# ---------------------------------------------------------------------------


def test_idle_buffer_log_proportional():
    assert idle_buffer(0) == 1
    assert idle_buffer(1) == 1
    assert idle_buffer(3) == 2
    assert idle_buffer(7) == 3
    assert idle_buffer(100) == math.ceil(math.log2(101))


def test_packing_run_prefilled_workers():
    mgr = BinPackingManager(AllocatorConfig(pack_interval=0.0, keep_idle_buffer=False))
    reqs = [HostRequest("a", size_estimate=0.5) for _ in range(3)]
    run = mgr.run(0.0, reqs, worker_loads=[0.8, 0.0])
    # worker0 has 0.2 free -> first 0.5 lands on worker1, second on worker1,
    # third opens worker2
    assert [r.target_worker for r in run.placements] == [1, 1, 2]
    assert run.num_bins == 3
    assert run.target_workers == 3


def test_packing_run_idle_buffer_added():
    mgr = BinPackingManager(AllocatorConfig(keep_idle_buffer=True))
    run = mgr.run(0.0, [HostRequest("a", size_estimate=0.9)], worker_loads=[])
    assert run.num_bins == 1
    assert run.target_workers == 1 + idle_buffer(1)


def test_packing_interval_gate():
    mgr = BinPackingManager(AllocatorConfig(pack_interval=2.0))
    assert mgr.should_run(0.0)
    mgr.run(0.0, [], [])
    assert not mgr.should_run(1.0)
    assert mgr.should_run(2.0)


def test_packing_rejects_non_anyfit():
    mgr = BinPackingManager(AllocatorConfig(algorithm="harmonic"))
    # Harmonic supports no pre-filled open bins -> must raise
    with pytest.raises((ValueError, TypeError)):
        mgr.run(0.0, [HostRequest("a")], worker_loads=[0.5])


def test_headroom_caps_item_size():
    mgr = BinPackingManager(
        AllocatorConfig(keep_idle_buffer=False, headroom=0.1)
    )
    run = mgr.run(0.0, [HostRequest("a", size_estimate=1.0)], worker_loads=[])
    # item clamped to 0.9 -> fits a bin with headroom
    assert run.placements[0].target_worker == 0


@given(
    st.lists(st.floats(min_value=0.05, max_value=1.0), min_size=1, max_size=50),
    st.lists(st.floats(min_value=0.0, max_value=1.0), max_size=5),
)
@settings(max_examples=100, deadline=None)
def test_packing_run_never_overflows(sizes, loads):
    mgr = BinPackingManager(AllocatorConfig(keep_idle_buffer=False))
    reqs = [HostRequest("x", size_estimate=s) for s in sizes]
    run = mgr.run(0.0, reqs, worker_loads=loads)
    for load in run.scheduled_load:
        assert load <= 1.0 + 1e-9
    assert all(r.target_worker is not None for r in run.placements)
    assert run.ideal_bins <= run.num_bins or run.num_bins == len(
        [l for l in loads if l > 0]
    )
