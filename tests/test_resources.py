"""Unit tests for the ``Resources`` value type and its control-plane hooks:
per-dimension profiler estimates and the load predictor's bottleneck-
dimension pressure scaling."""

import pytest

from repro.core import Resources, as_resources
from repro.core.load_predictor import LoadPredictor, LoadPredictorConfig
from repro.core.profiler import MasterProfiler, ProfilerConfig, clamp_estimate


# ---------------------------------------------------------------------------
# Resources value type
# ---------------------------------------------------------------------------


def test_construction_and_views():
    r = Resources.of(cpu=0.3, mem=0.5)
    assert r.dims == ("cpu", "mem")
    assert r.get("cpu") == 0.3
    assert r.get("mem") == 0.5
    assert r.get("accel") == 0.0  # missing -> default
    assert r.as_tuple() == (0.3, 0.5)
    assert r.as_dict() == {"cpu": 0.3, "mem": 0.5}
    assert not r.is_scalar
    assert Resources.cpu(0.7).is_scalar
    assert Resources.cpu(0.7).to_float() == 0.7
    with pytest.raises(ValueError):
        r.to_float()  # multi-dim cannot collapse


def test_validation():
    with pytest.raises(ValueError):
        Resources((), [])
    with pytest.raises(ValueError):
        Resources(("cpu", "mem"), [0.5])  # shape mismatch


def test_align_reorders_and_zero_fills():
    r = Resources.of(cpu=0.2, accel=0.8)
    a = r.align(("cpu", "mem", "accel"))
    assert a.dims == ("cpu", "mem", "accel")
    assert a.as_tuple() == (0.2, 0.0, 0.8)
    # aligning to own dims is the identity
    assert r.align(r.dims) is r


def test_scalar_coercion():
    v = as_resources(0.4, ("cpu", "mem"))
    assert v.as_tuple() == (0.4, 0.0)  # float == CPU-only demand
    w = as_resources(Resources.of(mem=0.3), ("cpu", "mem"))
    assert w.as_tuple() == (0.0, 0.3)


def test_arithmetic_value_semantics():
    a = Resources.of(cpu=0.2, mem=0.4)
    b = Resources.of(cpu=0.1, mem=0.1)
    s = a + b
    assert s.as_tuple() == pytest.approx((0.3, 0.5))
    assert a.as_tuple() == (0.2, 0.4)  # untouched
    assert (a - b).as_tuple() == pytest.approx((0.1, 0.3))
    assert (a * 2.0).as_tuple() == pytest.approx((0.4, 0.8))
    assert (a / 2.0).as_tuple() == pytest.approx((0.1, 0.2))
    # sum() support (starts at int 0)
    assert sum([a, b]).as_tuple() == pytest.approx((0.3, 0.5))


def test_dominant_dimension():
    r = Resources.of(cpu=0.2, mem=0.6, accel=0.1)
    assert r.dominant() == ("mem", 0.6)
    # utilization against a non-uniform capacity flips the dominant dim
    cap = Resources.of(cpu=0.25, mem=1.0, accel=1.0)
    dim, frac = r.dominant(cap)
    assert dim == "cpu" and frac == pytest.approx(0.8)


def test_clamp_floors_cpu_only():
    r = Resources.of(cpu=0.0, mem=-0.2, accel=1.7)
    c = r.clamp(1e-3, 1.0)
    assert c.as_tuple() == (1e-3, 0.0, 1.0)


def test_equality():
    assert Resources.of(cpu=0.5) == Resources.cpu(0.5)
    assert Resources.of(cpu=0.5) != Resources.of(mem=0.5)
    assert Resources.of(cpu=0.5) != 0.5


# ---------------------------------------------------------------------------
# Profiler: per-dimension observed usage and estimates
# ---------------------------------------------------------------------------


def test_profiler_vector_moving_average():
    p = MasterProfiler(ProfilerConfig(window=4))
    p.set_resource_dims(("cpu", "mem"))
    for c, m in ((0.1, 0.4), (0.2, 0.6), (0.3, 0.2)):
        p.observe("img", Resources(("cpu", "mem"), (c, m)))
    est = p.estimate("img")
    assert isinstance(est, Resources)
    assert est.get("cpu") == pytest.approx(0.2)
    assert est.get("mem") == pytest.approx(0.4)


def test_profiler_vector_default_for_unseen_image():
    p = MasterProfiler(ProfilerConfig(default_size=0.42))
    p.set_resource_dims(("cpu", "mem", "accel"))
    est = p.estimate("never-seen")
    assert isinstance(est, Resources)
    assert est.as_tuple() == (0.42, 0.42, 0.42)


def test_profiler_scalar_path_unchanged_by_vector_support():
    """1-D Resources observations produce the exact scalar estimates."""
    ps = MasterProfiler(ProfilerConfig(window=8))
    pv = MasterProfiler(ProfilerConfig(window=8))
    pv.set_resource_dims(("cpu",))
    vals = [0.11, 0.52, 0.97, 0.33, 0.08]
    for v in vals:
        ps.observe("img", v)
        pv.observe("img", Resources.cpu(v))
    assert pv.estimate("img").to_float() == ps.estimate("img")


def test_profiler_scalar_samples_survive_switch_to_vector_dims():
    """Regression: a persistent profiler carried from a scalar run onto a
    multi-resource cluster must convert its stale float samples, not crash
    (or return floats) in vector mode."""
    p = MasterProfiler(ProfilerConfig(window=4))
    p.observe("img", 0.2)
    p.observe("img", 0.4)
    p.set_resource_dims(("cpu", "mem"))
    est = p.estimate("img")
    assert isinstance(est, Resources)
    assert est.get("cpu") == pytest.approx(0.3)  # learned CPU profile kept
    assert est.get("mem") == 0.0                 # no memory evidence yet
    # new vector observations mix into the same window without TypeError
    p.observe("img", Resources.of(cpu=0.2, mem=0.6))
    est = p.estimate("img")
    assert est.get("mem") == pytest.approx(0.2)  # (0 + 0 + 0.6) / 3


def test_clamp_estimate_vector_vs_scalar():
    cfg = ProfilerConfig(min_size=0.01, max_size=1.0)
    assert clamp_estimate(3.0, cfg) == 1.0
    v = clamp_estimate(Resources.of(cpu=3.0, mem=0.0), cfg)
    assert v.as_tuple() == (1.0, 0.0)  # mem may be zero; cpu clamps


# ---------------------------------------------------------------------------
# Load predictor: bottleneck-dimension pressure
# ---------------------------------------------------------------------------

CFG = LoadPredictorConfig(
    queue_low=8, queue_high=64, roc_low=1.0, roc_high=8.0,
    small_increase=2, large_increase=8, read_interval=1.0, cooldown=5.0,
)


def test_effective_pressure_scalar_is_identity():
    q, dim = LoadPredictor.effective_pressure(13.0, None)
    assert q == 13.0 and dim == "cpu"
    q, dim = LoadPredictor.effective_pressure(13.0, Resources.cpu(5.0))
    assert q == 13.0  # 1-D demand: no scaling


def test_effective_pressure_scales_on_bottleneck():
    # 10 messages, each ~0.1 CPU but ~0.4 mem: mem pressure is 4x
    demand = Resources.of(cpu=1.0, mem=4.0)
    q, dim = LoadPredictor.effective_pressure(10.0, demand)
    assert dim == "mem"
    assert q == pytest.approx(40.0)
    # CPU-dominant demand never scales up
    q, dim = LoadPredictor.effective_pressure(10.0, Resources.of(cpu=4.0, mem=1.0))
    assert q == 10.0 and dim == "cpu"


def test_update_with_demand_triggers_earlier():
    """A mem-bound backlog of 6 messages (< queue_low) still scales up."""
    lp = LoadPredictor(CFG)
    demand = Resources.of(cpu=0.6, mem=2.4)  # mem = 4x cpu
    d = lp.update(0.0, 6.0, demand=demand)
    # effective pressure 24 >= queue_low -> case 4 (first read, roc 0)
    assert d.case == 4 and d.num_pes == 2
    assert d.bottleneck == "mem"
    assert d.pressure == pytest.approx(24.0)
    assert d.queue_len == 6.0  # raw length still reported


def test_update_evaluates_demand_lazily():
    """The backlog demand scan must not run on gated (cooldown /
    read-interval) ticks — the IRM passes it as a callable."""
    lp = LoadPredictor(CFG)
    calls = []

    def demand():
        calls.append(1)
        return Resources.of(cpu=0.6, mem=2.4)

    d = lp.update(0.0, 100.0, demand=demand)  # first read: scales up
    assert d.num_pes > 0 and len(calls) == 1
    lp.update(1.0, 100.0, demand=demand)      # inside cooldown: gated
    lp.update(2.0, 100.0, demand=demand)
    assert len(calls) == 1                    # never evaluated while gated
    lp.update(6.0, 100.0, demand=demand)      # cooldown over
    assert len(calls) == 2


def test_update_without_demand_is_bitwise_identical():
    a, b = LoadPredictor(CFG), LoadPredictor(CFG)
    for t, q in ((0.0, 0.0), (1.0, 5.0), (2.0, 9.0), (3.5, 40.0), (9.0, 2.0)):
        da = a.update(t, q)
        db = b.update(t, q, demand=None)
        assert (da.num_pes, da.case, da.roc, da.queue_len) == (
            db.num_pes, db.case, db.roc, db.queue_len
        )
