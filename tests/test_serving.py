"""Tests for the paged KV cache (First-Fit page allocator) and the
IRM-scheduled serving engine."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serving import (
    EngineConfig,
    PageAllocator,
    PagedCacheLayout,
    ReplicaConfig,
    Request,
    ServingEngine,
)


def layout(num_pages=64, page_size=16, max_pages=32):
    return PagedCacheLayout(
        num_pages=num_pages, page_size=page_size, n_kv_heads=2, head_dim=8,
        max_pages_per_seq=max_pages,
    )


# ---------------------------------------------------------------------------
# Page allocator
# ---------------------------------------------------------------------------


def test_allocator_first_fit_lowest_index():
    a = PageAllocator(layout())
    p1 = a.allocate(1, 32)  # 2 pages
    assert p1 == [0, 1]
    p2 = a.allocate(2, 16)
    assert p2 == [2]
    a.free(1)
    # freed low pages are reused first (First-Fit keeps the pool dense)
    p3 = a.allocate(3, 16)
    assert p3 == [0]


def test_allocator_extend_and_page_table():
    a = PageAllocator(layout(page_size=4))
    a.allocate(7, 4)          # 1 page
    fresh = a.extend(7, 1)    # crosses a page boundary
    assert len(fresh) == 1
    assert a.seq_len(7) == 5
    t = a.page_table([7])
    assert t.shape == (1, 32)
    assert (t[0, :2] >= 0).all() and (t[0, 2:] == -1).all()


def test_allocator_exhaustion_returns_none():
    a = PageAllocator(layout(num_pages=2, page_size=4, max_pages=8))
    assert a.allocate(1, 8) is not None  # both pages
    assert a.allocate(2, 1) is None      # pool exhausted
    assert a.extend(1, 4) is None
    a.free(1)
    assert a.allocate(2, 1) is not None


def test_allocator_max_pages_per_seq():
    a = PageAllocator(layout(num_pages=64, page_size=4, max_pages=2))
    assert a.allocate(1, 12) is None  # needs 3 pages > max 2


def test_allocator_double_allocate_raises():
    a = PageAllocator(layout())
    a.allocate(1, 4)
    with pytest.raises(KeyError):
        a.allocate(1, 4)
    with pytest.raises(KeyError):
        a.extend(99)


@given(
    st.lists(
        st.tuples(st.integers(1, 100), st.booleans()),
        min_size=1, max_size=60,
    )
)
@settings(max_examples=100, deadline=None)
def test_allocator_conservation(ops):
    """Pages are conserved: used + free == num_pages, no double ownership."""
    a = PageAllocator(layout(num_pages=32, page_size=8, max_pages=32))
    live = {}
    for i, (tokens, do_free) in enumerate(ops):
        if do_free and live:
            sid = next(iter(live))
            a.free(sid)
            del live[sid]
        else:
            pages = a.allocate(i, tokens)
            if pages is not None:
                live[i] = pages
        # invariants
        assert a.used_pages + a.free_pages == 32
        owned = [p for pages in live.values() for p in pages]
        assert len(owned) == len(set(owned))  # no double ownership
        assert a.used_pages == len(owned)


def test_allocator_utilization_watermark():
    a = PageAllocator(layout(num_pages=16, page_size=8, max_pages=16))
    a.allocate(1, 64)  # 8 pages
    a.allocate(2, 8)
    assert a.highest_used_page() == 9
    a.free(1)
    # only page 8 remains live -> watermark stays until reuse packs low again
    assert a.highest_used_page() == 9
    a.allocate(3, 8)
    assert 0 in a.seq_pages(3)


# ---------------------------------------------------------------------------
# Serving engine (continuous batching + IRM autoscaling)
# ---------------------------------------------------------------------------


ENGINE = EngineConfig(
    replica=ReplicaConfig(
        max_slots=4, kv_pages=256, page_size=16,
        prefill_tokens_per_s=100_000.0, decode_tokens_per_s=4_000.0,
        spinup_delay=2.0,
    ),
    max_replicas=4,
    dt=0.1,
)


def make_requests(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_len=int(rng.integers(64, 512)),
            max_new_tokens=int(rng.integers(16, 128)),
        )
        for _ in range(n)
    ]


def test_engine_drains_all_requests():
    eng = ServingEngine(ENGINE)
    for r in make_requests(40):
        eng.submit(r)
    eng.run_until_drained(t_max=600.0)
    assert len(eng.completed) == 40
    s = eng.summary()
    assert s["p50_latency"] > 0
    assert s["p99_latency"] >= s["p50_latency"]


def test_engine_scales_up_under_load_and_down_after():
    eng = ServingEngine(ENGINE)
    for r in make_requests(60, seed=1):
        eng.submit(r)
    eng.run_until_drained(t_max=600.0)
    peak = max(m["replicas"] for m in eng.metrics)
    assert peak > 1  # queue pressure triggered replica scale-up
    assert eng.metrics[-1]["replicas"] <= peak


def test_engine_respects_max_replicas():
    eng = ServingEngine(ENGINE)
    for r in make_requests(200, seed=2):
        eng.submit(r)
    for _ in range(2000):
        eng.step()
    assert max(m["replicas"] for m in eng.metrics) <= ENGINE.max_replicas


def test_engine_profiler_learns_request_cost():
    eng = ServingEngine(ENGINE)
    reqs = make_requests(30, seed=3)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(t_max=600.0)
    assert eng.profiler.num_observations("default") == 30
    learned = eng.profiler.estimate("default")
    rc = ENGINE.replica
    true_mean = np.mean(
        [min(1.0, r.total_tokens / (rc.kv_pages * rc.page_size)) for r in reqs]
    )
    assert learned == pytest.approx(true_mean, rel=0.3)


def test_engine_admission_never_overflows_slots():
    eng = ServingEngine(ENGINE)
    for r in make_requests(100, seed=4):
        eng.submit(r)
    for _ in range(1500):
        eng.step()
        for rep in eng.backend.replicas:
            if not rep.retired:
                assert (
                    len(rep.active) + len(rep.prefilling)
                    <= ENGINE.replica.max_slots
                )
