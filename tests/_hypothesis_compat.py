"""Optional-dependency shim: property tests skip cleanly without hypothesis.

``hypothesis`` is an optional extra (see requirements-dev.txt).  When it is
installed, this module re-exports the real ``given`` / ``settings`` /
``strategies``.  When it is not, ``@given(...)`` marks the test as skipped
and the strategy expressions evaluate to inert placeholders, so the seed
property suites (test_binpack, test_packing, test_irm_components,
test_serving, test_perf_paths) still *collect* and their plain pytest tests
still run.

Usage in a test module::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Stand-in ``@given``: skip the test instead of running it."""

        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        """Stand-in ``@settings``: identity decorator."""

        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Inert ``strategies`` namespace: every attribute is a callable
        returning a placeholder, so module-level strategy expressions like
        ``st.lists(st.floats(...), min_size=1)`` still evaluate."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
