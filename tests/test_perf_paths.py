"""Tests for the §Perf optimization paths: iterative top-k routing,
group-local MoE dispatch, distributed flash-decode, and the TPU-faithful
HLO accounting (AR+DS ≡ RS, bf16-payload detection)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.launch.hlo_analysis import analyze_hlo_text
from repro.models.layers import _decode_attention_local, decode_attention
from repro.models.moe import _top_k_iterative, expert_capacity

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Iterative top-k (partition-friendly router)
# ---------------------------------------------------------------------------


@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_topk_iterative_matches_lax(T, k, seed):
    E = 16
    k = min(k, E)
    rng = np.random.default_rng(seed)
    probs = jnp.asarray(jax.nn.softmax(
        jnp.asarray(rng.normal(size=(T, E)), jnp.float32)))
    v1, i1 = _top_k_iterative(probs, k)
    v2, i2 = jax.lax.top_k(probs, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    # indices may differ on exact ties; values define the routing weights
    np.testing.assert_allclose(
        np.sort(np.asarray(i1), axis=-1) == np.sort(np.asarray(i2), axis=-1),
        True,
    )


def test_expert_capacity_alignment():
    # einsum path: 8-aligned (tight); kernel path: 128-aligned (MXU tiles)
    assert expert_capacity(4096, 128, 8, 1.25, align=8) == 320
    assert expert_capacity(4096, 128, 8, 1.25, align=128) == 384
    assert expert_capacity(1, 128, 1, 1.0, align=8) == 8


def test_batch_shard_count_no_mesh():
    from repro.distributed.context import batch_shard_count

    assert batch_shard_count(256) == 1  # no mesh context active


# ---------------------------------------------------------------------------
# Distributed flash-decode
# ---------------------------------------------------------------------------


def test_decode_local_body_matches_dense():
    """offset=0, no collective axes == the dense decode reference."""
    rng = np.random.default_rng(0)
    B, H, KVH, S, D = 3, 8, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    lens = jnp.asarray([5, 64, 33], jnp.int32)
    out_local = _decode_attention_local(q, k, v, lens, 0, (), window=0)
    out_dense = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_dense),
                               rtol=2e-5, atol=2e-5)


def test_decode_local_body_offset_masks_correctly():
    """A shard whose slice starts past cache_len contributes nothing."""
    rng = np.random.default_rng(1)
    B, H, KVH, S, D = 2, 4, 2, 32, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    lens = jnp.asarray([10, 20], jnp.int32)
    out = _decode_attention_local(q, k, v, lens, 1000, (), window=0)
    assert np.abs(np.asarray(out)).max() == 0.0


@pytest.mark.slow
def test_distributed_decode_matches_single_device():
    """Run a tiny model's decode under a (2, 4) host-device mesh with the
    sequence-sharded cache + shard_map flash-decode, and compare logits
    against the plain single-device path (subprocess so XLA_FLAGS applies)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed.context import activation_sharding
        from repro.distributed.sharding import (
            batch_shardings, cache_shardings, make_rules, param_shardings)
        from repro.models import build_model, init_params

        cfg = get_config("qwen2-72b").smoke()   # GQA kv < model-axis size
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        B, T = 4, 8
        prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, T)),
                             jnp.int32)

        # single-device reference
        cache = model.init_cache(B, 32, dtype=jnp.float32)
        logits_ref = None
        for t in range(T):
            logits_ref, cache = model.decode_step(
                params, {"tokens": prompt[:, t:t+1]}, cache)

        # distributed: (data=2, model=4) mesh, sequence-sharded cache
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, "serve")
        p_shard = param_shardings(model.param_specs(), mesh, rules)
        params_d = jax.device_put(params, p_shard)
        with mesh, activation_sharding(mesh, rules):
            cache = model.init_cache(B, 32, dtype=jnp.float32)
            c_shard = cache_shardings(cache, mesh, rules)
            cache = jax.device_put(cache, c_shard)
            step = jax.jit(model.decode_step, donate_argnums=(2,))
            logits_d = None
            for t in range(T):
                logits_d, cache = step(
                    params_d, {"tokens": prompt[:, t:t+1]}, cache)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(logits_ref),
            rtol=2e-3, atol=2e-3)
        print("DISTRIBUTED_DECODE_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DISTRIBUTED_DECODE_OK" in proc.stdout


@pytest.mark.slow
def test_moe_group_local_dispatch_matches_single_device():
    """Group-local MoE dispatch under a mesh == single-device routing
    (same losses within drop-pattern tolerance at zero drops)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed.context import activation_sharding
        from repro.distributed.sharding import (
            batch_shardings, make_rules, param_shardings)
        from repro.models import build_model, init_params, make_batch

        cfg = get_config("qwen3-moe-30b-a3b").smoke()
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        batch = make_batch(cfg, "train", 8, 64, seed=0)

        loss_ref, _ = model.loss(params, batch)   # G = 1

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = make_rules(mesh, "fsdp")
        p_shard = param_shardings(model.param_specs(), mesh, rules)
        params_d = jax.device_put(params, p_shard)
        with mesh, activation_sharding(mesh, rules):
            loss_d, _ = jax.jit(model.loss)(params_d, batch)  # G = 8
        # same tokens, same experts; only the group partition of capacity
        # differs (zero drops at smoke scale) -> losses match closely
        np.testing.assert_allclose(float(loss_d), float(loss_ref),
                                   rtol=5e-3)
        print("MOE_GROUPS_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "MOE_GROUPS_OK" in proc.stdout


# ---------------------------------------------------------------------------
# TPU-faithful HLO accounting
# ---------------------------------------------------------------------------


def test_ar_plus_dynamic_slice_counts_as_reduce_scatter():
    hlo = """
HloModule test

%fused_dus (p0: f32[4096], p1: f32[1024]) -> f32[1024] {
  %p0 = f32[4096]{0} parameter(0)
  %p1 = f32[1024]{0} parameter(1)
  ROOT %dynamic-slice.1 = f32[1024]{0} dynamic-slice(%p0), dynamic_slice_sizes={1024}
}

ENTRY %main (p0: f32[4096]) -> f32[1024] {
  %p0 = f32[4096]{0} parameter(0)
  %ar = f32[4096]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %dynamic-slice.0 = f32[1024]{0} dynamic-slice(%ar), dynamic_slice_sizes={1024}
}
"""
    cost = analyze_hlo_text(hlo)
    # RS-equivalent: 1x tensor bytes (16384), not 2x
    assert cost.coll["all-reduce"] == pytest.approx(16384.0)


def test_plain_ar_still_counts_double():
    hlo = """
HloModule test

ENTRY %main (p0: f32[4096]) -> f32[4096] {
  %p0 = f32[4096]{0} parameter(0)
  %ar = f32[4096]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %neg = f32[4096]{0} negate(%ar)
}
"""
    cost = analyze_hlo_text(hlo)
    assert cost.coll["all-reduce"] == pytest.approx(2 * 16384.0)


def test_bf16_payload_detected_behind_cpu_promotion():
    hlo = """
HloModule test

%fused_cc (param_0: f32[1024]) -> f32[1024] {
  %param_0 = f32[1024]{0} parameter(0)
  %convert.1 = bf16[1024]{0} convert(%param_0)
  ROOT %convert.2 = f32[1024]{0} convert(%convert.1)
}

ENTRY %main (p0: f32[1024]) -> f32[4096] {
  %p0 = f32[1024]{0} parameter(0)
  %convert_convert_fusion = f32[1024]{0} fusion(%p0), kind=kLoop, calls=%fused_cc
  ROOT %ag = f32[4096]{0} all-gather(%convert_convert_fusion), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""
    cost = analyze_hlo_text(hlo)
    # payload is semantically bf16: half of the f32 output bytes
    assert cost.coll["all-gather"] == pytest.approx(16384.0 / 2)
