"""Tests for the divisibility-aware sharding rules and the trip-count-aware
HLO cost analysis that feeds the roofline."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed.sharding import axes_to_pspec, make_rules
from repro.launch.hlo_analysis import (
    analyze_hlo_text,
    top_collectives,
)


def abstract_mesh(shape, names):
    """AbstractMesh across jax versions: 0.4.x takes (name, size) pairs,
    newer jax takes positional (shape, names)."""
    try:
        return AbstractMesh(tuple(zip(names, shape, strict=True)))
    except TypeError:
        return AbstractMesh(shape, names)


def mesh_16x16():
    return abstract_mesh((16, 16), ("data", "model"))


def mesh_2x16x16():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def test_heads_shard_when_divisible():
    mesh = mesh_16x16()
    rules = make_rules(mesh)
    # 64 heads % 16 == 0 -> sharded on model
    spec = axes_to_pspec(("embed", "heads", "head_dim"), (8192, 64, 128),
                         rules, mesh)
    assert spec == P("data", "model", None)


def test_kv_heads_replicate_when_indivisible():
    mesh = mesh_16x16()
    rules = make_rules(mesh)
    # qwen2: 8 kv heads % 16 != 0 -> replicated
    spec = axes_to_pspec(("embed", "kv_heads", "head_dim"), (8192, 8, 128),
                         rules, mesh)
    assert spec == P("data", None, None)


def test_experts_ep_vs_fallback():
    mesh = mesh_16x16()
    rules = make_rules(mesh)
    # qwen3-moe: 128 experts % 16 == 0 -> EP on model
    spec = axes_to_pspec(("experts", "embed", "mlp"), (128, 2048, 768),
                         rules, mesh)
    assert spec == P("model", "data", None)
    # grok: 8 experts % 16 != 0 -> replicate experts, shard d_ff instead
    spec = axes_to_pspec(("experts", "embed", "mlp"), (8, 6144, 32768),
                         rules, mesh)
    assert spec == P(None, "data", "model")


def test_axis_used_once_per_tensor():
    mesh = mesh_16x16()
    rules = make_rules(mesh)
    # vocab wants model, mlp wants model: only the first dim gets it
    spec = axes_to_pspec(("vocab", "mlp"), (65536, 4096), rules, mesh)
    assert spec == P("model", None)


def test_kv_seq_composes_remaining_axes():
    mesh = mesh_16x16()
    rules = make_rules(mesh)
    # decode cache (layers, B, S, KVH, hd): batch over data, kv_seq gets model
    spec = axes_to_pspec(
        ("layers", "batch", "kv_seq", "kv_heads", None),
        (8, 128, 32768, 8, 128), rules, mesh,
    )
    assert spec == P(None, "data", "model", None, None)
    # long_500k: B=1 -> batch unshardable, kv_seq takes data AND model
    spec = axes_to_pspec(
        ("layers", "batch", "kv_seq", "kv_heads", None),
        (4, 1, 524288, 8, 128), rules, mesh,
    )
    assert spec == P(None, None, ("data", "model"), None, None)


def test_multipod_embed_takes_pod_and_data():
    mesh = mesh_2x16x16()
    rules = make_rules(mesh)
    spec = axes_to_pspec(("embed", "mlp"), (8192, 29568), rules, mesh)
    assert spec == P(("pod", "data"), "model")


def test_indivisible_dim_skips_axis_entirely():
    mesh = mesh_16x16()
    rules = make_rules(mesh)
    # internvl2: d_model=896; 896 % 16 == 0 -> shards; 14 heads -> replicated
    spec = axes_to_pspec(("embed", "heads", "head_dim"), (896, 14, 64),
                         rules, mesh)
    assert spec == P("data", None, None)


# ---------------------------------------------------------------------------
# HLO analysis (trip-count-aware cost)
# ---------------------------------------------------------------------------


def test_dot_flops_counted():
    def f(a, b):
        return a @ b

    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    hlo = jax.jit(f).lower(a, b).compile().as_text()
    cost = analyze_hlo_text(hlo)
    assert cost.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_loop_multiplier():
    """cost_analysis counts a while body once; ours multiplies by trips."""
    TRIPS = 7

    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return out

    x = jnp.zeros((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    hlo = compiled.as_text()
    cost = analyze_hlo_text(hlo)
    expect = TRIPS * 2 * 64 ** 3
    assert cost.flops == pytest.approx(expect, rel=0.05)
    # XLA's own analysis undercounts (body counted once) — this is exactly
    # why hlo_analysis exists; guard the assumption:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    assert xla_flops < expect


def test_collective_wire_bytes_conventions():
    hlo = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cost = analyze_hlo_text(hlo)
    assert cost.coll["all-reduce"] == pytest.approx(2 * 4096.0)  # 2x bytes
    assert cost.coll["all-gather"] == pytest.approx(16384.0)     # output bytes
    assert cost.coll["collective-permute"] == pytest.approx(4096.0)
    assert cost.coll_count == 3
    assert cost.dcn_bytes == 0.0


def test_cross_pod_classified_as_dcn():
    hlo = """
HloModule test

ENTRY %main (p0: f32[256]) -> f32[256] {
  %p0 = f32[256]{0} parameter(0)
  ROOT %ar = f32[256]{0} all-reduce(%p0), replica_groups={{0,256}}, to_apply=%add
}
"""
    cost = analyze_hlo_text(hlo, pod_size=256)
    assert cost.dcn_bytes > 0
    assert cost.ici_bytes == 0.0


def test_real_program_collectives_under_mesh():
    """An actually-sharded program reports nonzero collective bytes."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices for a real collective")


def test_top_collectives_ranking():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    hlo = jax.jit(f).lower(jnp.zeros((32, 32))).compile().as_text()
    rows = top_collectives(hlo, n=5)
    assert isinstance(rows, list)  # no collectives on 1 device -> empty ok
