"""Per-architecture smoke tests (assigned-architecture requirement).

Each assigned architecture is instantiated at its REDUCED same-family config
(``ArchConfig.smoke()``: tiny dims, 2 pattern periods, few experts) and runs
one forward/train step plus a prefill->decode consistency check on CPU,
asserting output shapes and the absence of NaNs.  The FULL configs are only
ever exercised via the dry-run (ShapeDtypeStructs, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model, init_params, make_batch
from repro.training import OptimizerConfig, init_opt_state, make_train_step

B, S = 2, 64


@pytest.fixture(scope="module")
def built():
    """Cache (model, params, batch) per arch across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).smoke()
            model = build_model(cfg)
            params = init_params(model.param_specs(), jax.random.PRNGKey(0))
            batch = make_batch(cfg, "train", B, S, seed=1)
            cache[arch] = (cfg, model, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_matches_assignment(arch):
    """The full config carries the exact assigned hyper-parameters."""
    cfg = get_config(arch)
    assigned = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }[arch]
    L, d, H, KVH, dff, V = assigned
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KVH
    assert cfg.vocab_size == V
    if cfg.moe is not None:
        assert cfg.moe.expert_d_ff == dff
    else:
        assert cfg.d_ff == dff
    # family-specific structure
    if arch == "jamba-v0.1-52b":
        assert cfg.pattern.count("A") * 7 == cfg.pattern.count("M")
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 2
    if arch == "qwen3-moe-30b-a3b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 8
    if arch == "grok-1-314b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
    if arch == "qwen3-8b":
        assert cfg.qk_norm
    if arch == "qwen2-72b":
        assert cfg.qkv_bias
    if arch == "olmo-1b":
        assert cfg.norm_type == "layernorm_np"
    if arch == "xlstm-125m":
        assert set(cfg.pattern) <= {"l", "s"}
    if arch == "seamless-m4t-medium":
        assert cfg.encdec and cfg.frontend == "audio"
    if arch == "internvl2-1b":
        assert cfg.frontend == "vision"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_loss_shapes_and_finite(arch, built):
    cfg, model, params, batch = built(arch)
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch} loss is not finite"
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step_updates_params(arch, built):
    cfg, model, params, batch = built(arch)
    step_fn = make_train_step(model, OptimizerConfig(learning_rate=1e-3))
    opt_state = init_opt_state(params)
    new_params, new_opt, metrics = jax.jit(step_fn)(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(new_opt["step"]) == 1
    # params actually moved and stayed finite
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params
    )
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert jnp.all(jnp.isfinite(leaf))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch, built):
    """decode_step after prefill continues the sequence the prefill built:
    prefill logits of the full prompt == teacher-forced decode logits."""
    cfg, model, params, _ = built(arch)
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(1, 8)), jnp.int32
    )
    batch = {
        "tokens": prompt,
        "segment_ids": jnp.ones_like(prompt),
        "positions": jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (1, 8)),
    }
    if cfg.encdec:
        enc_len = 8
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(1, enc_len, cfg.d_model)) * 0.02, jnp.float32
        )
        batch["enc_segment_ids"] = jnp.ones((1, enc_len), jnp.int32)
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(1, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.float32,
        )

    logits_p, cache = model.prefill(params, batch)
    assert jnp.all(jnp.isfinite(logits_p))

    next_tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)[:, None]
    logits_d, cache = model.decode_step(params, {"tokens": next_tok}, cache)
    assert logits_d.shape == logits_p.shape
    assert jnp.all(jnp.isfinite(logits_d))
    # decoding a second token also works (cache round-trips)
    tok2 = jnp.argmax(logits_d, axis=-1).astype(jnp.int32)[:, None]
    logits_d2, _ = model.decode_step(params, {"tokens": tok2}, cache)
    assert jnp.all(jnp.isfinite(logits_d2))


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen3-8b", "xlstm-125m",
                                  "jamba-v0.1-52b"])
def test_decode_matches_prefill_teacher_forced(arch, built):
    """Stronger consistency: running the prompt token-by-token through
    decode_step produces (approximately) the prefill's last-token logits."""
    cfg, model, params, _ = built(arch)
    rng = np.random.default_rng(3)
    T = 6
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(1, T)), jnp.int32)
    batch = {
        "tokens": prompt,
        "segment_ids": jnp.ones_like(prompt),
        "positions": jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (1, T)),
    }
    logits_p, _ = model.prefill(params, batch)

    cache = model.init_cache(1, T + 2, dtype=jnp.float32)
    logits_d = None
    for t in range(T):
        logits_d, cache = model.decode_step(
            params, {"tokens": prompt[:, t : t + 1]}, cache
        )
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_p), rtol=2e-2, atol=2e-2
    )


def test_packed_vs_separate_loss_equivalence():
    """Two documents packed into one row give the same loss as two rows —
    the correctness contract of First-Fit packing + segment masking."""
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    d1 = rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
    d2 = rng.integers(1, cfg.vocab_size, size=40).astype(np.int32)

    S = 64

    def row(doc, seg_id):
        t = np.zeros(S, np.int32)
        l = np.full(S, -1, np.int32)
        s = np.zeros(S, np.int32)
        p = np.zeros(S, np.int32)
        n = len(doc)
        t[:n] = doc
        l[: n - 1] = doc[1:]
        s[:n] = seg_id
        p[:n] = np.arange(n)
        return t, l, s, p

    # packed: both documents in one row
    tp = np.zeros(S, np.int32)
    lp = np.full(S, -1, np.int32)
    sp = np.zeros(S, np.int32)
    pp = np.zeros(S, np.int32)
    tp[: len(d1)] = d1
    lp[: len(d1) - 1] = d1[1:]
    sp[: len(d1)] = 1
    pp[: len(d1)] = np.arange(len(d1))
    off = len(d1)
    tp[off : off + len(d2)] = d2
    lp[off : off + len(d2) - 1] = d2[1:]
    sp[off : off + len(d2)] = 2
    pp[off : off + len(d2)] = np.arange(len(d2))

    packed = {
        "tokens": jnp.asarray(tp)[None],
        "labels": jnp.asarray(lp)[None],
        "segment_ids": jnp.asarray(sp)[None],
        "positions": jnp.asarray(pp)[None],
    }
    r1, r2 = row(d1, 1), row(d2, 1)
    separate = {
        "tokens": jnp.asarray(np.stack([r1[0], r2[0]])),
        "labels": jnp.asarray(np.stack([r1[1], r2[1]])),
        "segment_ids": jnp.asarray(np.stack([r1[2], r2[2]])),
        "positions": jnp.asarray(np.stack([r1[3], r2[3]])),
    }
    loss_packed, _ = model.loss(params, packed)
    loss_sep, _ = model.loss(params, separate)
    np.testing.assert_allclose(
        float(loss_packed), float(loss_sep), rtol=1e-4
    )


def test_param_counts_match_materialized():
    """Analytic param_counts() agrees with the materialized tree (smoke)."""
    for arch in ("olmo-1b", "qwen3-8b"):
        cfg = get_config(arch).smoke()
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        n_real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        n_analytic, _ = cfg.param_counts()
        # analytic count excludes norm scales and uses the unpadded vocab;
        # require agreement within 5%
        assert abs(n_real - n_analytic) / n_real < 0.05
