"""Cross-backend validation: the live runtime tracks the simulator.

The discrete-event sim is tick-exact; the live runtime executes the same
scenarios as real concurrent asyncio work in scaled wall-clock time.  The
two can never be bit-identical — that divergence under real concurrency is
the point of having a live backend — but the *scheduling behavior* the
paper measures must land in the same place: utilization of the workers the
IRM opens, and how many workers it targets.  These tests pin that for a
scalar policy on the paper's scenarios, for a vector policy on the
multi-resource ones (including the rigid accelerator gate under concurrent
pulls), for the event-driven arrival races of the bursty shape, and for
the fault model: a worker killed mid-run must requeue its in-flight
messages and still complete the stream on both backends, with identical
requeue accounting.

The same bands pin ``backend="multiproc"`` — the live runtime with every
worker promoted to an OS process behind pickled queues
(``runtime.transport.MultiprocTransport``).  That backend adds real IPC
latency and process scheduling on top of event-loop jitter, yet must
exhibit the *same* packing behavior, because the master, IRM, and
lifecycle code are byte-for-byte shared and only the transport differs.

Tolerances are deliberately wide bands, not equalities: they catch the
failure modes we actually saw while building the backend (phantom-bin
livelock → utilization collapses to ~half; arrival race → worker target
overshoots by 2x) while staying robust to honest scheduling jitter.
"""

import os
import tempfile

import pytest

from repro.obs import ObsConfig
from repro.obs.analyze import drift_report, render_drift
from repro.obs.exporters import write_jsonl
from repro.runtime import RuntimeConfig
from repro.scenarios.engine import run_scenario
from repro.scenarios.registry import get_scenario

# 1 scenario second = 10 ms wall: fast enough for CI, coarse enough that
# event-loop jitter on a loaded runner stays small relative to the delays
FAST = RuntimeConfig(time_scale=0.01)
# the process backend adds queue hops and OS scheduling; give it 2x the
# wall budget per scenario second so IPC latency stays small relative to
# the boot/start delays the bands are calibrated against
FAST_MP = RuntimeConfig(time_scale=0.02)


def _pair(name: str, policy: str, seed: int = 0, sim_overrides=None,
          live_backend: str = "live"):
    scn = get_scenario(name)
    kwargs = dict(
        policy=policy,
        base_seed=seed,
        n_runs=1,
        stream_overrides=scn.smoke_overrides,
        t_max=scn.smoke_t_max,
        sim_overrides=sim_overrides,
        obs=ObsConfig(),
    )
    sim = run_scenario(name, backend="sim", **kwargs)
    runtime = FAST if live_backend == "live" else FAST_MP
    live = run_scenario(name, backend=live_backend, runtime=runtime,
                        **kwargs)
    return sim, live


def _dump_events_on_failure(sim, live) -> str:
    """A band failure on its own says *that* the backends diverged, not
    where.  Dump both runs' event logs next to the failure and fold the
    analyzer's drift report into the assertion message, so the first
    CI failure already shows which lifecycle stage (queue-wait, handoff,
    service) or event count moved."""
    if sim.obs is None or live.obs is None:
        return ""
    d = tempfile.mkdtemp(prefix="parity-events-")
    write_jsonl(os.path.join(d, "sim-events.jsonl"), sim.obs.events)
    write_jsonl(os.path.join(d, "live-events.jsonl"), live.obs.events)
    report = drift_report(sim.obs.events, live.obs.events)
    return (
        f"\n\nevent logs dumped to {d} (a=sim-events.jsonl, "
        f"b=live-events.jsonl)\n" + render_drift(report)
    )


def _assert_same_resource_mix(sim, live, *, abs_tol: float = 0.1):
    """Pin the per-dimension demand mix across backends.

    ``summary["bottleneck_dim"]`` is the argmax over total scheduled
    resource, and the mixed-accel scenario keeps its two tenant
    dimensions deliberately near-balanced (complementary tenants) — the
    totals sit within a few percent of each other, so the argmax *label*
    can flip on wall-clock jitter even when the backend schedules the
    right mix.  Comparing each dimension's share of the total is
    strictly stronger than label equality whenever the scenario has a
    decisive bottleneck, and stays meaningful when it does not."""
    sim_tot = sim.final.scheduled_res.sum(axis=(0, 1))
    live_tot = live.final.scheduled_res.sum(axis=(0, 1))
    sim_share = sim_tot / sim_tot.sum()
    live_share = live_tot / live_tot.sum()
    try:
        assert live_share == pytest.approx(sim_share, abs=abs_tol), (
            f"scheduled-resource mix diverged: dims "
            f"{sim.final.resource_dims} sim {sim_share} vs live {live_share}"
        )
    except AssertionError as exc:
        raise AssertionError(
            str(exc) + _dump_events_on_failure(sim, live)
        ) from None


def _assert_parity(sim, live, *, util_tol: float, target_tol: int,
                   makespan_ratio: float):
    s, l = sim.summary, live.summary
    try:
        # both backends process (nearly) the whole stream
        assert l["completed"] >= 0.9 * l["total"]
        assert s["completed"] >= 0.9 * s["total"]
        # utilization of scheduled-active worker cells
        assert l["mean_scheduled_utilization_active"] == pytest.approx(
            s["mean_scheduled_utilization_active"], abs=util_tol
        )
        # worker-target trajectory endpoints
        assert abs(l["max_target_workers"]
                   - s["max_target_workers"]) <= target_tol
        lf = int(live.final.target_workers[-1])
        sf = int(sim.final.target_workers[-1])
        assert abs(lf - sf) <= target_tol
        # end-to-end drain time within a band of the sim's
        assert l["makespan_s"] <= makespan_ratio * s["makespan_s"]
        assert l["makespan_s"] >= s["makespan_s"] / makespan_ratio
    except AssertionError as exc:
        raise AssertionError(
            str(exc) + _dump_events_on_failure(sim, live)
        ) from None


@pytest.mark.timeout(180)
def test_live_matches_sim_synthetic_first_fit():
    """Scalar policy, the paper's Sec. VI-A scenario."""
    sim, live = _pair("synthetic", "first-fit")
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)


@pytest.mark.timeout(180)
def test_live_matches_sim_microscopy_first_fit():
    """Scalar policy, the paper's Sec. VI-B use case."""
    sim, live = _pair("microscopy", "first-fit")
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)
    # both concentrate load on low-index workers (Fig. 3 behavior)
    assert live.summary["low_index_load_fraction"] > 0.6
    assert sim.summary["low_index_load_fraction"] > 0.6


@pytest.mark.timeout(180)
def test_live_matches_sim_vector_policy():
    """Vector policy on the multi-resource scenario: same bottleneck
    dimension, same capacity guarantees, comparable packing density."""
    sim, live = _pair("microscopy-mem", "vector-first-fit")
    _assert_parity(sim, live, util_tol=0.2, target_tol=3,
                   makespan_ratio=1.8)
    assert live.summary["bottleneck_dim"] == sim.summary["bottleneck_dim"]
    for res in (live.final, sim.final):
        assert (res.scheduled_res <= 1.0 + 1e-9).all()


@pytest.mark.timeout(180)
def test_live_matches_sim_bursty_first_fit():
    """Event-driven arrival races: bursts land on the live master from a
    real feeder task, not a tick boundary — the adversarial case for the
    queue-ROC predictor on both backends."""
    sim, live = _pair("bursty", "first-fit")
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)
    # both see the bursts as genuine backlog spikes
    assert sim.summary["peak_queue_len"] >= 8
    assert live.summary["peak_queue_len"] >= 8


@pytest.mark.timeout(180)
def test_live_matches_sim_mixed_accel_vector():
    """The rigid accelerator gate under concurrent pulls: complementary
    CPU/accel tenants must co-locate without overcommitting either
    dimension on either backend."""
    sim, live = _pair("mixed-accel", "vector-first-fit")
    _assert_parity(sim, live, util_tol=0.2, target_tol=3,
                   makespan_ratio=1.8)
    _assert_same_resource_mix(sim, live)
    for res in (live.final, sim.final):
        assert (res.scheduled_res <= 1.0 + 1e-9).all()


@pytest.mark.timeout(180)
def test_fault_parity_worker_kill_mid_run():
    """The paper's V-B.2 fault-tolerance claim, pinned across backends: a
    worker killed mid-run loses its in-flight messages back to the queue
    head (TTL requeue, at-least-once), and *both* backends still complete
    the entire stream — with identical requeue accounting.

    The kill lands at t=20.5, the midpoint of the schedule's largest
    start/done-free window (no message event within ±2.0 scenario
    seconds), and this test runs at a slower time scale than the rest of
    the suite (1 scenario second = 50 ms wall), so the in-flight set at
    the kill — and therefore the requeue count — tolerates ~100 ms of
    event-loop jitter before it could change.  That makes the *exact*
    count equality below safe to assert on a loaded CI runner."""
    scn = get_scenario("microscopy")
    kwargs = dict(
        policy="first-fit", base_seed=0, n_runs=1,
        stream_overrides=scn.smoke_overrides, t_max=scn.smoke_t_max,
        sim_overrides={"fail_worker_at": (0, 20.5)},
        obs=ObsConfig(),
    )
    sim = run_scenario("microscopy", backend="sim", **kwargs)
    live = run_scenario("microscopy", backend="live",
                        runtime=RuntimeConfig(time_scale=0.05), **kwargs)
    # at-least-once: every message completes despite the kill
    assert sim.summary["completed"] == sim.summary["total"]
    assert live.summary["completed"] == live.summary["total"]
    # the kill actually caught in-flight work, and the two fault models
    # harvested exactly the same messages
    assert sim.final.requeued > 0
    assert live.final.requeued == sim.final.requeued
    # scheduling behavior stays inside the standard parity bands
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)


# ---------------------------------------------------------------------------
# The same contracts over OS-process workers (backend="multiproc")
# ---------------------------------------------------------------------------


@pytest.mark.timeout(240)
def test_multiproc_matches_sim_microscopy_first_fit():
    """Scalar policy over real process workers: the paper's use case must
    land in the exact bands the in-process asyncio backend is held to —
    the transport swap may not change packing behavior."""
    sim, live = _pair("microscopy", "first-fit", live_backend="multiproc")
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)
    assert live.summary["low_index_load_fraction"] > 0.6


@pytest.mark.timeout(240)
def test_multiproc_matches_sim_mixed_accel_vector():
    """The rigid accelerator gate with pulls arriving as IPC events: the
    gate check runs master-side on the event loop (head + gate + pull is
    atomic there), so capacity guarantees must hold exactly even though
    the requesting PEs live in other processes."""
    sim, live = _pair("mixed-accel", "vector-first-fit",
                      live_backend="multiproc")
    _assert_parity(sim, live, util_tol=0.2, target_tol=3,
                   makespan_ratio=1.8)
    _assert_same_resource_mix(sim, live)
    for res in (live.final, sim.final):
        assert (res.scheduled_res <= 1.0 + 1e-9).all()


@pytest.mark.timeout(240)
def test_multiproc_fault_parity_worker_kill_mid_run():
    """The fault contract over a *real* SIGKILL: killing the worker's OS
    process mid-run must harvest its in-flight messages back to the
    master's head and still complete the whole stream.  The requeue count
    can differ from the sim's by the messages the process had already
    flushed into the data queue at the kill instant (the drain applies
    those as completions — work that genuinely finished is not redone),
    so this asserts a band rather than the in-process backend's exact
    equality: at least one requeue, within ±2 of the sim's count."""
    scn = get_scenario("microscopy")
    kwargs = dict(
        policy="first-fit", base_seed=0, n_runs=1,
        stream_overrides=scn.smoke_overrides, t_max=scn.smoke_t_max,
        sim_overrides={"fail_worker_at": (0, 20.5)},
        obs=ObsConfig(),
    )
    sim = run_scenario("microscopy", backend="sim", **kwargs)
    live = run_scenario("microscopy", backend="multiproc",
                        runtime=RuntimeConfig(time_scale=0.05), **kwargs)
    # at-least-once: every message completes despite the SIGKILL
    assert sim.summary["completed"] == sim.summary["total"]
    assert live.summary["completed"] == live.summary["total"]
    assert sim.final.requeued > 0
    assert live.final.requeued > 0
    assert abs(live.final.requeued - sim.final.requeued) <= 2
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)
