"""Cross-backend validation: the live runtime tracks the simulator.

The discrete-event sim is tick-exact; the live runtime executes the same
scenarios as real concurrent asyncio work in scaled wall-clock time.  The
two can never be bit-identical — that divergence under real concurrency is
the point of having a live backend — but the *scheduling behavior* the
paper measures must land in the same place: utilization of the workers the
IRM opens, and how many workers it targets.  These tests pin that, for a
scalar policy on the paper's scenarios and a vector policy on the
multi-resource one.

Tolerances are deliberately wide bands, not equalities: they catch the
failure modes we actually saw while building the backend (phantom-bin
livelock → utilization collapses to ~half; arrival race → worker target
overshoots by 2x) while staying robust to honest scheduling jitter.
"""

import pytest

from repro.runtime import RuntimeConfig
from repro.scenarios.engine import run_scenario
from repro.scenarios.registry import get_scenario

# 1 scenario second = 10 ms wall: fast enough for CI, coarse enough that
# event-loop jitter on a loaded runner stays small relative to the delays
FAST = RuntimeConfig(time_scale=0.01)


def _pair(name: str, policy: str, seed: int = 0):
    scn = get_scenario(name)
    kwargs = dict(
        policy=policy,
        base_seed=seed,
        n_runs=1,
        stream_overrides=scn.smoke_overrides,
        t_max=scn.smoke_t_max,
    )
    sim = run_scenario(name, backend="sim", **kwargs)
    live = run_scenario(name, backend="live", runtime=FAST, **kwargs)
    return sim, live


def _assert_parity(sim, live, *, util_tol: float, target_tol: int,
                   makespan_ratio: float):
    s, l = sim.summary, live.summary
    # both backends process (nearly) the whole stream
    assert l["completed"] >= 0.9 * l["total"]
    assert s["completed"] >= 0.9 * s["total"]
    # utilization of scheduled-active worker cells
    assert l["mean_scheduled_utilization_active"] == pytest.approx(
        s["mean_scheduled_utilization_active"], abs=util_tol
    )
    # worker-target trajectory endpoints
    assert abs(l["max_target_workers"] - s["max_target_workers"]) <= target_tol
    lf = int(live.final.target_workers[-1])
    sf = int(sim.final.target_workers[-1])
    assert abs(lf - sf) <= target_tol
    # end-to-end drain time within a band of the sim's
    assert l["makespan_s"] <= makespan_ratio * s["makespan_s"]
    assert l["makespan_s"] >= s["makespan_s"] / makespan_ratio


@pytest.mark.timeout(180)
def test_live_matches_sim_synthetic_first_fit():
    """Scalar policy, the paper's Sec. VI-A scenario."""
    sim, live = _pair("synthetic", "first-fit")
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)


@pytest.mark.timeout(180)
def test_live_matches_sim_microscopy_first_fit():
    """Scalar policy, the paper's Sec. VI-B use case."""
    sim, live = _pair("microscopy", "first-fit")
    _assert_parity(sim, live, util_tol=0.15, target_tol=2,
                   makespan_ratio=1.6)
    # both concentrate load on low-index workers (Fig. 3 behavior)
    assert live.summary["low_index_load_fraction"] > 0.6
    assert sim.summary["low_index_load_fraction"] > 0.6


@pytest.mark.timeout(180)
def test_live_matches_sim_vector_policy():
    """Vector policy on the multi-resource scenario: same bottleneck
    dimension, same capacity guarantees, comparable packing density."""
    sim, live = _pair("microscopy-mem", "vector-first-fit")
    _assert_parity(sim, live, util_tol=0.2, target_tol=3,
                   makespan_ratio=1.8)
    assert live.summary["bottleneck_dim"] == sim.summary["bottleneck_dim"]
    for res in (live.final, sim.final):
        assert (res.scheduled_res <= 1.0 + 1e-9).all()
