"""Integration tests: the IRM driving the simulated cluster (paper Sec. VI).

Each test pins one of the paper's evaluation claims at small scale:
utilization concentrates on low-index workers, schedules stay <= 100%,
error settles near zero outside start/stop transients, worker caps are
respected while the IRM keeps requesting more, profile learning across runs,
and fault tolerance under worker failure.
"""

import numpy as np

from repro.core import (
    IRM,
    IRMConfig,
    SimConfig,
    simulate,
    synthetic_workload,
    usecase_workload,
)


def small_usecase(seed=0, n=60):
    return usecase_workload(seed=seed, n_images=n, duration_range=(4.0, 8.0))


SIM = SimConfig(
    dt=0.5,
    cores_per_worker=4,
    max_workers=5,
    worker_boot_delay=5.0,
    pe_start_delay=1.0,
    container_idle_timeout=1.0,
    t_max=900.0,
    seed=0,
)


def test_all_messages_complete():
    res = simulate(small_usecase(), SIM)
    assert res.completed == res.total
    assert res.makespan > 0


def test_load_concentrates_on_low_index_workers():
    """Fig. 3: 'the workload is focused toward the lower index workers'."""
    res = simulate(small_usecase(n=40), SIM)
    per_worker = res.scheduled_cpu.sum(axis=0)  # time-integrated load
    # low-index half must carry strictly more than the high-index half
    w = len(per_worker)
    assert per_worker[: w // 2].sum() > per_worker[w - w // 2 :].sum()
    # and worker 0 is the busiest
    assert per_worker.argmax() == 0


def test_scheduled_cpu_never_exceeds_capacity():
    res = simulate(small_usecase(), SIM)
    assert (res.scheduled_cpu <= 1.0 + 1e-9).all()


def test_workers_filled_before_spill():
    """Fig. 4/8: utilization peaks at 90-100% before the next worker opens."""
    res = simulate(usecase_workload(seed=1, n_images=120,
                                    duration_range=(4.0, 8.0)), SIM)
    # whenever worker 1 is scheduled above zero, worker 0's scheduled load
    # must (at that moment) be high — First-Fit spills only when full.
    spill = res.scheduled_cpu[:, 1] > 0.05
    assert spill.any()
    w0_at_spill = res.scheduled_cpu[spill, 0]
    assert np.median(w0_at_spill) > 0.7


def test_error_settles_near_zero():
    """Fig. 5/9: error is noisy at PE start bursts, settles close to 0."""
    res = simulate(small_usecase(n=80), SIM)
    err = res.error  # percentage points
    busy = res.scheduled_cpu > 0.2
    # overall mean absolute error bounded (transients included)
    assert np.abs(err[busy]).mean() < 40.0
    # in the steady middle of the run the median error is small
    T = err.shape[0]
    mid = slice(T // 3, 2 * T // 3)
    mid_busy = busy[mid]
    if mid_busy.any():
        assert np.median(np.abs(err[mid][mid_busy])) < 25.0


def test_worker_cap_respected_but_target_exceeds():
    """Fig. 10: the IRM keeps requesting beyond the 5-worker cap."""
    big = usecase_workload(seed=2, n_images=300, duration_range=(8.0, 16.0))
    res = simulate(big, SIM)
    assert res.active_workers.max() <= SIM.max_workers
    assert res.target_workers.max() > SIM.max_workers


def test_profile_learning_across_runs():
    """Sec. VI-B: 'the initial run performed slightly worse than subsequent
    runs' — profile persistence across runs improves the makespan."""
    irm = IRM(IRMConfig())
    makespans = []
    for run in range(3):
        stream = usecase_workload(seed=run, n_images=60,
                                  duration_range=(4.0, 8.0))
        res = simulate(stream, SIM, irm=irm)
        assert res.completed == res.total
        makespans.append(res.makespan)
    # profiled runs are no slower than the cold one (small tolerance)
    assert min(makespans[1:]) <= makespans[0] * 1.10


def test_worker_failure_recovery():
    """Fault tolerance: a killed worker's in-flight messages are requeued
    and the workload still completes."""
    cfg = SimConfig(**{**SIM.__dict__, "fail_worker_at": (0, 30.0),
                       "t_max": 1200.0})
    res = simulate(small_usecase(n=50), cfg)
    assert res.completed == res.total


def test_synthetic_workload_with_peaks_completes():
    stream = synthetic_workload(
        seed=0, t_end=120.0, batch_interval=12.0, batch_size=(2, 4),
        peak_times=(40.0,), peak_size=16,
    )
    res = simulate(stream, SimConfig(**{**SIM.__dict__, "t_max": 1500.0}))
    assert res.completed == res.total
    # the peak shows up as a queue spike
    assert res.queue_len.max() >= 8


def test_idle_workers_are_released():
    """Idle PEs self-terminate: the PE population shrinks as the backlog
    drains (the sim stops at completion, before workers fully deactivate)."""
    res = simulate(small_usecase(n=30), SIM)
    peak = res.pe_count.max()
    assert peak >= 4
    assert res.pe_count[-1] < peak


def test_metrics_recorded_every_tick():
    res = simulate(small_usecase(n=20), SIM)
    T = len(res.times)
    assert res.measured_cpu.shape == (T, SIM.max_workers)
    assert res.scheduled_cpu.shape == (T, SIM.max_workers)
    assert len(res.queue_len) == T
    assert len(res.ideal_bins) == T
