"""End-to-end system tests.

``test_dryrun_single_cell`` runs the actual multi-pod dry-run entry point in
a subprocess (it must set XLA_FLAGS before jax initializes, which cannot
happen in-process here): one cheap cell on both the 16x16 and 2x16x16
production meshes — the minimal proof that the launcher, shardings, and
compile path are coherent.  The full 64-cell sweep lives in
``results/dryrun_baseline.json`` (see EXPERIMENTS.md §Dry-run).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    out = tmp_path / "dryrun.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "olmo-1b", "--shape", "decode_32k",
            "--multi-pod", "both", "--out", str(out),
        ],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    records = json.loads(out.read_text())
    assert len(records) == 2
    for rec in records:
        assert "error" not in rec
        assert rec["chips"] in (256, 512)
        assert rec["memory"]["total_hbm_bytes"] > 0
        assert rec["flops_per_dev"] > 0
        assert rec["collectives"]["total"] > 0
        assert rec["dominant"] in ("compute", "memory", "collective")
    multi = next(r for r in records if r["mesh"] == "2x16x16")
    assert multi["chips"] == 512


def test_end_to_end_stream_train():
    """Stream documents -> First-Fit packing -> train a tiny model a few
    steps — the paper's pipeline wired end to end."""
    import jax

    from repro.configs import get_config
    from repro.data import StreamingPipeline, synthetic_documents
    from repro.models import build_model, init_params
    from repro.training import OptimizerConfig, init_opt_state, make_train_step

    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, OptimizerConfig(learning_rate=1e-3)))

    docs = synthetic_documents(cfg.vocab_size, mean_len=80, max_len=256,
                               seed=0, limit=200)
    pipe = StreamingPipeline(docs, seq_len=128, batch_size=2, prefetch=2)

    import jax.numpy as jnp

    losses = []
    for i, pb in enumerate(pipe):
        batch = {
            "tokens": jnp.asarray(pb.tokens),
            "labels": jnp.asarray(pb.labels),
            "segment_ids": jnp.asarray(pb.segment_ids),
            "positions": jnp.asarray(pb.positions),
        }
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if i >= 8:
            break
    assert all(np.isfinite(l) for l in losses)
    assert int(opt["step"]) >= 8


def test_paper_headline_hio_beats_spark():
    """Section VI-B: HIO+IRM finishes the image batch in roughly half
    Spark's wall time (asserted loosely at >= 1.3x here for a reduced run)."""
    from repro.core import (
        SimConfig,
        SparkConfig,
        simulate,
        simulate_spark,
        usecase_workload,
    )

    stream_h = usecase_workload(seed=0, n_images=200)
    hio = simulate(
        stream_h,
        SimConfig(dt=0.5, cores_per_worker=8, max_workers=5,
                  worker_boot_delay=10.0, pe_start_delay=2.0, t_max=3000.0),
    )
    stream_s = usecase_workload(seed=0, n_images=200)
    spark = simulate_spark(stream_s, SparkConfig(t_max=3000.0))
    assert hio.completed == hio.total
    assert spark.completed == spark.total
    assert spark.makespan > 1.3 * hio.makespan
