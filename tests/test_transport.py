"""Transport layer: OS-process workers behind the same master.

``backend="multiproc"`` promotes every worker to a real
``multiprocessing.Process`` speaking pickled command/data queues
(``runtime.transport.MultiprocTransport``), while ``backend="live"``
keeps the zero-copy in-process handoff (``InProcTransport``).  These
tests pin the transport contract itself: streams complete over the
process boundary, the serialization counters and profiler-drift ledger
are populated, a SIGKILLed worker's in-flight messages are harvested
back into the master with at-least-once accounting, no child processes
outlive a run, and the scenario engine routes/validates the new backend.
Cross-backend *scheduling* parity lives in test_backend_parity.py.
"""

import multiprocessing as mp

import pytest

from repro.core.sim import SimConfig
from repro.core.workloads import usecase_workload
from repro.runtime import (
    InProcTransport,
    MultiprocTransport,
    RuntimeConfig,
    make_transport,
    run_live,
)
from repro.scenarios.engine import run_scenario
from repro.scenarios.registry import get_scenario

FAST = RuntimeConfig(time_scale=0.01, transport="multiproc")


def _small_stream(seed=0, n=24):
    return usecase_workload(seed=seed, n_images=n, duration_range=(4.0, 8.0))


# ---------------------------------------------------------------------------
# Transport registry / construction
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_make_transport_resolves_names():
    assert isinstance(make_transport("inproc"), InProcTransport)
    assert isinstance(make_transport("multiproc"), MultiprocTransport)
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("carrier-pigeon")


@pytest.mark.timeout(30)
def test_transports_share_the_stats_interface():
    for tr in (InProcTransport(), MultiprocTransport()):
        s = tr.stats()
        assert s["transport"] in ("inproc", "multiproc")


# ---------------------------------------------------------------------------
# End-to-end over the process boundary
# ---------------------------------------------------------------------------


@pytest.mark.timeout(180)
def test_multiproc_stream_completes_and_counts_bytes():
    stats = {}
    res = run_live(_small_stream(), SimConfig(), runtime=FAST, stats=stats)
    assert res.completed == res.total == 24
    assert res.requeued == 0
    t = stats["transport"]
    assert t["transport"] == "multiproc"
    assert t["workers_spawned"] >= 1
    # every message crossed the wire twice (out as work, in as completion)
    assert t["data_msgs_out"] == 24
    assert t["data_msgs_in"] == 24
    assert t["data_bytes_out"] > 0 and t["data_bytes_in"] > 0
    assert t["ser_bytes_per_msg"] > 0
    assert t["ser_ms_per_msg"] >= 0.0


@pytest.mark.timeout(180)
def test_multiproc_reports_real_cpu_and_drift():
    """The drift ledger is the point of having real processes: emulated
    model CPU vs. measured thread CPU, per message, surfaced as a stat.
    Sleep payloads burn ~no CPU, so real << emulated and the drift is
    large and positive — exactly what the ledger should expose."""
    stats = {}
    res = run_live(_small_stream(), SimConfig(), runtime=FAST, stats=stats)
    assert res.completed == res.total
    t = stats["transport"]
    assert t["measurement"] == "emulated"
    assert t["emulated_cpu_core_s"] > 0.0
    assert 0.0 <= t["real_cpu_core_s"] < t["emulated_cpu_core_s"]
    assert t["profiler_drift_pp"] > 0.0
    # whole-process CPU (os.times deltas) was actually sampled
    assert t["proc_cpu_s"] >= 0.0


@pytest.mark.timeout(180)
def test_multiproc_os_measurement_mode_completes():
    """measurement="os" feeds the real per-message CPU samples to the
    (unmodified) profiler instead of the emulated draws.  With sleep
    payloads the learned sizes collapse toward zero — packing gets
    denser, but the stream must still fully complete (the FIFO handoff
    does not depend on the profiler being right)."""
    rt = RuntimeConfig(time_scale=0.01, transport="multiproc",
                       measurement="os")
    stats = {}
    res = run_live(_small_stream(), SimConfig(), runtime=rt, stats=stats)
    assert res.completed == res.total
    assert stats["transport"]["measurement"] == "os"


@pytest.mark.timeout(60)
def test_os_measurement_requires_multiproc():
    rt = RuntimeConfig(time_scale=0.01, transport="inproc", measurement="os")
    with pytest.raises(ValueError, match="measurement"):
        run_live(_small_stream(n=4), SimConfig(), runtime=rt)


# ---------------------------------------------------------------------------
# Fault path: SIGKILL + harvest keeps at-least-once accounting
# ---------------------------------------------------------------------------


@pytest.mark.timeout(240)
def test_multiproc_kill_harvests_and_requeues():
    """Kill worker 0 mid-run: the parent SIGKILLs the OS process, drains
    whatever completions were already flushed into the data queue, and
    requeues the still-in-flight originals at the master's head.  The
    stream must still complete in full, with the requeue count recorded
    in the SimResult (the fault-parity suite compares it across
    backends; here we pin that the multiproc path produces it at all)."""
    cfg = SimConfig(fail_worker_at=(0, 20.5))
    rt = RuntimeConfig(time_scale=0.05, transport="multiproc")
    stream = usecase_workload(seed=0, n_images=40,
                              duration_range=(4.0, 8.0))
    res = run_live(stream, cfg, runtime=rt)
    assert res.completed == res.total == 40
    assert res.requeued > 0


@pytest.mark.timeout(240)
def test_multiproc_no_orphan_processes_after_runs():
    """Neither a clean drain nor a mid-run SIGKILL may leak children."""
    run_live(_small_stream(), SimConfig(), runtime=FAST)
    assert mp.active_children() == []
    cfg = SimConfig(fail_worker_at=(0, 20.5))
    rt = RuntimeConfig(time_scale=0.05, transport="multiproc")
    run_live(usecase_workload(seed=0, n_images=40,
                              duration_range=(4.0, 8.0)), cfg, runtime=rt)
    assert mp.active_children() == []


# ---------------------------------------------------------------------------
# Scenario-engine routing
# ---------------------------------------------------------------------------


@pytest.mark.timeout(180)
def test_engine_routes_multiproc_backend():
    scn = get_scenario("microscopy")
    result = run_scenario(
        "microscopy", policy="first-fit", n_runs=1,
        stream_overrides=scn.smoke_overrides, t_max=scn.smoke_t_max,
        backend="multiproc", runtime=RuntimeConfig(time_scale=0.01),
    )
    assert result.backend == "multiproc"
    assert result.summary["completed"] == result.summary["total"]


@pytest.mark.timeout(60)
def test_engine_rejects_unsupported_backend_combinations():
    with pytest.raises(ValueError, match="unknown backend"):
        run_scenario("microscopy", backend="teleport")
    with pytest.raises(ValueError, match="runtime config"):
        run_scenario("microscopy", backend="sim",
                     runtime=RuntimeConfig(time_scale=0.01))


@pytest.mark.timeout(60)
def test_engine_honors_scenario_backend_allowlist():
    import dataclasses

    scn = get_scenario("microscopy")
    sim_only = dataclasses.replace(scn, name="sim-only-probe",
                                   backends=("sim",))
    with pytest.raises(ValueError, match="does not support backend"):
        run_scenario(sim_only, backend="multiproc", n_runs=1,
                     stream_overrides=scn.smoke_overrides,
                     t_max=scn.smoke_t_max)
