"""Equivalence suite for the indexed simulation hot path.

The refactored ``repro.core.sim`` (per-image FIFO deques, PE event indices,
preallocated recording buffers) must reproduce the frozen pre-refactor
implementation ``repro.core.sim_reference`` tick-for-tick, bit-for-bit:
same seeds, same RNG draw order, same float-summation order.  These tests
pin that contract on every registered scenario, across profiler-persisting
multi-run experiments, and under fault injection — plus a property test
that per-image deque pulling matches the old global-FIFO scan order on
random multi-image queues.
"""

import dataclasses
from collections import deque

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import IRM, IRMConfig, SimConfig, simulate
from repro.core.sim_reference import simulate_reference
from repro.core.workloads import usecase_workload
from repro.scenarios import get_scenario, scenario_names

ARRAY_FIELDS = ("times", "measured_cpu", "scheduled_cpu", "queue_len",
                "active_workers", "target_workers", "ideal_bins", "pe_count")


def assert_results_identical(a, b, label=""):
    for f in ARRAY_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        assert x.dtype == y.dtype, f"{label}{f}: dtype {x.dtype} != {y.dtype}"
        np.testing.assert_array_equal(x, y, err_msg=f"{label}{f}")
    assert a.completed == b.completed
    assert a.total == b.total
    assert a.makespan == b.makespan


def _smoke_cfg(scn):
    cfg = scn.sim_config()
    if scn.smoke_t_max is not None:
        cfg = dataclasses.replace(cfg, t_max=scn.smoke_t_max)
    return cfg, (scn.smoke_overrides or {})


# ---------------------------------------------------------------------------
# Every registered scenario: indexed sim == reference sim, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", scenario_names())
def test_indexed_sim_matches_reference(name):
    scn = get_scenario(name)
    cfg, overrides = _smoke_cfg(scn)
    a = simulate(scn.make_stream(0, **overrides), cfg)
    b = simulate_reference(scn.make_stream(0, **overrides), cfg)
    assert a.total > 0 and a.completed == a.total
    assert_results_identical(a, b, label=f"{name}: ")


def test_multi_run_profiler_persistence_matches_reference():
    """The paper's repeated-run experiment: one IRM, profiler kept across
    runs — both sims must evolve the shared profiler state identically."""
    scn = get_scenario("microscopy")
    cfg, overrides = _smoke_cfg(scn)
    irm_a, irm_b = IRM(IRMConfig()), IRM(IRMConfig())
    for i in range(3):
        a = simulate(scn.make_stream(i, **overrides), cfg, irm=irm_a)
        b = simulate_reference(scn.make_stream(i, **overrides), cfg, irm=irm_b)
        assert_results_identical(a, b, label=f"run{i}: ")


def test_fault_injection_matches_reference():
    """Worker failure requeues in-flight messages at the queue head; the
    per-image deques must reproduce the reference's insert(0) ordering."""
    cfg = SimConfig(
        dt=0.5, cores_per_worker=4, max_workers=5, worker_boot_delay=5.0,
        pe_start_delay=1.0, container_idle_timeout=1.0, t_max=600.0, seed=0,
        fail_worker_at=(0, 25.0),
    )
    kw = dict(n_images=40, duration_range=(4.0, 8.0))
    a = simulate(usecase_workload(seed=0, **kw), cfg)
    b = simulate_reference(usecase_workload(seed=0, **kw), cfg)
    assert a.completed == a.total  # at-least-once: nothing lost
    assert_results_identical(a, b, label="fault: ")


# ---------------------------------------------------------------------------
# Property: per-image deque pulling == global-FIFO scan order
# ---------------------------------------------------------------------------


def _scan_pull(queue, image):
    """The reference P2P pull: first matching message, list.pop(i)."""
    for i, m in enumerate(queue):
        if m[0] == image:
            return queue.pop(i)
    return None


class _DequeQueue:
    """The indexed master queue: per-image FIFOs keyed by global seq."""

    def __init__(self):
        self.by_image = {}
        self.back = 0
        self.front = 0

    def push_back(self, msg):
        self.back += 1
        self.by_image.setdefault(msg[0], deque()).append((self.back, msg))

    def push_front(self, msg):
        self.front -= 1
        self.by_image.setdefault(msg[0], deque()).appendleft((self.front, msg))

    def pull(self, image):
        dq = self.by_image.get(image)
        if dq:
            return dq.popleft()[1]
        return None


def _run_trace(trace):
    """Drive both queue implementations through one interleaved op trace.

    ``trace`` is a list of ("arrive" | "fail" | "pull", image) ops; messages
    are (image, id) tuples.  Returns both pull sequences.
    """
    scan_q, deque_q = [], _DequeQueue()
    scan_out, deque_out = [], []
    pulled = []
    next_id = 0
    for op, image in trace:
        if op == "arrive":
            msg = (image, next_id)
            next_id += 1
            scan_q.append(msg)
            deque_q.push_back(msg)
        elif op == "fail" and pulled:
            # a failed worker re-inserts an in-flight message at the head
            msg = pulled.pop(0)
            scan_q.insert(0, msg)
            deque_q.push_front(msg)
        elif op == "pull":
            a = _scan_pull(scan_q, image)
            b = deque_q.pull(image)
            scan_out.append(a)
            deque_out.append(b)
            if a is not None:
                pulled.append(a)
    return scan_out, deque_out


@given(
    st.lists(
        st.tuples(
            st.sampled_from(["arrive", "arrive", "pull", "fail"]),
            st.sampled_from(["img-a", "img-b", "img-c", "img-d"]),
        ),
        min_size=1,
        max_size=200,
    )
)
@settings(max_examples=200, deadline=None)
def test_deque_pull_matches_global_fifo_scan(trace):
    scan_out, deque_out = _run_trace(trace)
    assert scan_out == deque_out


def test_deque_pull_matches_scan_seeded():
    """Deterministic version of the property (runs without hypothesis)."""
    rng = np.random.default_rng(1234)
    images = ["img-a", "img-b", "img-c", "img-d", "img-e"]
    for _ in range(50):
        ops = rng.choice(["arrive", "arrive", "pull", "fail"], size=300)
        imgs = rng.choice(images, size=300)
        scan_out, deque_out = _run_trace(list(zip(ops, imgs, strict=True)))
        assert scan_out == deque_out


def test_front_reinsert_order_is_lifo_of_insertions():
    """insert(0) twice means the second message is pulled first — the
    deque queue's decreasing negative sequence numbers must agree."""
    trace = [
        ("arrive", "img-a"), ("arrive", "img-a"),
        ("pull", "img-a"), ("pull", "img-a"),   # both in flight
        ("fail", ""), ("fail", ""),             # requeue msg0 then msg1
        ("pull", "img-a"), ("pull", "img-a"),
    ]
    scan_out, deque_out = _run_trace(trace)
    assert scan_out == deque_out
    # after the two front-inserts, msg1 (inserted last) is at the head
    assert [m[1] for m in scan_out] == [0, 1, 1, 0]
