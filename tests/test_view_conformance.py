"""ClusterView protocol conformance across all three backends.

``repro.core.view_conformance.verify_cluster_view`` is the executable
contract for the IRM's cluster seam; here it runs against the simulator's
``SimCluster``, the live runtime's ``LiveCluster``, and the serving
engine's ``ServingClusterView`` — in cold and mid-workload states — plus
the degraded cases the protocol explicitly tolerates (a view without the
optional ``backlog_resource_demand``) and rejects (missing required
methods, malformed returns).
"""

import asyncio

import pytest

from repro.core.irm import IRM, IRMConfig
from repro.core.resources import Resources
from repro.core.sim import SimCluster, SimConfig
from repro.core.view_conformance import verify_cluster_view
from repro.scenarios.registry import get_scenario
from repro.scenarios.streams import Message
from repro.serving.engine import EngineConfig, Request, ServingEngine


def _make_live_cluster(cfg: SimConfig, irm: IRM):
    from repro.runtime.clock import ScaledClock
    from repro.runtime.lifecycle import Lifecycle
    from repro.runtime.live import LiveCluster
    from repro.runtime.master import Master
    from repro.runtime.payloads import SleepPayload
    from repro.runtime.worker import WorkerPool

    clock = ScaledClock(0.005)
    master = Master()
    pool = WorkerPool(cfg, master, clock, SleepPayload(), poll_interval=0.5)
    lifecycle = Lifecycle(pool, cfg, clock)
    return LiveCluster(cfg, irm, master, pool, lifecycle), master, clock


def test_sim_cluster_conforms_cold_and_loaded():
    cluster = SimCluster(SimConfig(), IRM(IRMConfig()))
    assert verify_cluster_view(cluster) == []
    cluster._push_back(Message(image="a", duration=5.0))
    cluster._push_back(Message(image="b", duration=5.0))
    cluster.scale_workers(2)
    assert verify_cluster_view(cluster) == []


def test_sim_cluster_conforms_vector_mode():
    cfg = SimConfig(resource_dims=("cpu", "mem"))
    cluster = SimCluster(cfg, IRM(IRMConfig()))
    cluster._push_back(
        Message(image="a", duration=5.0, resources={"mem": 0.3})
    )
    assert verify_cluster_view(cluster) == []
    assert isinstance(cluster.backlog_resource_demand(), Resources)


@pytest.mark.timeout(30)
def test_live_cluster_conforms_cold_and_loaded():
    async def go():
        irm = IRM(IRMConfig())
        cluster, master, clock = _make_live_cluster(SimConfig(), irm)
        clock.start()
        assert verify_cluster_view(cluster) == []
        master.push_back(Message(image="a", duration=5.0))
        cluster.scale_workers(2)
        assert verify_cluster_view(cluster) == []
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_live_cluster_conforms_vector_mode():
    async def go():
        cfg = SimConfig(resource_dims=("cpu", "mem"))
        irm = IRM(IRMConfig())
        cluster, master, clock = _make_live_cluster(cfg, irm)
        clock.start()
        master.push_back(
            Message(image="a", duration=5.0, resources={"mem": 0.3})
        )
        assert verify_cluster_view(cluster) == []
        assert isinstance(cluster.backlog_resource_demand(), Resources)
        return True

    assert asyncio.run(go())


def test_serving_view_conforms_cold_and_loaded():
    eng = ServingEngine(EngineConfig())
    view = eng.cluster_view()
    assert verify_cluster_view(view) == []
    eng.submit(Request(prompt_len=64, max_new_tokens=32, req_class="a"))
    eng.submit(Request(prompt_len=64, max_new_tokens=32, req_class="b"))
    assert verify_cluster_view(view) == []
    assert isinstance(view.backlog_resource_demand(), Resources)


def test_serving_view_actuators_admit_and_scale():
    """The adapter's actuators drive the real engine."""
    from repro.core.queues import HostRequest

    eng = ServingEngine(EngineConfig())
    view = eng.cluster_view()
    eng.submit(Request(prompt_len=64, max_new_tokens=32, req_class="a"))
    view.scale_workers(2)
    assert eng._target == 2
    assert view.try_start_pe(
        HostRequest(image="a", size_estimate=0.1, target_worker=0)
    )
    assert not eng.queue  # the queued request was admitted
    # no matching class queued -> placement fails (TTL-requeue path)
    assert not view.try_start_pe(
        HostRequest(image="zzz", size_estimate=0.1, target_worker=0)
    )


def test_view_without_optional_method_is_tolerated():
    """backlog_resource_demand is optional — both for the checker and for
    a real IRM step."""

    class MinimalView:
        def __init__(self):
            self.scaled_to = None

        def queue_length(self):
            return 3.0

        def queue_image_mix(self):
            return {"img": 1.0}

        def worker_scheduled_loads(self):
            return [0.5, 0.0]

        def try_start_pe(self, req):
            return True

        def scale_workers(self, target):
            self.scaled_to = target

    view = MinimalView()
    assert verify_cluster_view(view) == []
    irm = IRM(IRMConfig())
    for i in range(20):
        irm.step(float(i), view)
    assert view.scaled_to is not None  # the IRM ran fine without the signal


def test_checker_flags_missing_and_malformed_views():
    class MissingActuators:
        def queue_length(self):
            return 0.0

        def queue_image_mix(self):
            return {}

        def worker_scheduled_loads(self):
            return []

    problems = verify_cluster_view(MissingActuators())
    assert any("try_start_pe" in p for p in problems)
    assert any("scale_workers" in p for p in problems)

    class Malformed:
        def queue_length(self):
            return -1.0

        def queue_image_mix(self):
            return {"a": 0.4, "b": 0.4}  # doesn't sum to 1

        def worker_scheduled_loads(self):
            return ["not-a-load"]

        def try_start_pe(self, req):
            return False

        def scale_workers(self, target):
            pass

        def backlog_resource_demand(self):
            return 42  # neither None nor Resources

    problems = verify_cluster_view(Malformed())
    assert any("non-negative" in p for p in problems)
    assert any("sum to 1" in p for p in problems)
    assert any("float or Resources" in p for p in problems)
    assert any("backlog_resource_demand" in p for p in problems)


@pytest.mark.timeout(60)
def test_registered_scenarios_views_conform_mid_run():
    """Both sim backends stay conformant in the middle of a real workload."""
    from repro.core.sim import simulate

    scn = get_scenario("synthetic")
    cfg = scn.sim_config()
    cfg.t_max = 30.0  # stop mid-stream

    checked = []
    orig_step = IRM.step

    def checking_step(self, t, view):
        if len(checked) < 5:
            problems = verify_cluster_view(view)
            assert problems == [], problems
            checked.append(t)
        return orig_step(self, t, view)

    IRM.step = checking_step
    try:
        simulate(scn.make_stream(0, **scn.smoke_overrides), cfg)
    finally:
        IRM.step = orig_step
    assert checked
