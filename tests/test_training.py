"""Training substrate tests: optimizer, microbatching, gradient compression,
checkpointing, and the fault-tolerant controller."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.distributed import GradCompressor
from repro.models import build_model, init_params, make_batch
from repro.training import (
    OptimizerConfig,
    init_opt_state,
    lr_at,
    make_train_step,
)
from repro.training.controller import TrainController, TrainControllerConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params


def batches(cfg, n, B=2, S=64):
    for i in range(n):
        yield make_batch(cfg, "train", B, S, seed=i)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_lr_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=1e-2)
    assert lrs[-1] == pytest.approx(1e-4, rel=5e-2)  # min_lr floor
    # warmup is monotone increasing
    warm = [float(lr_at(cfg, jnp.asarray(s))) for s in range(11)]
    assert all(b >= a for a, b in zip(warm, warm[1:], strict=False))


def test_loss_decreases_over_steps(tiny):
    cfg, model, params = tiny
    step = jax.jit(make_train_step(model, OptimizerConfig(
        learning_rate=3e-3, warmup_steps=2, decay_steps=50)))
    opt = init_opt_state(params)
    fixed = make_batch(cfg, "train", 2, 64, seed=0)
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, fixed)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_grad_clipping_caps_update(tiny):
    cfg, model, params = tiny
    from repro.training.optimizer import adamw_update

    grads = jax.tree.map(lambda p: 100.0 * jnp.ones_like(p), params)
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(params, grads, opt,
                                 OptimizerConfig(grad_clip_norm=1.0))
    assert float(metrics["grad_norm"]) > 1.0  # pre-clip norm reported


def test_microbatching_matches_full_batch(tiny):
    """grad accumulation over 4 microbatches == single-shot batch."""
    cfg, model, params = tiny
    batch = make_batch(cfg, "train", 8, 64, seed=0)
    opt1 = init_opt_state(params)
    opt4 = init_opt_state(params)
    step1 = jax.jit(make_train_step(model, OptimizerConfig(), microbatches=1))
    step4 = jax.jit(make_train_step(model, OptimizerConfig(), microbatches=4))
    p1, _, m1 = step1(params, opt1, batch)
    p4, _, m4 = step4(params, opt4, batch)
    # CE is a mean over tokens; microbatch slices have equal token counts
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4
    )
    assert max(jax.tree.leaves(diffs)) < 5e-3


def test_microbatch_indivisible_raises(tiny):
    cfg, model, params = tiny
    batch = make_batch(cfg, "train", 2, 64, seed=0)
    step = make_train_step(model, OptimizerConfig(), microbatches=3)
    with pytest.raises(ValueError):
        step(params, init_opt_state(params), batch)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------


def test_compressor_bounded_quant_error():
    comp = GradCompressor(stochastic=False)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    deq, err = comp.apply(g, None)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(g["w"] - deq["w"]))) <= scale * 0.5 + 1e-6
    # error feedback state holds exactly the residual
    np.testing.assert_allclose(
        np.asarray(err["w"]), np.asarray(g["w"] - deq["w"]), rtol=1e-6,
        atol=1e-7,
    )


def test_compressor_error_feedback_is_unbiased_over_time():
    """Accumulated dequantized sum tracks the true gradient sum."""
    comp = GradCompressor(stochastic=False)
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32) * 1e-3
    ef = None
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, ef = comp.apply({"w": g_true}, {"w": ef["w"]} if ef else None)
        total = total + deq["w"]
    np.testing.assert_allclose(
        np.asarray(total), np.asarray(50 * g_true), rtol=0.05, atol=1e-4
    )


def test_training_with_compression_converges(tiny):
    cfg, model, params = tiny
    step = jax.jit(make_train_step(
        model, OptimizerConfig(learning_rate=3e-3, warmup_steps=2),
        compressor=GradCompressor(stochastic=False),
    ))
    opt = init_opt_state(params)
    opt["ef"] = None
    fixed = make_batch(cfg, "train", 2, 64, seed=0)
    losses = []
    opt.pop("ef")
    state = dict(opt)
    for _ in range(10):
        params, state, m = step(params, state, fixed)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, model, params = tiny
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(10, {"p": params})
    restored = mgr.restore(10, {"p": params})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["p"]), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path, tiny):
    _, _, params = tiny
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"p": params})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_save(tmp_path, tiny):
    _, _, params = tiny
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(7, {"p": params})
    mgr.wait()
    assert mgr.latest_step() == 7
    restored = mgr.restore(7, {"p": params})
    assert jax.tree.structure(restored) == jax.tree.structure({"p": params})


def test_checkpoint_checksum_detects_corruption(tmp_path, tiny):
    _, _, params = tiny
    mgr = CheckpointManager(str(tmp_path))
    path = mgr.save(1, {"p": params})
    # corrupt one leaf file
    victim = next(f for f in os.listdir(path) if f.endswith(".npy"))
    arr = np.load(os.path.join(path, victim))
    arr_bytes = arr.ravel()
    arr_bytes[0] += 1.0
    np.save(os.path.join(path, victim), arr)
    with pytest.raises(IOError):
        mgr.restore(1, {"p": params})


def test_controller_restarts_after_injected_failure(tmp_path, tiny):
    cfg, model, params = tiny
    step = jax.jit(make_train_step(model, OptimizerConfig(learning_rate=1e-3)))
    ctl = TrainController(step, TrainControllerConfig(
        checkpoint_dir=str(tmp_path), checkpoint_every=3,
        async_checkpoint=False,
    ))
    opt = init_opt_state(params)
    p, o, summary = ctl.run(
        params, opt, batches(cfg, 30), num_steps=10, fail_at=7,
    )
    assert summary["restarts"] == 1
    assert summary["final_step"] == 10
    assert int(o["step"]) >= 9  # restarted from step 6 checkpoint, refinished


def test_controller_cold_start_and_resume(tmp_path, tiny):
    cfg, model, params = tiny
    step = jax.jit(make_train_step(model, OptimizerConfig()))
    cfg_ctl = TrainControllerConfig(
        checkpoint_dir=str(tmp_path), checkpoint_every=5,
        async_checkpoint=False,
    )
    ctl = TrainController(step, cfg_ctl)
    opt = init_opt_state(params)
    p, o, _ = ctl.run(params, opt, batches(cfg, 10), num_steps=5)
    # a new controller (fresh process) resumes from the checkpoint
    ctl2 = TrainController(step, cfg_ctl)
    p2, o2, start = ctl2.init_state(lambda: (params, init_opt_state(params)))
    assert start == 5
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(p)[0]), np.asarray(jax.tree.leaves(p2)[0])
    )
