"""Fast content-hash pin for the frozen reference simulator.

``core/sim_reference.py`` is the pre-refactor simulator the equivalence
suite (``tests/test_sim_equivalence.py``) pins ``repro.core.sim`` against
tick for tick — its entire value is that it never changes.  The full
checker (``python -m repro.analysis``, rule R3) enforces the same pin in
CI; this unit test is the milliseconds-cheap tier-1 tripwire that fails
the plain ``pytest`` run the moment the file is touched, without waiting
for the analysis job.
"""

import hashlib
import json
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
MANIFEST = REPO_ROOT / "src/repro/analysis/frozen_manifest.json"


@pytest.mark.timeout(30)
def test_frozen_reference_hash_matches_manifest():
    manifest = json.loads(MANIFEST.read_text(encoding="utf-8"))
    for entry in manifest["frozen"]:
        target = REPO_ROOT / entry["path"]
        assert target.is_file(), f"frozen file {entry['path']} is missing"
        actual = hashlib.sha256(target.read_bytes()).hexdigest()
        assert actual == entry["sha256"], (
            f"{entry['path']} changed (sha256 {actual} != pinned "
            f"{entry['sha256']}).  This file is the frozen reference the "
            f"tick-for-tick equivalence contract in "
            f"tests/test_sim_equivalence.py measures repro.core.sim "
            f"against; editing it silently moves the goalposts for every "
            f"pinned scenario.  If the change is genuinely intended, "
            f"re-pin the hash in {MANIFEST.relative_to(REPO_ROOT)} in the "
            f"same commit and justify it in the commit message."
        )
