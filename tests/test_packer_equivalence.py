"""Property suite pinning the numpy packing engine to the object packers.

The ``NumpyPacker`` replaces trusted per-bin object code on the fleet-scale
hot path, so every decision it makes must be index-for-index identical to
the object packers — same assignments, same bins opened, and a bitwise-equal
used matrix — for every policy in ``POLICIES``/``VECTOR_POLICIES``, over
randomized item streams, capacities, and pre-filled bins.

The seeded ``numpy.random`` loops below are the always-run pins (>= 200
randomized cases per policy, as the scale work requires); the
hypothesis-driven variants add minimized counterexamples when hypothesis is
installed and skip cleanly via ``_hypothesis_compat`` when it is not.  The
final section runs every registered scenario end to end under
``engine="numpy"`` and asserts the ``SimResult`` time series are
bit-identical to the object run.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.binpack import (
    NUMPY_BIN_THRESHOLD,
    Bin,
    FirstFit,
    Item,
    NumpyPacker,
    VectorBin,
    VectorFirstFit,
    VectorItem,
    make_packer,
)
from repro.scenarios import (
    POLICIES,
    VECTOR_POLICIES,
    get_scenario,
    run_scenario,
    scenario_names,
)

N_CASES = 200  # randomized cases per policy (acceptance floor: 200)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _object_used(packer, ndims):
    """The object packer's bins as an (n, ndims) used matrix."""
    if not packer.bins:
        return np.empty((0, ndims), dtype=np.float64)
    return np.asarray(
        [np.atleast_1d(np.asarray(b.used, dtype=np.float64))
         for b in packer.bins],
        dtype=np.float64,
    )


def _check_scalar_case(policy, cap, prefill, sizes):
    obj = make_packer(
        policy, capacity=cap,
        bins=[Bin(cap, used=float(u)) for u in prefill],
    )
    fast = make_packer(policy, capacity=cap, engine="numpy", used=prefill)
    assert isinstance(fast, NumpyPacker)
    a = [obj.pack_one(Item(float(s))) for s in sizes]
    b = [fast.pack_one(Item(float(s))) for s in sizes]
    assert a == b, f"{policy}: placements diverge"
    np.testing.assert_array_equal(
        _object_used(obj, 1), fast.used_matrix(),
        err_msg=f"{policy}: used matrices diverge",
    )


def _check_vector_case(policy, cap, prefill, sizes, heuristic="first"):
    cap_t = tuple(float(c) for c in cap)
    kw = {"heuristic": heuristic} if policy == "vector-first-fit" else {}
    obj = make_packer(
        policy, capacity=cap_t,
        bins=[VectorBin(cap_t, used=tuple(r)) for r in prefill], **kw,
    )
    fast = make_packer(
        policy, capacity=cap_t, engine="numpy", used=prefill, **kw
    )
    assert isinstance(fast, NumpyPacker)
    items = [VectorItem(tuple(r)) for r in sizes]
    res_obj = obj.pack(items)
    res_fast = fast.pack([VectorItem(tuple(r)) for r in sizes])
    label = f"{policy}/{heuristic}"
    assert res_obj.assignments == res_fast.assignments, (
        f"{label}: placements diverge"
    )
    assert res_obj.opened == res_fast.opened
    np.testing.assert_array_equal(
        _object_used(obj, len(cap_t)), fast.used_matrix(),
        err_msg=f"{label}: used matrices diverge",
    )


def _random_vector_case(rng, ndims):
    cap = rng.uniform(0.4, 1.0, size=ndims)
    prefill = rng.uniform(0.0, 1.0, size=(int(rng.integers(0, 6)), ndims))
    prefill = prefill * cap
    sizes = rng.uniform(0.0, 1.0, size=(int(rng.integers(1, 41)), ndims))
    sizes = sizes * cap
    # keep every item non-zero somewhere (the VectorItem contract) but
    # sprinkle exact zeros into auxiliary dimensions — the degenerate case
    # a feasibility mask gets wrong first
    sizes[:, 0] = np.maximum(sizes[:, 0], 1e-3)
    if ndims > 1:
        zero = rng.random(size=(len(sizes), ndims - 1)) < 0.25
        sizes[:, 1:] = np.where(zero, 0.0, sizes[:, 1:])
    return cap, prefill, sizes


# ---------------------------------------------------------------------------
# Seeded randomized equivalence (always run; >= 200 cases per policy)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_scalar_equivalence_randomized(policy):
    rng = np.random.default_rng(hash(policy) % (2**32))
    for _ in range(N_CASES):
        cap = float(rng.uniform(0.4, 1.0))
        prefill = rng.uniform(0.0, cap, size=int(rng.integers(0, 6)))
        sizes = rng.uniform(1e-3, cap, size=int(rng.integers(1, 41)))
        _check_scalar_case(policy, cap, prefill, sizes)


@pytest.mark.parametrize("policy", VECTOR_POLICIES)
@pytest.mark.parametrize("ndims", [1, 3])
def test_vector_equivalence_randomized(policy, ndims):
    rng = np.random.default_rng((hash(policy) + ndims) % (2**32))
    for _ in range(N_CASES):
        cap, prefill, sizes = _random_vector_case(rng, ndims)
        _check_vector_case(policy, cap, prefill, sizes)


@pytest.mark.parametrize("heuristic", ["dot", "l2"])
def test_vector_first_fit_heuristics_equivalence(heuristic):
    rng = np.random.default_rng(hash(heuristic) % (2**32))
    for _ in range(N_CASES):
        cap, prefill, sizes = _random_vector_case(rng, 3)
        _check_vector_case(
            "vector-first-fit", cap, prefill, sizes, heuristic=heuristic
        )


# ---------------------------------------------------------------------------
# Degenerate cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", VECTOR_POLICIES)
def test_zero_size_auxiliary_dimensions(policy):
    """Items with exact zeros in every dimension but one."""
    cap = (1.0, 1.0, 1.0)
    sizes = [(0.4, 0.0, 0.0), (0.4, 0.0, 0.0), (0.4, 0.0, 0.0),
             (0.001, 0.0, 0.0), (0.9, 0.0, 0.0)]
    _check_vector_case(policy, cap, np.empty((0, 3)), np.asarray(sizes))


@pytest.mark.parametrize("policy", VECTOR_POLICIES)
def test_bin_full_in_one_dimension(policy):
    """A pre-filled bin exactly full in one dimension with slack in the
    others: any item demanding that dimension must skip it on both
    engines; a zero-demand item may still land there."""
    cap = (1.0, 1.0)
    prefill = np.asarray([[0.1, 1.0]])  # mem exactly full
    sizes = np.asarray([[0.2, 0.1], [0.3, 0.0], [0.2, 0.1]])
    _check_vector_case(policy, cap, prefill, sizes)


def test_one_dim_vector_matches_scalar_path():
    """1-D vector packing is the scalar path: identical assignments from
    scalar first-fit and vector-first-fit on both engines."""
    rng = np.random.default_rng(7)
    sizes = rng.uniform(0.05, 1.0, size=50)
    results = []
    for name, engine in [("first-fit", "object"), ("first-fit", "numpy"),
                         ("vector-first-fit", "object"),
                         ("vector-first-fit", "numpy")]:
        p = make_packer(name, capacity=1.0, engine=engine)
        if name == "first-fit":
            results.append([p.pack_one(Item(float(s))) for s in sizes])
        else:
            results.append(
                [p.pack_one(VectorItem((float(s),))) for s in sizes]
            )
    assert results[0] == results[1] == results[2] == results[3]


def test_numpy_oversize_validation_matches_object():
    fast = make_packer("first-fit", capacity=0.5, engine="numpy")
    with pytest.raises(ValueError, match="exceeds bin capacity"):
        fast.pack_one(Item(0.8))
    vfast = make_packer("vector-first-fit", capacity=(0.5, 1.0),
                        engine="numpy")
    with pytest.raises(ValueError, match="exceed bin capacity"):
        vfast.pack_one(VectorItem((0.8, 0.1)))
    ffd = make_packer("vector-ffd", capacity=(0.5, 1.0), engine="numpy")
    with pytest.raises(ValueError, match="exceed bin capacity"):
        ffd.pack([VectorItem((0.8, 0.1))])
    with pytest.raises(TypeError, match="offline"):
        ffd.pack_one(VectorItem((0.1, 0.1)))


# ---------------------------------------------------------------------------
# Hypothesis variants (skip cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------


@given(
    st.lists(st.floats(min_value=0.01, max_value=1.0),
             min_size=1, max_size=60),
    st.sampled_from(POLICIES),
)
@settings(max_examples=100, deadline=None)
def test_scalar_equivalence_hypothesis(sizes, policy):
    _check_scalar_case(policy, 1.0, np.empty(0), np.asarray(sizes))


@given(
    st.lists(
        st.tuples(st.floats(min_value=0.01, max_value=1.0),
                  st.floats(min_value=0.0, max_value=1.0)),
        min_size=1, max_size=60,
    ),
    st.sampled_from(VECTOR_POLICIES),
)
@settings(max_examples=100, deadline=None)
def test_vector_equivalence_hypothesis(pairs, policy):
    _check_vector_case(
        policy, (1.0, 1.0), np.empty((0, 2)), np.asarray(pairs)
    )


# ---------------------------------------------------------------------------
# Factory / engine selection
# ---------------------------------------------------------------------------


def test_engine_numpy_resolves_every_swept_policy():
    for name in (*POLICIES, *VECTOR_POLICIES):
        p = make_packer(name, capacity=1.0, engine="numpy")
        assert isinstance(p, NumpyPacker) and p.name == name


def test_engine_numpy_rejects_unimplemented_policies():
    with pytest.raises(ValueError, match="no numpy engine"):
        make_packer("harmonic", engine="numpy")


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown packing engine"):
        make_packer("first-fit", engine="fortran")


def test_auto_engine_switches_on_prefilled_bin_count():
    small = make_packer("first-fit", engine="auto",
                        used=np.full(NUMPY_BIN_THRESHOLD - 1, 0.1))
    big = make_packer("first-fit", engine="auto",
                      used=np.full(NUMPY_BIN_THRESHOLD, 0.1))
    assert isinstance(small, FirstFit)
    assert isinstance(big, NumpyPacker)
    # the object fallback keeps the used= prefill (bins materialized)
    assert len(small.bins) == NUMPY_BIN_THRESHOLD - 1
    assert small.bins[0].used == pytest.approx(0.1)
    vec = make_packer("vector-first-fit", engine="auto",
                      capacity=(1.0, 1.0), used=np.full((4, 2), 0.2))
    assert isinstance(vec, VectorFirstFit)
    assert vec.bins[0].used == (pytest.approx(0.2), pytest.approx(0.2))


def test_numpy_reset_and_bins_materialization():
    p = make_packer("vector-best-fit", capacity=(1.0, 0.5), engine="numpy",
                    used=np.asarray([[0.3, 0.1]]))
    assert p.n_bins == 1
    bins = p.bins
    assert isinstance(bins[0], VectorBin)
    assert bins[0].used == (pytest.approx(0.3), pytest.approx(0.1))
    p.reset()
    assert p.n_bins == 0 and p.used_matrix().shape == (0, 2)


# ---------------------------------------------------------------------------
# Registered-scenario regression pin: engine="numpy" end to end
# ---------------------------------------------------------------------------

ARRAY_FIELDS = ("times", "measured_cpu", "scheduled_cpu", "queue_len",
                "active_workers", "target_workers", "ideal_bins", "pe_count")


@pytest.mark.parametrize("name", scenario_names())
def test_registered_scenario_numpy_engine_bit_identical(name):
    """The fast engine can become the sim default only if every pinned
    scenario's time series survives the swap bit-for-bit."""
    scn = get_scenario(name)
    kwargs = dict(n_runs=1, stream_overrides=scn.smoke_overrides,
                  t_max=scn.smoke_t_max)
    a = run_scenario(scn, engine="object", **kwargs).final
    b = run_scenario(scn, engine="numpy", **kwargs).final
    assert a.total > 0 and a.completed == a.total
    for f in ARRAY_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        assert x.dtype == y.dtype, f"{name}/{f}: dtype diverges"
        np.testing.assert_array_equal(x, y, err_msg=f"{name}/{f}")
    if a.scheduled_res is not None:
        np.testing.assert_array_equal(a.scheduled_res, b.scheduled_res)
    assert a.makespan == b.makespan
    assert a.requeued == b.requeued
