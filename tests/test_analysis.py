"""The invariant checker checks itself: per-rule fixtures + the real tree.

Two halves:

1. **Fixtures** — for each rule R1–R6, a minimal synthetic repo tree
   (R7/R8 have their own fixture suite in ``test_protocol.py``)
   (written under ``tmp_path`` in the same ``src/repro/...`` layout the
   checker walks) containing exactly one violation, proving the rule
   *fires*.  A checker that silently stops matching would otherwise keep
   returning "clean" forever — these are the checker's regression tests.
2. **The gate** — ``test_real_tree_is_clean`` runs every rule over this
   repository and applies the committed baseline; it is the tier-1
   wrapper of the CI ``analysis`` job, so a new invariant violation fails
   the ordinary test suite even before CI runs the standalone checker.
"""

from pathlib import Path

import pytest

from repro.analysis import (
    DEFAULT_BASELINE_NAME,
    RULES,
    apply_baseline,
    load_baseline,
    run_analysis,
)
from repro.analysis.__main__ import main as analysis_main
from repro.runtime.annotations import loop_only, worker_side

REPO_ROOT = Path(__file__).resolve().parents[1]


def _write_tree(root: Path, files: dict) -> Path:
    for rel, content in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content, encoding="utf-8")
    return root


def _messages(findings, rule):
    return [f.message for f in findings if f.rule == rule]


# ---------------------------------------------------------------------------
# R1 — blocking-in-async
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_r1_fires_on_blocking_call_in_async(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/runtime/mod.py": (
            "import time\n"
            "async def tick():\n"
            "    time.sleep(1.0)\n"
        ),
    })
    found = run_analysis(tmp_path, rules=["R1"])
    msgs = _messages(found, "R1")
    assert len(msgs) == 1
    assert "time.sleep" in msgs[0]
    assert found[0].symbol == "tick"


@pytest.mark.timeout(30)
def test_r1_fires_on_loop_call_into_worker_side(tmp_path):
    """The annotation vocabulary is enforced at call-graph boundaries: an
    edge from loop-reachable code into @worker_side is itself a finding."""
    _write_tree(tmp_path, {
        "src/repro/runtime/mod.py": (
            "from repro.runtime.annotations import worker_side\n"
            "@worker_side\n"
            "def grind():\n"
            "    pass\n"
            "async def tick():\n"
            "    grind()\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R1"]), "R1")
    assert len(msgs) == 1
    assert "@worker_side" in msgs[0] and "grind" in msgs[0]


@pytest.mark.timeout(30)
def test_r1_exempts_annotated_deliberate_stall(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/runtime/mod.py": (
            "import time\n"
            "from repro.runtime.annotations import loop_only\n"
            "@loop_only(blocking='teardown join after the clock stopped')\n"
            "def drain():\n"
            "    time.sleep(0.1)\n"
            "async def tick():\n"
            "    drain()\n"
        ),
    })
    assert _messages(run_analysis(tmp_path, rules=["R1"]), "R1") == []


# ---------------------------------------------------------------------------
# R2 — single-consumer / thread affinity
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_r2_fires_on_unannotated_mirror_mutation(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/runtime/mod.py": (
            "def poke(pe):\n"
            "    pe.state = 2\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R2"]), "R2")
    assert len(msgs) == 1
    assert "pe.state" in msgs[0] and "@loop_only" in msgs[0]


@pytest.mark.timeout(30)
def test_r2_fires_on_second_data_channel_consumer(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/runtime/mod.py": (
            "def steal(data_q):\n"
            "    return data_q.get_nowait()\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R2"]), "R2")
    assert len(msgs) == 1
    assert "single-consumer" in msgs[0]


@pytest.mark.timeout(30)
def test_r2_fires_on_contradictory_annotations(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/runtime/mod.py": (
            "from repro.runtime.annotations import loop_only, worker_side\n"
            "@loop_only\n"
            "@worker_side\n"
            "def confused():\n"
            "    pass\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R2"]), "R2")
    assert any("both @loop_only and @worker_side" in m for m in msgs)


# ---------------------------------------------------------------------------
# R3 — frozen-reference guard
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_r3_fires_on_modified_frozen_file(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/core/sim_reference.py": "# a drive-by edit\n",
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R3"]), "R3")
    assert any("frozen file modified" in m for m in msgs)
    assert any("re-pin the hash" in m for m in msgs)


@pytest.mark.timeout(30)
def test_r3_fires_on_import_outside_allowlist(tmp_path):
    ref = (REPO_ROOT / "src/repro/core/sim_reference.py").read_text()
    found = run_analysis(_write_tree(tmp_path, {
        "src/repro/core/sim_reference.py": ref,  # pinned content: no hash hit
        "src/repro/runtime/sneaky.py": (
            "from repro.core.sim_reference import simulate_reference\n"
        ),
    }), rules=["R3"])
    assert [f.path for f in found] == ["src/repro/runtime/sneaky.py"]
    assert "allowlist" in found[0].message


# ---------------------------------------------------------------------------
# R4 — wire-contract drift
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_r4_fires_on_unregistered_field(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/scenarios/streams.py": (
            "from dataclasses import dataclass\n"
            "@dataclass\n"
            "class Message:\n"
            "    image: str\n"
            "    duration: float\n"
            "    cpu_cores: float\n"
            "    arrival: float\n"
            "    resources: dict\n"
            "    msg_id: int\n"
            "    start_t: float\n"
            "    done_t: float\n"
            "    smuggled: bytes\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R4"]), "R4")
    assert any(
        "drift" in m and "'smuggled'" in m and "wire_manifest.json" in m
        for m in msgs
    )


@pytest.mark.timeout(30)
def test_r4_fires_on_stale_manifest_entry(tmp_path):
    """The inverse direction: a registered field the class no longer has."""
    _write_tree(tmp_path, {
        "src/repro/scenarios/streams.py": (
            "from dataclasses import dataclass\n"
            "@dataclass\n"
            "class Message:\n"
            "    image: str\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R4"]), "R4")
    assert any("stale wire manifest" in m and "duration" in m for m in msgs)


# ---------------------------------------------------------------------------
# R5 — determinism lint
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_r5_fires_on_wall_clock_read(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/core/mod.py": (
            "import time\n"
            "def stamp():\n"
            "    return time.time()\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R5"]), "R5")
    assert len(msgs) == 1
    assert "wall-clock read time.time()" in msgs[0]


@pytest.mark.timeout(30)
def test_r5_fires_on_unseeded_rng_and_set_iteration(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/core/mod.py": (
            "import numpy as np\n"
            "def draw(images):\n"
            "    rng = np.random.default_rng()\n"
            "    for img in set(images):\n"
            "        rng.random()\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R5"]), "R5")
    assert any("unseeded default_rng()" in m for m in msgs)
    assert any("hash-order-dependent" in m for m in msgs)


@pytest.mark.timeout(30)
def test_r5_fires_on_wall_clock_in_runtime_decision_logic(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/runtime/policy.py": (
            "import time\n"
            "def decide():\n"
            "    return time.time()\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R5"]), "R5")
    assert len(msgs) == 1
    assert "ScaledClock" in msgs[0]


@pytest.mark.timeout(30)
def test_r5_exempts_annotated_measurement_sites_not_rng(tmp_path):
    _write_tree(tmp_path, {
        # the sanctioned wall-clock wrapper is allowlisted wholesale
        "src/repro/runtime/clock.py": (
            "import time\n"
            "def now():\n"
            "    return time.perf_counter()\n"
        ),
        # measurement affinity annotations and async drivers are exempt
        "src/repro/runtime/meas.py": (
            "import time\n"
            "from .annotations import loop_only, worker_side\n"
            "@worker_side\n"
            "def grind():\n"
            "    return time.perf_counter()\n"
            "@loop_only\n"
            "def poll():\n"
            "    return time.monotonic()\n"
            "async def drive():\n"
            "    return time.time()\n"
        ),
        # RNG gets no exemption anywhere, even under annotations
        "src/repro/obs/jitterbug.py": (
            "import random\n"
            "from repro.runtime.annotations import worker_side\n"
            "@worker_side\n"
            "def jitter():\n"
            "    return random.random()\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R5"]), "R5")
    assert len(msgs) == 1
    assert "global RNG" in msgs[0]


# ---------------------------------------------------------------------------
# R6 — event-schema manifest
# ---------------------------------------------------------------------------

_R6_MANIFEST = (
    '{"schema_test": "tests/test_obs.py",\n'
    ' "events": {"msg.enqueued": ["msg_id", "image", "arrival"]}}\n'
)
_R6_TEST = 'def test_schema():\n    assert "msg.enqueued"\n'


@pytest.mark.timeout(30)
def test_r6_fires_on_unregistered_event_type(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _R6_MANIFEST,
        "tests/test_obs.py": _R6_TEST,
        "src/repro/runtime/mod.py": (
            "def go(bus, m):\n"
            '    bus.emit("msg.enqueued", msg_id=m.msg_id, image=m.image,\n'
            "             arrival=m.arrival)\n"
            '    bus.emit("msg.mystery", msg_id=m.msg_id)\n'
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R6"]), "R6")
    assert any(
        "'msg.mystery'" in m and "event_manifest.json" in m for m in msgs
    )


@pytest.mark.timeout(30)
def test_r6_fires_on_payload_field_drift(tmp_path):
    """Both directions: an emitted field the manifest lacks, and a
    manifest field the emit site dropped."""
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _R6_MANIFEST,
        "tests/test_obs.py": _R6_TEST,
        "src/repro/runtime/mod.py": (
            "def go(bus, m):\n"
            '    bus.emit("msg.enqueued", msg_id=m.msg_id, image=m.image,\n'
            "             priority=3)\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R6"]), "R6")
    assert any("'priority'" in m and "drift" in m for m in msgs)
    assert any("'arrival'" in m and "full pinned field set" in m for m in msgs)


@pytest.mark.timeout(30)
def test_r6_fires_on_stale_entry_and_unexercised_type(tmp_path):
    # nothing emits msg.enqueued, and the schema test never names it
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _R6_MANIFEST,
        "tests/test_obs.py": "def test_nothing():\n    pass\n",
        "src/repro/runtime/mod.py": "def go(bus):\n    pass\n",
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R6"]), "R6")
    assert any("stale event manifest" in m for m in msgs)
    assert any("never exercised by the schema test" in m for m in msgs)


@pytest.mark.timeout(30)
def test_r6_fires_on_non_literal_event_type(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _R6_MANIFEST,
        "tests/test_obs.py": _R6_TEST,
        "src/repro/runtime/mod.py": (
            "def go(bus, m, ev):\n"
            '    bus.emit("msg.enqueued", msg_id=m.msg_id, image=m.image,\n'
            "             arrival=m.arrival)\n"
            "    bus.emit(ev, msg_id=m.msg_id)\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R6"]), "R6")
    assert any("non-literal event type" in m for m in msgs)


# ---------------------------------------------------------------------------
# Infrastructure: parse findings, baseline semantics, annotations, CLI
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_unparseable_file_is_a_finding_not_a_gap(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/core/broken.py": "def oops(:\n",
    })
    found = run_analysis(tmp_path, rules=["R5"])
    assert [f.rule for f in found] == ["parse"]


@pytest.mark.timeout(30)
def test_baseline_suppresses_by_key_and_reports_stale(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/core/mod.py": (
            "import time\n"
            "def stamp():\n"
            "    return time.time()\n"
        ),
    })
    found = run_analysis(tmp_path, rules=["R5"])
    assert len(found) == 1
    entry = {
        "rule": found[0].rule,
        "path": found[0].path,
        "symbol": found[0].symbol,
        "message": found[0].message,
    }
    active, suppressed, stale = apply_baseline(found, [entry])
    assert active == [] and len(suppressed) == 1 and stale == []
    # a suppression whose finding is gone must surface as stale
    active, suppressed, stale = apply_baseline([], [entry])
    assert stale == [entry]


@pytest.mark.timeout(30)
def test_annotations_are_transparent_identity_decorators():
    @worker_side
    def a():
        return 1

    @loop_only
    def b():
        return 2

    @loop_only(blocking="why")
    def c():
        return 3

    assert (a(), b(), c()) == (1, 2, 3)
    assert a.__worker_side__ and b.__loop_only__ and c.__loop_only__
    assert c.__loop_blocking_reason__ == "why"


# ---------------------------------------------------------------------------
# The gate: the real tree is clean (tier-1 wrapper of the CI analysis job)
# ---------------------------------------------------------------------------


@pytest.mark.timeout(120)
def test_real_tree_is_clean():
    findings = run_analysis(REPO_ROOT)
    suppressions = load_baseline(REPO_ROOT / DEFAULT_BASELINE_NAME)
    active, _, stale = apply_baseline(findings, suppressions)
    details = "\n".join(
        f"{f.path}:{f.line}: [{f.rule}] {f.message}" for f in active
    )
    assert not active, (
        f"invariant violations in the tree (fix them or, as a reviewed "
        f"decision, suppress in {DEFAULT_BASELINE_NAME}):\n{details}"
    )
    assert not stale, f"stale baseline suppressions: {stale}"


@pytest.mark.timeout(120)
def test_cli_exit_codes_and_json_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = analysis_main([
        "--root", str(REPO_ROOT), "--format", "json", "--out", str(out),
    ])
    capsys.readouterr()
    assert rc == 0
    import json

    report = json.loads(out.read_text())
    assert report["ok"] is True
    assert set(report["rules"]) == set(RULES)
    assert report["findings"] == []
    assert analysis_main(["--list-rules"]) == 0
    capsys.readouterr()


@pytest.mark.timeout(120)
def test_json_report_is_repo_relative(tmp_path, capsys):
    """The report must diff cleanly across checkouts: no absolute path
    may appear anywhere in it, and the root is pinned to '.'."""
    import json

    out = tmp_path / "report.json"
    rc = analysis_main([
        "--root", str(REPO_ROOT), "--rules", "R3", "--format", "json",
        "--out", str(out),
    ])
    capsys.readouterr()
    assert rc == 0
    text = out.read_text()
    assert str(REPO_ROOT) not in text
    assert json.loads(text)["root"] == "."


def _git(cwd, *argv):
    import subprocess

    subprocess.run(
        ["git", "-c", "user.email=t@example.com", "-c", "user.name=t",
         *argv],
        cwd=cwd, check=True, capture_output=True,
    )


@pytest.mark.timeout(60)
def test_changed_only_reports_only_changed_files(tmp_path, capsys):
    _write_tree(tmp_path, {
        "src/repro/core/old.py": (
            "import time\n"
            "def a():\n"
            "    return time.time()\n"
        ),
    })
    _git(tmp_path, "init", "-q")
    _git(tmp_path, "add", ".")
    _git(tmp_path, "commit", "-q", "-m", "seed")

    # nothing changed vs HEAD → the committed violation is out of scope
    rc = analysis_main(["--root", str(tmp_path), "--rules", "R5",
                        "--changed-only"])
    capsys.readouterr()
    assert rc == 0

    # an untracked file with a violation is in scope
    _write_tree(tmp_path, {
        "src/repro/core/new.py": (
            "import time\n"
            "def b():\n"
            "    return time.time()\n"
        ),
    })
    rc = analysis_main(["--root", str(tmp_path), "--rules", "R5",
                        "--changed-only"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "new.py" in out and "old.py" not in out


@pytest.mark.timeout(60)
def test_changed_only_outside_a_git_repo_is_a_usage_error(tmp_path, capsys):
    _write_tree(tmp_path, {"src/repro/core/mod.py": "x = 1\n"})
    rc = analysis_main(["--root", str(tmp_path), "--rules", "R5",
                        "--changed-only"])
    capsys.readouterr()
    assert rc == 2
