"""Wire contract: everything the multiproc transport ships must pickle.

``runtime.transport.MultiprocTransport`` moves ``Message`` payloads to
worker OS processes and back through pickled queue frames
(``Transport.serialize`` / ``deserialize``).  These tests pin the
serialization contract for every type that crosses — or could cross — the
process boundary: ``Message`` (including numpy-influenced float fields and
the auxiliary ``resources`` dict), ``Resources`` (a ``__slots__`` class
backed by a float64 ndarray), and ``HostRequest`` (whose
``size_estimate`` may be a ``Resources`` vector).  It also pins the one
*semantic* property serialization must not disturb: the master's
negative-sequence head-requeue ordering, exercised with messages that
have been round-tripped through the wire format.
"""

import asyncio
import pickle

import numpy as np
import pytest

from repro.core.queues import HostRequest
from repro.core.resources import Resources
from repro.core.workloads import Message
from repro.runtime.master import Master
from repro.runtime.transport import InProcTransport, MultiprocTransport


def _roundtrip(obj, transport=None):
    if transport is None:
        return pickle.loads(pickle.dumps(obj, pickle.HIGHEST_PROTOCOL))
    return transport.deserialize(transport.serialize(obj))


# ---------------------------------------------------------------------------
# Message
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_message_roundtrip_scalar():
    m = Message(image="img/a", duration=12.5, cpu_cores=1.25, arrival=3.0)
    m.start_t = 7.5
    r = _roundtrip(m)
    assert r is not m
    assert r.image == m.image
    assert r.duration == m.duration
    assert r.cpu_cores == m.cpu_cores
    assert r.arrival == m.arrival
    assert r.msg_id == m.msg_id
    assert r.start_t == 7.5 and r.done_t == -1.0
    assert r.resources is None


@pytest.mark.timeout(30)
def test_message_roundtrip_numpy_backed_fields():
    """Stream generators fill duration/cpu_cores from numpy RNG draws:
    np.float64 scalars must survive as exact doubles, and an auxiliary
    ``resources`` dict with numpy values must come back equal."""
    rng = np.random.default_rng(0)
    dur = rng.uniform(10.0, 20.0)            # np.float64
    cores = rng.normal(1.0, 0.1)
    m = Message(image="img/np", duration=dur, cpu_cores=cores,
                resources={"mem": float(rng.uniform(0.2, 0.5)),
                           "accel": 0.0})
    r = _roundtrip(m)
    assert float(r.duration) == float(dur)
    assert float(r.cpu_cores) == float(cores)
    assert r.resources == m.resources
    assert set(r.resources) == {"mem", "accel"}


@pytest.mark.timeout(30)
def test_message_roundtrip_via_transport_hooks():
    """Both transports expose the same serialize/deserialize hooks and the
    multiproc one accounts for them; the blob is plain pickle either way."""
    m = Message(image="img/hook", duration=5.0)
    for tr in (InProcTransport(), MultiprocTransport()):
        r = _roundtrip(m, transport=tr)
        assert r.msg_id == m.msg_id and r.image == m.image


# ---------------------------------------------------------------------------
# Resources
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_resources_roundtrip_preserves_dims_dtype_values():
    res = Resources(("cpu", "mem", "accel"), (0.25, 0.5, 0.0))
    r = _roundtrip(res)
    assert r.dims == ("cpu", "mem", "accel")
    assert r.values.dtype == np.float64
    assert r.values.shape == (3,)
    np.testing.assert_array_equal(r.values, res.values)
    # the copy is independent: value semantics survive the boundary
    assert r.values is not res.values


@pytest.mark.timeout(30)
def test_resources_roundtrip_arithmetic_identity():
    """Exact IEEE-754 doubles: packing math on a round-tripped vector must
    be bit-identical to packing math on the original (the profiler and
    allocator never see 'almost' the same estimate after a hop)."""
    a = Resources(("cpu", "mem"), (1.0 / 3.0, 0.7))
    b = _roundtrip(a)
    assert (a + b).values.tolist() == (a + a).values.tolist()
    assert _roundtrip(Resources.cpu(0.125)).get("cpu") == 0.125


# ---------------------------------------------------------------------------
# HostRequest
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_host_request_roundtrip_scalar_estimate():
    req = HostRequest(image="img/a", size_estimate=0.4, ttl=2,
                      target_worker=3, meta={"k": 1})
    r = _roundtrip(req)
    assert (r.image, r.size_estimate, r.ttl, r.target_worker) == \
        ("img/a", 0.4, 2, 3)
    assert r.req_id == req.req_id
    assert r.meta == {"k": 1}


@pytest.mark.timeout(30)
def test_host_request_roundtrip_queueing_fields():
    """``enqueue_time`` (admission stamp) and ``source`` ("autoscale" |
    "user" provenance) must survive the hop so a request that bounces off
    a still-booting worker keeps its original admission time and origin
    through the TTL-requeue loop."""
    req = HostRequest(image="img/t", size_estimate=0.2, ttl=1,
                      enqueue_time=42.5, source="user")
    r = _roundtrip(req)
    assert r.enqueue_time == 42.5
    assert r.source == "user"
    # defaults survive too: a fresh request round-trips as fresh
    fresh = _roundtrip(HostRequest(image="img/t", size_estimate=0.2))
    assert fresh.enqueue_time == 0.0
    assert fresh.source == "autoscale"


@pytest.mark.timeout(30)
def test_host_request_roundtrip_vector_estimate():
    est = Resources(("cpu", "mem"), (0.3, 0.45))
    req = HostRequest(image="img/v", size_estimate=est)
    r = _roundtrip(req)
    assert isinstance(r.size_estimate, Resources)
    assert r.size_estimate.dims == est.dims
    np.testing.assert_array_equal(r.size_estimate.values, est.values)


# ---------------------------------------------------------------------------
# Negative-seq requeue ordering across the wire
# ---------------------------------------------------------------------------


def _drain_image(master, image):
    out = []
    while True:
        m = master.pull(image)
        if m is None:
            return out
        out.append(m)


@pytest.mark.timeout(30)
def test_requeue_ordering_survives_serialization():
    """A failed worker's in-flight messages come back through the data
    queue as pickled frames, then re-enter the master at the *head*
    (negative seqs).  Whatever serialization did to the objects, the pull
    order must be: head re-inserts in reverse harvest order (insert(0, m)
    semantics), then the untouched FIFO tail."""

    async def scenario():
        master = Master(total_expected=6)
        originals = [Message(image="img/a", duration=float(i), arrival=0.0)
                     for i in range(6)]
        for m in originals:
            master.push_back(m)
        # two PEs pull the global head pair; the master now tracks them
        a = master.pull("img/a")
        b = master.pull("img/a")
        assert (a.duration, b.duration) == (0.0, 1.0)
        # the worker dies: the harvest crosses the wire as pickle frames
        harvest = [pickle.loads(pickle.dumps(m, pickle.HIGHEST_PROTOCOL))
                   for m in (a, b)]
        for m in harvest:
            master.requeue(m)
        assert master.requeued == 2
        order = [m.duration for m in _drain_image(master, "img/a")]
        # reverse harvest order at the head (b then a reversed → a, b? no:
        # appendleft(a) then appendleft(b) ⇒ b is the new global head)
        assert order == [1.0, 0.0, 2.0, 3.0, 4.0, 5.0]

    asyncio.run(scenario())


@pytest.mark.timeout(30)
def test_requeue_seq_numbers_stay_negative_and_decreasing():
    """The head re-insert contract the backlog observers rely on: each
    requeue takes the next *decreasing* negative sequence number even when
    the message object is a deserialized copy."""

    async def scenario():
        master = Master(total_expected=3)
        for i in range(3):
            master.push_back(Message(image="x", duration=float(i)))
        pulled = [master.pull("x") for _ in range(3)]
        for m in pulled:
            master.requeue(_roundtrip(m))
        dq = master._img_queues["x"]
        seqs = [s for s, _ in dq]
        assert seqs == [-3, -2, -1]
        assert all(s < 0 for s in seqs)
        # requeue cleared the start stamps (at-least-once restart)
        assert all(m.start_t == -1.0 for _, m in dq)

    asyncio.run(scenario())
