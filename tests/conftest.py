"""Shared test fixtures.

NOTE: no ``XLA_FLAGS`` manipulation here — smoke tests and benchmarks must
see the real single CPU device; only ``launch/dryrun.py`` (run as its own
process) forces 512 host devices.
"""

import signal

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# @pytest.mark.timeout(seconds): wall-clock budget for a single test.
#
# The live-runtime suites (test_runtime, test_backend_parity) drive a real
# asyncio event loop; a deadlocked await would otherwise hang the whole CI
# job until the job-level timeout.  The marker arms a SIGALRM-based
# interval timer around the test call so a stuck test fails in seconds
# with a clear message instead.  Implemented here because the environment
# pins its dependency set (no pytest-timeout plugin); the marker name and
# semantics match that plugin's method="signal" mode, and this hook steps
# aside if the real plugin is ever installed.
# ---------------------------------------------------------------------------


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    pm = item.config.pluginmanager
    if (
        marker is None
        or not hasattr(signal, "SIGALRM")
        # pytest-timeout registers as "timeout" (entry point) — probe both
        # names so this hook steps aside whenever the real plugin is present
        or pm.hasplugin("timeout")
        or pm.hasplugin("pytest_timeout")
    ):
        yield
        return
    seconds = float(marker.args[0]) if marker.args else 60.0

    def _on_timeout(signum, frame):
        raise TimeoutError(
            f"test exceeded its {seconds:g}s wall-clock budget "
            "(@pytest.mark.timeout) — likely a deadlocked runtime await"
        )

    old_handler = signal.signal(signal.SIGALRM, _on_timeout)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old_handler)


# ---------------------------------------------------------------------------
# Child-process reaping: the multiproc transport runs workers as OS
# processes.  A test that fails (or trips the SIGALRM watchdog above)
# mid-run can leave daemonized worker children behind; a later test —
# or the pytest process itself at exit — would then hang on queue feeder
# threads or inherit stale children.  Reap after every test, and once
# more at session teardown, so one broken run can never poison the rest
# of the suite.
# ---------------------------------------------------------------------------


def _reap_children(grace_s: float = 2.0) -> int:
    """SIGKILL + join any live multiprocessing children; returns count."""
    import multiprocessing as mp

    children = mp.active_children()
    for proc in children:
        try:
            proc.kill()
        except (OSError, ValueError, AttributeError):
            pass
    for proc in children:
        try:
            proc.join(grace_s)
        except (OSError, ValueError, AssertionError):
            pass
    return len(children)


@pytest.fixture(autouse=True)
def _reap_stray_worker_processes():
    """Per-test guard: no test may leak worker processes to the next one.

    Runs the reap in teardown regardless of pass/fail, so a test that
    raised (including via the timeout watchdog) while a multiproc
    transport was live still gets its children collected.
    """
    yield
    _reap_children()


@pytest.fixture(scope="session", autouse=True)
def _reap_worker_processes_at_exit():
    """Session backstop: whatever survived per-test reaping dies here."""
    yield
    reaped = _reap_children()
    if reaped:
        import sys

        print(f"\n[conftest] reaped {reaped} stray worker process(es) "
              "at session teardown", file=sys.stderr)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
