"""Shared test fixtures.

NOTE: no ``XLA_FLAGS`` manipulation here — smoke tests and benchmarks must
see the real single CPU device; only ``launch/dryrun.py`` (run as its own
process) forces 512 host devices.
"""

import signal

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# @pytest.mark.timeout(seconds): wall-clock budget for a single test.
#
# The live-runtime suites (test_runtime, test_backend_parity) drive a real
# asyncio event loop; a deadlocked await would otherwise hang the whole CI
# job until the job-level timeout.  The marker arms a SIGALRM-based
# interval timer around the test call so a stuck test fails in seconds
# with a clear message instead.  Implemented here because the environment
# pins its dependency set (no pytest-timeout plugin); the marker name and
# semantics match that plugin's method="signal" mode, and this hook steps
# aside if the real plugin is ever installed.
# ---------------------------------------------------------------------------


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    pm = item.config.pluginmanager
    if (
        marker is None
        or not hasattr(signal, "SIGALRM")
        # pytest-timeout registers as "timeout" (entry point) — probe both
        # names so this hook steps aside whenever the real plugin is present
        or pm.hasplugin("timeout")
        or pm.hasplugin("pytest_timeout")
    ):
        yield
        return
    seconds = float(marker.args[0]) if marker.args else 60.0

    def _on_timeout(signum, frame):
        raise TimeoutError(
            f"test exceeded its {seconds:g}s wall-clock budget "
            "(@pytest.mark.timeout) — likely a deadlocked runtime await"
        )

    old_handler = signal.signal(signal.SIGALRM, _on_timeout)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old_handler)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
