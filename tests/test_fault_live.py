"""At-least-once under churn: random worker kills on the live backend.

The cross-backend fault-parity test pins one curated kill; this suite
stresses the property the paper actually claims (V-B.2): *whenever* a
worker dies, its in-flight messages re-enter the queue head and the
stream still completes — nothing lost, nothing duplicated.  Kill times
and victims are drawn from a seeded RNG over the window where the
microscopy pool is busiest, so every CI run replays the same draws while
the schedule underneath stays genuinely concurrent.

Loss would show up as ``completed < total`` (the drain never fires and
the run ends at ``t_max`` short of the stream); duplication as
``completed > total`` or a completion recorded for a message the master
also still holds.  Both are asserted per run.  Every test carries the
SIGALRM watchdog marker so a kill-induced deadlock fails in seconds.
"""

import dataclasses

import numpy as np
import pytest

from repro.runtime import RuntimeConfig, run_live
from repro.scenarios.registry import get_scenario

FAST = RuntimeConfig(time_scale=0.005)


def _run_with_kill(worker_idx: int, kill_t: float):
    scn = get_scenario("microscopy")
    cfg = dataclasses.replace(
        scn.sim_config(),
        t_max=scn.smoke_t_max,
        fail_worker_at=(worker_idx, float(kill_t)),
    )
    stream = scn.make_stream(0, **scn.smoke_overrides)
    res = run_live(stream, cfg, runtime=FAST)
    return res


@pytest.mark.timeout(300)
def test_random_kill_times_never_lose_or_duplicate_messages():
    rng = np.random.default_rng(11)
    for trial in range(4):
        kill_t = float(rng.uniform(15.0, 55.0))
        worker_idx = int(rng.integers(0, 2))
        res = _run_with_kill(worker_idx, kill_t)
        label = f"trial {trial}: kill worker {worker_idx} @ {kill_t:.1f}s"
        # exactly-total completions: < total is loss, > total is a
        # duplicate completion slipping past the drain accounting
        assert res.completed == res.total, label
        # every stream message really finished (bijective completion)
        assert all(m.done_t >= 0.0 for m in res.messages), label
        # a processed-then-requeued message keeps only its final stamps
        assert all(m.done_t > m.start_t >= 0.0 for m in res.messages), label
        assert res.requeued >= 0


@pytest.mark.timeout(120)
def test_kill_during_boot_window_still_completes():
    """Killing the first worker while it is still BOOTING: no messages are
    in flight yet, so nothing requeues — but the slot must die, stay
    dead, and the pool must route the whole stream around it."""
    res = _run_with_kill(0, 5.0)  # worker_boot_delay is 15s
    assert res.completed == res.total
    assert res.requeued == 0
    assert all(m.done_t >= 0.0 for m in res.messages)
