"""The observability plane: schema pin, metric merge, analyzer, exporters.

This file is the runtime half of the R6 pin (``repro-analyze`` checks the
``bus.emit`` call sites statically; here the three backends actually run
and must produce byte-identical payload schemas).  It also pins the two
properties that make the metrics plane trustworthy:

- **clean drain** — after a multiproc run completes, the worker-side
  counters merged over the data queue equal the master's completion count
  exactly (the flush rides the queue *before* each completion, FIFO);
- **SIGKILL bounds** — killing a worker process mid-run may lose the
  killed worker's unflushed delta and may double-count a message whose
  metrics flush outran its completion event, but never by more than the
  in-flight PEs at the kill: ``completed <= merged <= completed + pes``.

The analyzer tests close the loop the issue asks for: latency
decomposition sums reproduce each message's recorded e2e latency, and the
p50/p95/p99 computed from the event log alone equal the ones
``benchmarks/runtime_throughput.py`` computes from the run's in-memory
``Message`` list.
"""

import json
import subprocess
import sys

import numpy as np
import pytest

from repro.obs import EventBus, ObsConfig
from repro.obs.analyze import (
    audit_report,
    critical_path,
    drift_report,
    e2e_percentiles,
    fold_events,
    latency_decomposition,
    load_manifest,
    render_drift,
    schema_of,
    summarize,
    validate_events,
)
from repro.obs.audit import explain_rejections
from repro.obs.exporters import (
    load_events,
    prometheus_text,
    run_summary,
    write_jsonl,
)
from repro.obs.metrics import MetricsRegistry
from repro.runtime import RuntimeConfig
from repro.scenarios.engine import run_scenario
from repro.scenarios.registry import get_scenario

#: Every event type the manifest pins — listed literally so both this
#: test and the R6 "exercised" check can see each one.
EXPECTED_TYPES = (
    "msg.enqueued",
    "msg.pulled",
    "msg.started",
    "msg.completed",
    "msg.requeued",
    "worker.boot",
    "worker.active",
    "worker.deactivate",
    "worker.kill",
    "pe.spawn",
    "pe.exit",
    "irm.pack",
)


def _run(backend, *, sim_overrides=None, time_scale=0.01, level="full"):
    scn = get_scenario("microscopy")
    kwargs = dict(
        policy="first-fit", base_seed=0, n_runs=1,
        stream_overrides=scn.smoke_overrides, t_max=scn.smoke_t_max,
        sim_overrides=sim_overrides, obs=ObsConfig(level=level),
    )
    if backend != "sim":
        kwargs["runtime"] = RuntimeConfig(time_scale=time_scale)
    return run_scenario("microscopy", backend=backend, **kwargs)


@pytest.fixture(scope="module")
def sim_result():
    return _run("sim")


@pytest.fixture(scope="module")
def live_result():
    return _run("live")


@pytest.fixture(scope="module")
def mp_result():
    return _run("multiproc", time_scale=0.02)


@pytest.fixture(scope="module")
def sim_fault():
    return _run("sim", sim_overrides={"fail_worker_at": (0, 20.5)})


@pytest.fixture(scope="module")
def mp_fault():
    return _run("multiproc", time_scale=0.05,
                sim_overrides={"fail_worker_at": (0, 20.5)})


# ---------------------------------------------------------------------------
# Metric instruments
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2.5)
    reg.gauge("g").set(7.0)
    reg.gauge("g").set(3.0)
    h = reg.histogram("h", bounds=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(99.0)
    snap = reg.snapshot()
    assert snap["c"] == {"type": "counter", "value": 3.5}
    assert snap["g"] == {"type": "gauge", "value": 3.0}
    assert snap["h"]["counts"] == [1, 1, 1]
    assert snap["h"]["count"] == 3
    assert snap["h"]["sum"] == pytest.approx(104.5)
    with pytest.raises(TypeError):
        reg.gauge("c")


def test_delta_merge_equals_snapshot():
    """N worker registries flushed as deltas into a master registry give
    the same totals as observing everything in one registry."""
    master = MetricsRegistry()
    reference = MetricsRegistry()
    for w in range(3):
        worker = MetricsRegistry()
        for i in range(4):
            v = w + i * 0.5
            worker.counter("done").inc()
            worker.histogram("svc").observe(v)
            reference.counter("done").inc()
            reference.histogram("svc").observe(v)
            if i == 1:  # mid-run flush: deltas, not totals, must ship
                master.merge(worker.delta())
        master.merge(worker.delta())
        assert worker.delta() == {}  # drained: nothing left to ship
    assert master.snapshot() == reference.snapshot()


def test_histogram_merge_rejects_mismatched_bounds():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("h", bounds=(1.0, 2.0)).observe(1.5)
    b.histogram("h", bounds=(1.0, 3.0)).observe(1.5)
    with pytest.raises(ValueError, match="bounds mismatch"):
        a.merge(b.delta())


# ---------------------------------------------------------------------------
# Cross-backend schema equality (the runtime half of R6)
# ---------------------------------------------------------------------------


def test_schema_identical_across_backends(sim_result, live_result, mp_result):
    """All three backends emit byte-identical payload schemas on the
    shared scenario, and every observed type conforms to the manifest."""
    schemas = {}
    for name, res in (("sim", sim_result), ("live", live_result),
                      ("multiproc", mp_result)):
        assert res.obs is not None
        assert validate_events(res.obs.events) == []
        schemas[name] = schema_of(res.obs.events)
    common = set(schemas["sim"]) & set(schemas["live"]) & set(schemas["multiproc"])
    # the happy path must produce the full lifecycle on every backend
    assert {"msg.enqueued", "msg.pulled", "msg.started", "msg.completed",
            "worker.boot", "worker.active", "pe.spawn", "pe.exit",
            "irm.pack"} <= common
    for ev in common:
        pinned = json.dumps(schemas["sim"][ev], sort_keys=True)
        assert json.dumps(schemas["live"][ev], sort_keys=True) == pinned
        assert json.dumps(schemas["multiproc"][ev], sort_keys=True) == pinned


def test_fault_runs_cover_the_remaining_types(sim_fault, mp_fault):
    """worker.kill / msg.requeued only appear under faults; with those
    runs included, the union of observed types is the entire manifest."""
    assert validate_events(sim_fault.obs.events) == []
    assert validate_events(mp_fault.obs.events) == []
    observed = set()
    for res in (sim_fault, mp_fault):
        observed |= {e["ev"] for e in res.obs.events}
    assert {"worker.kill", "msg.requeued", "worker.deactivate"} <= observed


def test_event_logs_conform_to_the_protocol_machines(
        sim_result, live_result, mp_result, sim_fault, mp_fault):
    """The runtime half of rule R8: every backend's event log — clean
    runs and mid-run-SIGKILL runs alike — replays against the protocol
    state machines with zero happens-before violations."""
    from repro.analysis.protocol import load_committed_manifest, replay_events

    manifest = load_committed_manifest()
    for name, res in (("sim", sim_result), ("live", live_result),
                      ("multiproc", mp_result), ("sim+kill", sim_fault),
                      ("multiproc+kill", mp_fault)):
        summary = replay_events(res.obs.events, manifest)
        assert summary.ok, (name, [str(v) for v in summary.violations])
        assert summary.completed > 0, name
    # the kill runs must actually exercise the requeue edge — otherwise
    # this test would pass on a log that never saw a failure
    assert replay_events(sim_fault.obs.events, manifest).requeued > 0
    assert replay_events(mp_fault.obs.events, manifest).requeued > 0


def test_manifest_matches_expected_types(sim_result, sim_fault):
    man = load_manifest()["events"]
    assert set(man) == set(EXPECTED_TYPES)
    observed = {e["ev"] for e in sim_result.obs.events}
    observed |= {e["ev"] for e in sim_fault.obs.events}
    assert observed == set(EXPECTED_TYPES)


def test_vector_policy_audit_capture():
    """The vector allocator path captures its audit too (multi-dim free
    vectors, per-dimension rejection reasons)."""
    scn = get_scenario("microscopy-mem")
    res = run_scenario(
        "microscopy-mem", policy="vector-first-fit", base_seed=0, n_runs=1,
        stream_overrides=scn.smoke_overrides, t_max=scn.smoke_t_max,
        obs=ObsConfig(),
    )
    assert validate_events(res.obs.events) == []
    packs = [e for e in res.obs.events
             if e["ev"] == "irm.pack" and e["placements"]]
    assert packs
    # multi-dimensional sizes ride the audit
    assert any(len(pl["size"]) == 2
               for p in packs for pl in p["placements"])


def test_lifecycle_level_drops_the_decision_audit():
    res = _run("sim", level="lifecycle")
    assert all(e["ev"] != "irm.pack" for e in res.obs.events)
    # lifecycle events still flow
    assert any(e["ev"] == "msg.completed" for e in res.obs.events)


# ---------------------------------------------------------------------------
# Metric merge over the process boundary
# ---------------------------------------------------------------------------


def test_multiproc_clean_drain_merges_exactly(mp_result):
    """Every worker-side delta rides the data queue before its completion
    event, so at clean drain the merged counter equals the master's
    completion count exactly — no loss, no double-count."""
    reg = mp_result.obs.registry.snapshot()
    completed = mp_result.summary["completed"]
    assert reg["worker.msgs_completed"]["value"] == completed
    assert reg["worker.service_s"]["count"] == completed
    assert reg["worker.payload_cpu_s"]["value"] > 0.0


def test_multiproc_sigkill_merge_bounds(mp_fault):
    """A SIGKILL mid-run loses at most the killed worker's unflushed
    delta and double-counts at most the in-flight PEs whose metric flush
    outran the completion event it preceded."""
    completed = mp_fault.summary["completed"]
    assert completed == mp_fault.summary["total"]  # at-least-once held
    kills = [e for e in mp_fault.obs.events if e["ev"] == "worker.kill"]
    assert len(kills) == 1
    pes_at_kill = kills[0]["pes"]
    merged = mp_fault.obs.registry.snapshot()["worker.msgs_completed"]["value"]
    assert completed <= merged <= completed + pes_at_kill


def test_transport_stats_surface_as_run_summary_metrics(mp_result):
    """``Transport.stats()`` counters are first-class metrics now —
    profiler drift and serialization cost no longer die inside the
    transport object."""
    reg = mp_result.obs.registry.snapshot()
    for key in ("transport.profiler_drift_pp", "transport.ser_bytes_per_msg",
                "transport.ser_ms_per_msg", "transport.data_msgs_in",
                "transport.workers_spawned"):
        assert key in reg, f"missing {key}"
        assert reg[key]["type"] == "gauge"
    summary = run_summary(mp_result.obs.registry)
    assert summary["metrics"]["transport.profiler_drift_pp"] is not None


# ---------------------------------------------------------------------------
# Analyzer: latency decomposition, percentiles, traces
# ---------------------------------------------------------------------------


def _decomposition_matches_recorded_e2e(res):
    events = res.obs.events
    enq = {e["msg_id"]: e for e in events if e["ev"] == "msg.enqueued"}
    dec = latency_decomposition(events)
    assert dec["totals"]["count"] == res.summary["completed"]
    for row in dec["per_message"]:
        total = row["queue_wait"] + row["handoff"] + row["service"]
        assert row["e2e"] == pytest.approx(total, abs=1e-9)
        done = [e for e in events
                if e["ev"] == "msg.completed" and e["msg_id"] == row["msg_id"]]
        recorded = done[-1]["done_t"] - enq[row["msg_id"]]["t"]
        assert row["e2e"] == pytest.approx(recorded, abs=1e-6)


def test_latency_decomposition_sums_to_recorded_e2e(sim_result, live_result):
    _decomposition_matches_recorded_e2e(sim_result)
    _decomposition_matches_recorded_e2e(live_result)


def test_decomposition_charges_requeues_to_queue_wait(sim_fault):
    dec = latency_decomposition(sim_fault.obs.events)
    reexecuted = [r for r in dec["per_message"] if r["attempts"] > 1]
    assert reexecuted, "fault run should re-execute at least one message"
    for row in reexecuted:
        assert row["service"] >= 0.0
        assert row["handoff"] >= -1e-9


def test_analyzer_percentiles_match_bench_pipeline(live_result):
    """p50/p95/p99 from the event log alone == the BENCH_runtime.json
    pipeline's numbers from the run's in-memory Message list."""
    done = [m for m in live_result.final.messages if m.done_t >= 0]
    lat = np.array([m.done_t - m.arrival for m in done])
    expected = {p: float(np.percentile(lat, p)) for p in (50, 95, 99)}
    pct = e2e_percentiles(live_result.obs.events)
    assert pct["count"] == len(done)
    assert pct["p50"] == pytest.approx(expected[50], rel=1e-12)
    assert pct["p95"] == pytest.approx(expected[95], rel=1e-12)
    assert pct["p99"] == pytest.approx(expected[99], rel=1e-12)


def test_critical_path_orders_one_message(sim_result):
    # msg_id is a process-wide auto-increment: derive a real id from the
    # log rather than assuming the stream starts at 0
    first = min(e["msg_id"] for e in sim_result.obs.events
                if e["ev"] == "msg.enqueued")
    path = critical_path(sim_result.obs.events, first)
    assert [h["ev"] for h in path][:2] == ["msg.enqueued", "msg.pulled"]
    assert path[-1]["ev"] == "msg.completed"
    assert all(h["dt"] >= 0.0 for h in path[1:])


def test_fold_events_derives_master_metrics(sim_result):
    reg = MetricsRegistry()
    fold_events(reg, sim_result.obs.events)
    snap = reg.snapshot()
    n = sim_result.summary["completed"]
    assert snap["events.msg.completed"]["value"] == n
    assert snap["latency.e2e_s"]["count"] == n
    assert snap["latency.service_s"]["count"] == n


# ---------------------------------------------------------------------------
# Decision audit
# ---------------------------------------------------------------------------


def test_explain_rejections_first_fit_skips_full_bins():
    rej = explain_rejections(
        "first-fit", capacity=[1.0],
        free_before=[[0.2], [0.9]], sizes=[[0.5]], assignments=[1],
    )
    assert len(rej) == 1 and len(rej[0]) == 1
    assert rej[0][0]["bin"] == 0
    assert "insufficient cpu" in rej[0][0]["reason"] or \
        "insufficient dim0" in rej[0][0]["reason"]


def test_explain_rejections_best_fit_names_looser_bins():
    rej = explain_rejections(
        "best-fit", capacity=[1.0],
        free_before=[[0.9], [0.6]], sizes=[[0.5]], assignments=[1],
        dims=("cpu",),
    )
    assert rej[0][0]["bin"] == 0
    assert "looser residual" in rej[0][0]["reason"]


def test_irm_pack_events_carry_consistent_audit(sim_result):
    packs = [e for e in sim_result.obs.events if e["ev"] == "irm.pack"]
    assert packs
    with_placements = [p for p in packs if p["placements"]]
    assert with_placements, "full level should capture placements"
    for p in with_placements:
        for pl in p["placements"]:
            assert pl["bin"] >= 0
            for rej in pl["rejections"]:
                assert rej["bin"] != pl["bin"]
    report = audit_report(sim_result.obs.events, run=0)
    assert "packing run 0" in report and "policy=first-fit" in report


# ---------------------------------------------------------------------------
# Drift report
# ---------------------------------------------------------------------------


def test_drift_report_flags_schema_and_count_divergence(sim_result):
    events = sim_result.obs.events
    clean = drift_report(events, events)
    assert clean["schema"] == {"only_in_a": [], "only_in_b": [],
                               "field_diffs": {}}
    assert all(c["a"] == c["b"] for c in clean["counts"].values())
    mutated = [dict(e) for e in events if e["ev"] != "pe.exit"]
    for e in mutated:
        if e["ev"] == "msg.completed":
            e["extra_field"] = 1
    rep = drift_report(events, mutated)
    assert "pe.exit" in rep["schema"]["only_in_a"]
    assert "msg.completed" in rep["schema"]["field_diffs"]
    text = render_drift(rep)
    assert "differs" in text and "e2e" in text


# ---------------------------------------------------------------------------
# Exporters + CLI
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_and_prometheus_text(tmp_path, sim_result):
    path = tmp_path / "events.jsonl"
    write_jsonl(path, sim_result.obs.events)
    assert load_events(path) == sim_result.obs.events
    reg = MetricsRegistry()
    fold_events(reg, sim_result.obs.events)
    text = prometheus_text(reg)
    assert "# TYPE events_msg_completed counter" in text
    assert '# TYPE latency_e2e_s histogram' in text
    assert 'latency_e2e_s_bucket{le="+Inf"}' in text
    # +Inf bucket is cumulative over everything
    n = sim_result.summary["completed"]
    assert f'latency_e2e_s_bucket{{le="+Inf"}} {n}' in text


def test_cli_subcommands(tmp_path, sim_result, live_result):
    from repro.obs.__main__ import main

    log = tmp_path / "events.jsonl"
    other = tmp_path / "other.jsonl"
    write_jsonl(log, sim_result.obs.events)
    write_jsonl(other, live_result.obs.events)
    first = min(e["msg_id"] for e in sim_result.obs.events
                if e["ev"] == "msg.enqueued")
    absent = max(e["msg_id"] for e in sim_result.obs.events
                 if e["ev"] == "msg.enqueued") + 10_000
    assert main(["schema-check", str(log)]) == 0
    assert main(["latency", str(log), "--json"]) == 0
    assert main(["trace", str(log), "--msg", str(first)]) == 0
    assert main(["trace", str(log), "--msg", str(absent)]) == 1
    assert main(["audit", str(log)]) == 0
    assert main(["diff", str(log), str(other)]) == 0
    assert main(["summary", str(log)]) == 0
    # a log violating the manifest fails the check
    bad = [dict(e) for e in sim_result.obs.events]
    bad[0]["mystery"] = True
    write_jsonl(log, bad)
    assert main(["schema-check", str(log)]) == 1


def test_cli_entrypoint_runs_as_module(tmp_path, sim_result):
    log = tmp_path / "events.jsonl"
    write_jsonl(log, sim_result.obs.events)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs", "summary", str(log)],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout)
    assert out["events"] == len(sim_result.obs.events)


def test_summarize_counts_and_percentiles(sim_result):
    s = summarize(sim_result.obs.events)
    assert s["events"] == len(sim_result.obs.events)
    assert s["counts"]["msg.completed"] == sim_result.summary["completed"]
    assert s["e2e"]["p50"] is not None


# ---------------------------------------------------------------------------
# Bus envelope
# ---------------------------------------------------------------------------


def test_bus_envelope_and_time_bases():
    bus = EventBus()
    bus.tick = 4.0
    bus.emit("worker.active", worker=1)
    bus.now = lambda: 4.7
    bus.emit("worker.active", worker=2)
    a, b = bus.events
    assert (a["seq"], a["t"], a["tick"]) == (0, 4.0, 4.0)
    assert (b["seq"], b["t"], b["tick"]) == (1, 4.7, 4.0)
    with pytest.raises(ValueError):
        EventBus(level="verbose")
