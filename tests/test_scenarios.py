"""Scenario engine tests: registry round-trip, smoke runs, seed parity.

Three layers:
  - the registry behaves like a registry (register/get/list/unregister,
    duplicate rejection, unknown-name errors),
  - every built-in scenario runs a short deterministic sim without error
    (via its ``smoke_overrides``) and satisfies the universal expectations,
  - the paper's two scenarios produce *bit-identical* time series to the
    legacy ``repro.core.workloads`` + ``simulate`` path, so moving the
    generators behind the registry changed nothing the benchmarks measure.
"""

import numpy as np
import pytest

from repro.core import IRM, IRMConfig, SimConfig, simulate
from repro.core.workloads import synthetic_workload, usecase_workload
from repro.scenarios import (
    Stream,
    get_scenario,
    list_scenarios,
    register_scenario,
    run_scenario,
    scenario_names,
    stream_to_requests,
    unregister_scenario,
)

SMALL_SIM = SimConfig(
    dt=0.5, cores_per_worker=4, max_workers=5,
    worker_boot_delay=5.0, pe_start_delay=1.0,
    container_idle_timeout=1.0, t_max=900.0, seed=0,
)


# ---------------------------------------------------------------------------
# Registry round-trip
# ---------------------------------------------------------------------------


def test_registry_round_trip():
    @register_scenario(
        "_test-dummy", "throwaway", sim_config=lambda: SMALL_SIM,
        tags=("test",),
    )
    def dummy_stream(seed=0, n=5):
        return usecase_workload(seed=seed, n_images=n,
                                duration_range=(2.0, 4.0))

    try:
        scn = get_scenario("_test-dummy")
        assert scn.make_stream is dummy_stream
        assert scn.tags == ("test",)
        assert "_test-dummy" in scenario_names()
        # the decorated function stays a plain generator
        assert isinstance(dummy_stream(0, n=3), Stream)
        # duplicate registration is rejected
        with pytest.raises(ValueError, match="already registered"):
            register_scenario("_test-dummy", "again")(dummy_stream)
    finally:
        unregister_scenario("_test-dummy")
    assert "_test-dummy" not in scenario_names()


def test_unknown_scenario_raises():
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("no-such-scenario")


def test_catalogue_has_at_least_six_scenarios():
    names = scenario_names()
    assert len(names) >= 6
    for required in ("synthetic", "microscopy", "bursty", "diurnal",
                     "heavy-tailed", "multi-tenant"):
        assert required in names


def test_unknown_policy_rejected_before_running():
    with pytest.raises(ValueError, match="unknown packing algorithm"):
        run_scenario("synthetic", policy="no-such-fit", n_runs=1,
                     t_max=1.0)


# ---------------------------------------------------------------------------
# Every scenario smoke-runs deterministically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", [s.name for s in list_scenarios()])
def test_scenario_smoke_runs(name):
    scn = get_scenario(name)
    assert scn.smoke_overrides is not None, "built-ins must define smoke runs"
    result = run_scenario(
        scn, n_runs=1, stream_overrides=scn.smoke_overrides,
        t_max=scn.smoke_t_max,
    )
    res = result.final
    assert res.total > 0
    assert res.completed == res.total
    assert (res.scheduled_cpu <= 1.0 + 1e-9).all()
    assert len(res.times) == res.measured_cpu.shape[0]


@pytest.mark.parametrize("name", [s.name for s in list_scenarios()])
def test_scenario_smoke_is_deterministic(name):
    scn = get_scenario(name)
    kwargs = dict(n_runs=1, stream_overrides=scn.smoke_overrides,
                  t_max=scn.smoke_t_max)
    a = run_scenario(scn, **kwargs).final
    b = run_scenario(scn, **kwargs).final
    np.testing.assert_array_equal(a.measured_cpu, b.measured_cpu)
    np.testing.assert_array_equal(a.scheduled_cpu, b.scheduled_cpu)
    assert a.makespan == b.makespan


def test_policy_sweep_changes_nothing_for_equivalent_firstfits():
    """first-fit and first-fit-tree are the same algorithm (property-tested
    in test_binpack); the scenario runner must preserve that equivalence."""
    scn = get_scenario("multi-tenant")
    kwargs = dict(n_runs=1, stream_overrides=scn.smoke_overrides,
                  t_max=scn.smoke_t_max)
    a = run_scenario(scn, policy="first-fit", **kwargs).final
    b = run_scenario(scn, policy="first-fit-tree", **kwargs).final
    np.testing.assert_array_equal(a.scheduled_cpu, b.scheduled_cpu)


# ---------------------------------------------------------------------------
# Seed parity: the registry path reproduces the legacy path bit-for-bit
# ---------------------------------------------------------------------------


def test_synthetic_scenario_matches_legacy_path():
    stream_kwargs = dict(t_end=60.0, peak_times=(30.0,), peak_size=8,
                         batch_size=(2, 4))
    legacy = simulate(synthetic_workload(seed=0, **stream_kwargs), SMALL_SIM)

    scn = get_scenario("synthetic")
    engine = simulate(scn.make_stream(0, **stream_kwargs), SMALL_SIM)

    np.testing.assert_array_equal(legacy.measured_cpu, engine.measured_cpu)
    np.testing.assert_array_equal(legacy.scheduled_cpu, engine.scheduled_cpu)
    np.testing.assert_array_equal(legacy.queue_len, engine.queue_len)
    assert legacy.makespan == engine.makespan


def test_microscopy_scenario_matches_legacy_path():
    import dataclasses

    stream_kwargs = dict(n_images=40, duration_range=(4.0, 8.0))
    scn = get_scenario("microscopy")

    # the registered generator IS the seed generator
    a = usecase_workload(seed=3, **stream_kwargs)
    b = scn.make_stream(3, **stream_kwargs)
    assert [m.duration for _, ms in a.batches for m in ms] == [
        m.duration for _, ms in b.batches for m in ms
    ]

    # and the runner adds nothing on top of a direct simulate() call
    result = run_scenario(
        "microscopy", n_runs=1, stream_overrides=stream_kwargs, t_max=900.0,
    )
    cfg = dataclasses.replace(scn.sim_config(), t_max=900.0)
    direct = simulate(usecase_workload(seed=0, **stream_kwargs), cfg,
                      irm=IRM(IRMConfig()))
    np.testing.assert_array_equal(result.final.measured_cpu,
                                  direct.measured_cpu)
    np.testing.assert_array_equal(result.final.scheduled_cpu,
                                  direct.scheduled_cpu)
    assert result.final.makespan == direct.makespan


# ---------------------------------------------------------------------------
# Serving adapter
# ---------------------------------------------------------------------------


def test_stream_to_requests_is_monotone_in_duration():
    stream = usecase_workload(seed=0, n_images=10,
                              duration_range=(5.0, 20.0))
    schedule = stream_to_requests(stream)
    assert len(schedule) == 10
    msgs = [m for _, ms in stream.batches for m in ms]
    by_id = sorted(range(10), key=lambda i: msgs[i].duration)
    toks = [schedule[i][1].max_new_tokens for i in by_id]
    assert toks == sorted(toks)
    assert all(req.req_class == msgs[0].image for _, req in schedule)


def test_serving_backend_drains_scenario_stream():
    from repro.scenarios import run_serving_scenario

    scn = get_scenario("bursty")
    summary = run_serving_scenario(
        scn, stream_overrides=scn.smoke_overrides, t_max=600.0,
    )
    assert summary["completed"] == summary["submitted"] > 0
    assert summary["peak_replicas"] >= 1
