"""Live streaming runtime: component behavior + end-to-end scenarios.

The end-to-end cases run real asyncio execution in scaled wall-clock time
(a few seconds each); every test carries a ``timeout`` marker so a
deadlocked await fails fast instead of hanging CI.
"""

import asyncio

import pytest

from repro.core.queues import HostRequest
from repro.core.sim import PEState, SimConfig, WorkerState
from repro.runtime import (
    Master,
    RuntimeConfig,
    ScaledClock,
    SleepPayload,
    make_payload,
    run_live,
)
from repro.scenarios.engine import run_scenario, summarize_result
from repro.scenarios.registry import get_scenario
from repro.scenarios.streams import Message

# 1 scenario second = 10 ms wall: fast enough for CI, coarse enough that
# event-loop jitter on a loaded runner stays small relative to the delays
FAST = RuntimeConfig(time_scale=0.01)


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_scaled_clock_maps_virtual_to_wall():
    async def go():
        clock = ScaledClock(time_scale=0.01)
        clock.start()
        await clock.sleep(10.0)  # 10 virtual seconds = 0.1 s wall
        return clock.now()

    elapsed = asyncio.run(go())
    assert 10.0 <= elapsed < 20.0


def test_scaled_clock_rejects_nonpositive_scale():
    with pytest.raises(ValueError):
        ScaledClock(time_scale=0.0)


def test_make_payload_unknown_name():
    with pytest.raises(ValueError, match="unknown payload"):
        make_payload("no-such-payload")


@pytest.mark.timeout(30)
def test_master_global_fifo_and_mix():
    async def go():
        master = Master(total_expected=3)
        a1 = Message(image="a", duration=1.0)
        b1 = Message(image="b", duration=1.0)
        a2 = Message(image="a", duration=1.0)
        for m in (a1, b1, a2):
            master.push_back(m)
        assert master.queue_length() == 3.0
        # first-occurrence order: a before b; counts 2/3 and 1/3
        mix = master.queue_image_mix()
        assert list(mix) == ["a", "b"]
        assert mix["a"] == pytest.approx(2 / 3)
        # global FIFO across images
        assert master.backlog_head(3) == [a1, b1, a2]
        # front re-insert beats older arrivals of the same image
        a0 = Message(image="a", duration=1.0)
        master.push_front(a0)
        assert master.backlog_head(4) == [a0, a1, b1, a2]
        assert master.pull("a") is a0
        assert master.pull("a") is a1
        assert master.pull("b") is b1
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(60)
def test_master_backlog_semantics_match_sim_cluster():
    """Drift guard: the live Master deliberately re-implements the sim's
    backlog structure (per-image FIFO deques + global sequence numbers)
    rather than sharing code with the equivalence-pinned ``core/sim.py``
    hot path — so pin the *semantics* instead: the same randomized
    push-back / push-front / pull sequence must leave both backends with
    identical global-FIFO heads and image mixes at every step."""
    import numpy as np

    from repro.core.irm import IRM
    from repro.core.sim import SimCluster, SimConfig

    async def go():
        rng = np.random.default_rng(7)
        master = Master()
        sim = SimCluster(SimConfig(), IRM())
        images = ["a", "b", "c"]
        for _step in range(300):
            op = rng.integers(0, 3)
            img = images[int(rng.integers(0, len(images)))]
            if op == 0:
                m = Message(image=img, duration=1.0)
                master.push_back(m)
                sim._push_back(m)
            elif op == 1:  # failure requeue: insert(0, m) semantics
                m = Message(image=img, duration=1.0)
                master.push_front(m)
                sim._push_front(m)
            elif master.queue_length() > 0:
                # pull the image of the current global-FIFO head, as an
                # idle PE of that image would
                head_img = master.backlog_head(1)[0].image
                pulled = master.pull(head_img)
                dq = sim._img_queues[head_img]
                _, expect = dq.popleft()
                sim._qlen -= 1
                assert pulled is expect
            assert master.queue_length() == sim.queue_length()
            assert master.queue_image_mix() == sim.queue_image_mix()
            assert master.backlog_head(8) == sim.backlog_head(8)
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_master_drain_event_requires_closed_arrivals():
    async def go():
        master = Master(total_expected=1)
        m = Message(image="a", duration=1.0)
        master.push_back(m)
        assert master.pull("a") is m
        m.done_t = 1.0
        master.complete(m)
        assert not master.drained.is_set()  # arrivals still open
        master.close_arrivals()
        assert master.drained.is_set()
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_master_drain_waits_for_in_flight_messages():
    """Regression: with ``total_expected`` unset the completed-count check
    is vacuous, and an empty backlog used to flip ``drained`` while pulled
    messages were still processing at PEs."""

    async def go():
        master = Master()  # total_expected unset (0)
        m = Message(image="a", duration=1.0)
        master.push_back(m)
        assert master.pull("a") is m  # now in flight at a PE
        assert master.in_flight == 1
        master.close_arrivals()
        assert not master.drained.is_set()  # queue empty but work pending
        m.done_t = 1.0
        master.complete(m)
        assert master.in_flight == 0
        assert master.drained.is_set()
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_master_requeue_reinserts_at_head_with_accounting():
    """A failure requeue returns the in-flight message to the global FIFO
    head, clears its start stamp, and keeps the at-least-once counters."""

    async def go():
        master = Master(total_expected=2)
        a1 = Message(image="a", duration=1.0)
        a2 = Message(image="a", duration=1.0)
        master.push_back(a1)
        master.push_back(a2)
        pulled = master.pull("a")
        assert pulled is a1
        pulled.start_t = 5.0
        master.requeue(pulled)  # its worker died
        assert pulled.start_t == -1.0
        assert master.in_flight == 0
        assert master.requeued == 1
        # head re-insert: the requeued message beats the older a2
        assert master.backlog_head(2) == [a1, a2]
        master.close_arrivals()
        assert not master.drained.is_set()  # nothing is done yet
        for _ in range(2):
            m = master.pull("a")
            m.done_t = 1.0
            master.complete(m)
        assert master.drained.is_set()
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_backlog_demand_accumulator_matches_scan():
    """The incremental per-image counters must reproduce the sim's
    64-message head scan exactly — shallow and deep backlogs, after
    interleaved pulls and front requeues."""
    import numpy as np

    from repro.core.irm import IRM, IRMConfig
    from repro.core.sim import SimConfig
    from repro.runtime.live import LiveCluster

    async def go():
        cfg = SimConfig(resource_dims=("cpu", "mem"))
        irm = IRM(IRMConfig())
        master = Master()
        cluster = LiveCluster(cfg, irm, master, pool=None, lifecycle=None)
        est = irm.profiler.estimate

        def scan_demand():
            total = None
            for msg in master.backlog_head(64):
                v = est(msg.image)
                total = v if total is None else total + v
            return total

        rng = np.random.default_rng(3)
        images = ["a", "b", "c", "d"]
        assert cluster.backlog_resource_demand() is None  # empty backlog
        for _step in range(400):
            op = rng.integers(0, 4)
            img = images[int(rng.integers(0, len(images)))]
            if op <= 1:  # bias toward pushes so the backlog exceeds 64
                master.push_back(Message(image=img, duration=1.0))
            elif op == 2:
                master.push_front(Message(image=img, duration=1.0))
            elif master.queue_length() > 0:
                head_img = master.backlog_head(1)[0].image
                master.requeue(master.pull(head_img))
                master.pull(head_img)
            fast, slow = cluster.backlog_resource_demand(), scan_demand()
            assert (fast is None) == (slow is None)
            if fast is not None:
                assert fast.dims == slow.dims
                np.testing.assert_allclose(
                    fast.values, slow.values, rtol=1e-12, atol=1e-12
                )
        assert master.queue_length() > 64  # the deep-backlog path was hit
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_pe_idles_out_and_worker_hosts_while_active():
    """A placed PE starts, drains its queue, then self-terminates."""

    async def go():
        from repro.runtime.lifecycle import Lifecycle
        from repro.runtime.worker import WorkerPool

        cfg = SimConfig(pe_start_delay=0.5, container_idle_timeout=1.0,
                        worker_boot_delay=0.0)
        clock = ScaledClock(time_scale=0.005)
        master = Master(total_expected=1)
        pool = WorkerPool(cfg, master, clock, SleepPayload(),
                          poll_interval=cfg.dt)
        lifecycle = Lifecycle(pool, cfg, clock)
        clock.start()
        lifecycle.scale_workers(1)
        w = pool.workers[0]
        assert w.state is WorkerState.ACTIVE  # zero boot delay
        master.push_back(Message(image="img", duration=2.0))
        assert pool.try_start_pe(
            HostRequest(image="img", size_estimate=0.2, target_worker=0)
        )
        assert w.pes[0].state is PEState.STARTING
        master.close_arrivals()
        await asyncio.wait_for(
            master.drained.wait(), clock.to_wall(60.0)
        )
        assert len(master.completed) == 1
        msg = master.completed[0]
        assert msg.start_t >= 0.5  # start delay elapsed first
        assert msg.done_t == pytest.approx(msg.start_t + 2.0, abs=1.0)
        # the PE idles out and removes itself from its worker
        deadline = clock.now() + 30.0
        while w.pes and clock.now() < deadline:
            await clock.sleep(0.5)
        assert not w.pes
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_try_start_pe_fails_while_worker_boots():
    async def go():
        from repro.runtime.lifecycle import Lifecycle
        from repro.runtime.worker import WorkerPool

        cfg = SimConfig(worker_boot_delay=50.0)
        clock = ScaledClock(time_scale=0.005)
        master = Master()
        pool = WorkerPool(cfg, master, clock, SleepPayload(),
                          poll_interval=cfg.dt)
        lifecycle = Lifecycle(pool, cfg, clock)
        clock.start()
        lifecycle.scale_workers(2)
        assert [w.state for w in pool.workers] == [WorkerState.BOOTING] * 2
        req = HostRequest(image="img", size_estimate=0.2, target_worker=0)
        assert not pool.try_start_pe(req)  # still initializing (paper V-B.2)
        assert not pool.try_start_pe(
            HostRequest(image="img", size_estimate=0.2, target_worker=7)
        )  # out of range
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_lifecycle_defers_scale_down_while_booting():
    """The anti-churn guard: no deactivation while boots are in flight."""

    async def go():
        from repro.runtime.lifecycle import Lifecycle
        from repro.runtime.worker import WorkerPool

        cfg = SimConfig(worker_boot_delay=50.0, max_workers=5)
        clock = ScaledClock(time_scale=0.005)
        pool = WorkerPool(cfg, Master(), clock, SleepPayload(),
                          poll_interval=cfg.dt)
        lifecycle = Lifecycle(pool, cfg, clock)
        clock.start()
        lifecycle.scale_workers(1)   # worker 0 boots, ready at t=50
        pool.promote_booted(50.0)    # its boot completes
        lifecycle.nominal_t = 50.0
        lifecycle.scale_workers(5)   # four more boot, ready at t=100
        lifecycle.scale_workers(2)   # four still BOOTING -> defer scale-down
        assert pool.workers[0].state is WorkerState.ACTIVE
        assert all(
            w.state is WorkerState.BOOTING for w in pool.workers[1:]
        )
        # once everything is ACTIVE the scale-down proceeds, highest first
        pool.promote_booted(100.0)
        lifecycle.nominal_t = 100.0
        lifecycle.scale_workers(2)
        assert [w.state for w in pool.workers] == [
            WorkerState.ACTIVE, WorkerState.ACTIVE, WorkerState.OFF,
            WorkerState.OFF, WorkerState.OFF,
        ]
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_lifecycle_stale_boot_does_not_block_scale_down():
    """Regression: the anti-churn guard is scoped to boots younger than
    ``worker_boot_delay``.  A stale BOOTING slot (its delay already
    elapsed — e.g. orphaned by a failure-driven kill/reboot cycle) must
    not pin the pool at max size forever."""

    async def go():
        from repro.runtime.lifecycle import Lifecycle
        from repro.runtime.worker import WorkerPool

        cfg = SimConfig(worker_boot_delay=5.0, max_workers=5)
        clock = ScaledClock(time_scale=0.001)
        pool = WorkerPool(cfg, Master(), clock, SleepPayload(),
                          poll_interval=cfg.dt)
        lifecycle = Lifecycle(pool, cfg, clock)
        clock.start()
        lifecycle.scale_workers(2)   # workers 0-1 boot, ready at t=5
        pool.promote_booted(5.0)
        lifecycle.nominal_t = 5.0
        lifecycle.scale_workers(3)   # worker 2 boots, ready at t=10
        # a later tick where worker 2 was never promoted (e.g. orphaned
        # by a failure-driven kill/reboot cycle): its ready time is in
        # the past — the stale state the scoped guard must see through
        lifecycle.nominal_t = 20.0
        lifecycle.scale_workers(2)
        assert [w.state for w in pool.workers] == [
            WorkerState.ACTIVE, WorkerState.OFF, WorkerState.BOOTING,
        ]
        # a boot genuinely in flight still defers the scale-down
        lifecycle.scale_workers(3)   # slot 1 reboots, ready at t=25
        lifecycle.scale_workers(2)
        assert pool.workers[1].state is WorkerState.BOOTING
        assert pool.workers[0].state is WorkerState.ACTIVE
        return True

    assert asyncio.run(go())


@pytest.mark.timeout(30)
def test_lifecycle_kill_worker_requeues_in_flight_at_head():
    """The live fault path: the victim's PE tasks are cancelled, their
    in-flight messages re-enter the master queue head (last PE first),
    and the failed slot is never rebooted by later scale-ups."""

    async def go():
        from repro.runtime.lifecycle import Lifecycle
        from repro.runtime.worker import WorkerPool

        cfg = SimConfig(pe_start_delay=0.5, container_idle_timeout=30.0,
                        worker_boot_delay=0.0, max_workers=5)
        clock = ScaledClock(time_scale=0.005)
        master = Master(total_expected=3)
        pool = WorkerPool(cfg, master, clock, SleepPayload(),
                          poll_interval=cfg.dt)
        lifecycle = Lifecycle(pool, cfg, clock)
        clock.start()
        lifecycle.scale_workers(2)
        m1 = Message(image="img", duration=50.0)
        m2 = Message(image="img", duration=50.0)
        m3 = Message(image="img", duration=50.0)
        for m in (m1, m2, m3):
            master.push_back(m)
        for _ in range(2):
            assert pool.try_start_pe(
                HostRequest(image="img", size_estimate=0.2, target_worker=0)
            )
        w = pool.workers[0]
        # let both PEs start and pull their messages
        while not (len(w.pes) == 2 and all(pe.msg for pe in w.pes)):
            await clock.sleep(0.5)
        assert master.in_flight == 2
        tasks = [pe.task for pe in w.pes]
        victims = [pe.msg for pe in w.pes]

        requeued = lifecycle.kill_worker(0)
        assert requeued == 2
        assert w.state is WorkerState.OFF and not w.pes
        assert master.requeued == 2 and master.in_flight == 0
        # insert(0, m) one by one: the last PE's message is globally first
        assert master.backlog_head(3) == [victims[1], victims[0], m3]
        assert all(m.start_t == -1.0 for m in victims)
        await asyncio.gather(*tasks, return_exceptions=True)
        # _pe_main absorbs the CancelledError; done-and-no-complete is the
        # observable contract (the harvested messages never completed)
        assert all(t.done() for t in tasks) and not master.completed
        # killing again is a no-op, and the dead slot is never rebooted
        assert lifecycle.kill_worker(0) == 0
        lifecycle.scale_workers(3)
        assert w.state is WorkerState.OFF
        # fresh slots were appended instead of resurrecting the dead one
        assert len(pool.workers) == 4
        assert all(x.state is not WorkerState.OFF for x in pool.workers[2:])
        return True

    assert asyncio.run(go())


# ---------------------------------------------------------------------------
# end-to-end scenarios on the live backend
# ---------------------------------------------------------------------------


@pytest.mark.timeout(120)
def test_live_completes_synthetic_end_to_end():
    scn = get_scenario("synthetic")
    cfg = scn.sim_config()
    cfg.t_max = scn.smoke_t_max
    res = run_live(
        scn.make_stream(0, **scn.smoke_overrides), cfg, runtime=FAST
    )
    # the threshold predictor may starve a sub-queue_low tail (faithful
    # paper behavior, see the scenario's nearly_completes note)
    assert res.completed >= 0.9 * res.total
    assert res.total == 20
    assert res.target_workers.max() >= 2
    assert (res.scheduled_cpu <= 1.0 + 1e-9).all()
    summary = summarize_result(res, cfg.dt)
    assert summary["mean_busy_utilization"] > 0.1


@pytest.mark.timeout(120)
def test_live_completes_microscopy_end_to_end():
    scn = get_scenario("microscopy")
    cfg = scn.sim_config()
    cfg.t_max = scn.smoke_t_max
    stats = {}
    res = run_live(
        scn.make_stream(0, **scn.smoke_overrides), cfg, runtime=FAST,
        stats=stats,
    )
    assert res.completed == res.total == 40
    assert res.makespan > 0
    # the IRM actually ran and made decisions
    assert stats["ticks"] > 10
    assert stats["irm_step_ms_mean"] > 0
    assert res.pe_count.max() >= 2


@pytest.mark.timeout(120)
def test_live_vector_scenario_respects_rigid_dimensions():
    """microscopy-mem on the live backend: memory is never overcommitted."""
    scn = get_scenario("microscopy-mem")
    cfg = scn.sim_config()
    cfg.t_max = scn.smoke_t_max
    res = run_live(
        scn.make_stream(0, **scn.smoke_overrides), cfg,
        irm_config=scn.irm_config(), runtime=FAST,
    )
    assert res.completed == res.total
    assert res.resource_dims == ("cpu", "mem")
    assert res.measured_res is not None
    d = res.resource_dims.index("mem")
    # rigid dimension: measured memory never exceeds worker capacity
    assert (res.measured_res[:, :, d] <= 1.0 + 1e-9).all()


@pytest.mark.timeout(120)
def test_live_profiler_persists_across_runs():
    """run_scenario(backend='live') reuses one IRM across back-to-back runs."""
    result = run_scenario(
        "microscopy", backend="live", runtime=FAST, n_runs=2,
        stream_overrides=get_scenario("microscopy").smoke_overrides,
        t_max=get_scenario("microscopy").smoke_t_max,
    )
    assert result.backend == "live"
    assert len(result.runs) == 2
    assert all(r.completed == r.total for r in result.runs)


@pytest.mark.timeout(120)
def test_live_jax_payload_runs_real_kernels():
    """The jax payload executes a real kernel per message and still meets
    the calibrated schedule."""
    scn = get_scenario("microscopy")
    cfg = scn.sim_config()
    cfg.t_max = scn.smoke_t_max
    res = run_live(
        scn.make_stream(0, n_images=8, duration_range=(4.0, 8.0)), cfg,
        runtime=RuntimeConfig(time_scale=0.01, payload="jax"),
    )
    assert res.completed == res.total == 8
    # service time = kernel wall time + calibrated padding >= the message's
    # scenario duration (small tolerance: clock/perf_counter jitter)
    for m in res.messages:
        assert m.done_t - m.start_t >= m.duration - 0.5


def test_run_scenario_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        run_scenario("synthetic", backend="quantum")
    with pytest.raises(ValueError, match="runtime config"):
        run_scenario("synthetic", backend="sim", runtime=RuntimeConfig())
