"""Incremental-repack pin: dirty-bin tracking must be invisible.

``BinPackingManager`` with ``incremental=True`` refreshes only the bins
whose loads changed since the previous decision (plus new slots and the
previous placement frontier) instead of rebuilding the whole prefill
matrix.  These tests drive randomized churn sequences — load perturbations,
fleet growth, scale-down truncation, failure-style zeroing — and assert
after *every* step that the incremental decisions are identical to a
from-scratch full repack, and (for scalar fleets) to the trusted object
packers.  A final pair of tests pins the dirty-fraction fallback and the
run counters that expose which path fired.
"""

import copy

import numpy as np
import pytest

from repro.core.allocator import AllocatorConfig, BinPackingManager
from repro.core.queues import HostRequest

SCALAR_ALGOS = ("first-fit", "best-fit", "worst-fit", "next-fit")
VECTOR_ALGOS = ("vector-first-fit", "vector-best-fit", "vector-next-fit",
                "dominant-fit", "vector-ffd")


def _mk_requests(rng, n):
    return [
        HostRequest("img", size_estimate=float(rng.uniform(0.05, 0.6)),
                    ttl=3)
        for _ in range(n)
    ]


def _run_pair(mgr_inc, mgr_full, t, reqs, loads):
    """Run both managers on identical inputs; return the incremental run."""
    run_inc = mgr_inc.run(t, copy.deepcopy(reqs), loads.copy())
    run_full = mgr_full.run(t, copy.deepcopy(reqs), loads.copy())
    assert (
        [r.target_worker for r in run_inc.placements]
        == [r.target_worker for r in run_full.placements]
    ), f"t={t}: incremental placements diverge from full repack"
    assert run_inc.num_bins == run_full.num_bins
    assert run_inc.ideal_bins == run_full.ideal_bins
    assert run_inc.target_workers == run_full.target_workers
    np.testing.assert_array_equal(
        np.asarray(run_inc.scheduled_load),
        np.asarray(run_full.scheduled_load),
        err_msg=f"t={t}: scheduled load matrices diverge",
    )
    return run_inc


def _churn(rng, loads, cap=1.0):
    """One random fleet mutation: perturb, grow, shrink, or zero (failure)."""
    move = rng.integers(0, 4)
    n = len(loads)
    if move == 0 and n:  # perturb a few rows (completions / new pulls)
        rows = rng.integers(0, n, size=max(1, n // 8))
        loads[rows] = rng.uniform(0.0, cap, size=loads[rows].shape)
    elif move == 1:  # scale-up: new empty slots appear
        grown = np.zeros((n + int(rng.integers(1, 4)),) + loads.shape[1:])
        grown[:n] = loads
        loads = grown
    elif move == 2 and n > 4:  # scale-down: trailing slots retired
        loads = loads[: n - int(rng.integers(1, 3))].copy()
    elif n:  # failure: a worker's load vanishes, its messages requeue
        loads[rng.integers(0, n)] = 0.0
    return loads


@pytest.mark.parametrize("algo", SCALAR_ALGOS)
def test_incremental_equals_full_repack_scalar_churn(algo):
    rng = np.random.default_rng(hash(algo) % (2**32))
    cfg = dict(algorithm=algo, engine="numpy", keep_idle_buffer=False)
    mgr_inc = BinPackingManager(AllocatorConfig(incremental=True, **cfg))
    mgr_full = BinPackingManager(AllocatorConfig(incremental=False, **cfg))
    # the object packers are the ground truth on scalar fleets
    mgr_obj = BinPackingManager(
        AllocatorConfig(algorithm=algo, engine="object",
                        keep_idle_buffer=False)
    )
    loads = rng.uniform(0.0, 1.0, size=12)
    for step in range(30):
        reqs = _mk_requests(rng, int(rng.integers(1, 8)))
        run_inc = _run_pair(mgr_inc, mgr_full, float(step), reqs, loads)
        run_obj = mgr_obj.run(float(step), copy.deepcopy(reqs),
                              [float(u) for u in loads])
        assert (
            [r.target_worker for r in run_inc.placements]
            == [r.target_worker for r in run_obj.placements]
        ), f"{algo} step {step}: numpy diverges from object packer"
        assert run_inc.num_bins == run_obj.num_bins
        loads = _churn(rng, loads)
    assert mgr_inc.incremental_runs > 0  # the fast path actually ran
    assert mgr_full.incremental_runs == 0
    assert mgr_full.full_repacks == 30


@pytest.mark.parametrize("algo", VECTOR_ALGOS)
def test_incremental_equals_full_repack_vector_churn(algo):
    rng = np.random.default_rng(hash(algo) % (2**32))
    cfg = dict(algorithm=algo, engine="numpy", keep_idle_buffer=False)
    mgr_inc = BinPackingManager(AllocatorConfig(incremental=True, **cfg))
    mgr_full = BinPackingManager(AllocatorConfig(incremental=False, **cfg))
    loads = rng.uniform(0.0, 1.0, size=(10, 3))
    for step in range(30):
        reqs = _mk_requests(rng, int(rng.integers(1, 8)))
        _run_pair(mgr_inc, mgr_full, float(step), reqs, loads)
        loads = _churn(rng, loads)
    assert mgr_inc.incremental_runs > 0


def test_unchanged_fleet_reuses_cache_and_stays_identical():
    """Back-to-back runs on identical loads: the second run dirties only
    the previous placement frontier, and still matches a full repack."""
    cfg = dict(algorithm="first-fit", engine="numpy",
               keep_idle_buffer=False)
    mgr_inc = BinPackingManager(AllocatorConfig(incremental=True, **cfg))
    mgr_full = BinPackingManager(AllocatorConfig(incremental=False, **cfg))
    rng = np.random.default_rng(42)
    loads = rng.uniform(0.0, 0.8, size=50)
    for t in range(5):
        reqs = _mk_requests(rng, 6)
        _run_pair(mgr_inc, mgr_full, float(t), reqs, loads)
    assert mgr_inc.full_repacks == 1  # only the cold start
    assert mgr_inc.incremental_runs == 4


def test_dirty_fraction_fallback_triggers_full_repack():
    """Churning more rows than ``dirty_fallback`` allows must abandon the
    incremental path; churning fewer must keep it."""
    rng = np.random.default_rng(3)
    loads = rng.uniform(0.0, 0.8, size=40)

    def mgr(fallback):
        return BinPackingManager(AllocatorConfig(
            algorithm="best-fit", engine="numpy", keep_idle_buffer=False,
            incremental=True, dirty_fallback=fallback,
        ))

    picky, lenient = mgr(0.05), mgr(1.0)
    for m in (picky, lenient):
        m.run(0.0, _mk_requests(rng, 3), loads.copy())
    assert picky.full_repacks == lenient.full_repacks == 1
    # dirty half the fleet: 0.5 > 0.05 -> fallback; 0.5 <= 1.0 -> not
    loads[: len(loads) // 2] = rng.uniform(0.0, 0.8, size=len(loads) // 2)
    for m in (picky, lenient):
        m.run(1.0, _mk_requests(rng, 3), loads.copy())
    assert picky.full_repacks == 2 and picky.incremental_runs == 0
    assert lenient.full_repacks == 1 and lenient.incremental_runs == 1


def test_capacity_change_invalidates_cache():
    """A capacity edit (AllocatorConfig.capacity) between runs must not
    reuse a prefill clamped against the old capacity."""
    cfg = AllocatorConfig(algorithm="first-fit", engine="numpy",
                          keep_idle_buffer=False, incremental=True)
    mgr = BinPackingManager(cfg)
    rng = np.random.default_rng(9)
    loads = rng.uniform(0.0, 2.0, size=30)  # some rows above capacity
    mgr.run(0.0, _mk_requests(rng, 3), loads.copy())
    cfg.capacity = 2.0  # live capacity edit
    reqs = _mk_requests(rng, 3)
    run = mgr.run(1.0, copy.deepcopy(reqs), loads.copy())
    fresh = BinPackingManager(AllocatorConfig(
        algorithm="first-fit", engine="numpy", keep_idle_buffer=False,
        incremental=False, capacity=2.0,
    )).run(1.0, copy.deepcopy(reqs), loads.copy())
    assert (
        [r.target_worker for r in run.placements]
        == [r.target_worker for r in fresh.placements]
    )
    assert run.num_bins == fresh.num_bins
    assert run.ideal_bins == fresh.ideal_bins
