"""Unit + property tests for the online bin-packing algorithms (paper Sec. IV).

The hypothesis properties are the system's invariants:
  - no bin ever exceeds its capacity,
  - a new bin is opened only when no active bin fits (Any-Fit, Algorithm 1),
  - First-Fit places each item in the lowest-index fitting bin,
  - the O(n log m) segment-tree First-Fit is exactly equivalent to the O(nm)
    scan version,
  - bin counts respect lower_bound <= used <= R * OPT + c quality envelopes.
"""

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.binpack import (
    ASYMPTOTIC_RATIO,
    BestFit,
    Bin,
    FirstFit,
    FirstFitDecreasing,
    FirstFitTree,
    Harmonic,
    Item,
    NextFit,
    VectorFirstFit,
    VectorItem,
    WorstFit,
    lower_bound,
    make_packer,
)

sizes_strategy = st.lists(
    st.floats(min_value=0.01, max_value=1.0, allow_nan=False), min_size=1, max_size=200
)


# ---------------------------------------------------------------------------
# Basic construction / validation
# ---------------------------------------------------------------------------


def test_item_validation():
    with pytest.raises(ValueError):
        Item(0.0)
    with pytest.raises(ValueError):
        Item(1.5)
    Item(1.0)  # boundary ok
    Item(1e-6)


def test_bin_overflow_raises():
    b = Bin(1.0)
    b.add(Item(0.7))
    with pytest.raises(ValueError):
        b.add(Item(0.5))
    assert b.fits(0.3)
    assert not b.fits(0.31)


def test_oversized_item_raises():
    ff = FirstFit(capacity=0.5)
    with pytest.raises(ValueError):
        ff.pack_one(Item(0.8))


def test_make_packer_unknown():
    with pytest.raises(ValueError):
        make_packer("second-fit")


# ---------------------------------------------------------------------------
# Hypothesis properties
# ---------------------------------------------------------------------------


@given(sizes_strategy)
@settings(max_examples=200, deadline=None)
def test_firstfit_no_overflow_and_lowest_index(sizes):
    ff = FirstFit()
    for s in sizes:
        idx = ff.pack_one(Item(s))
        # no overflow
        assert ff.bins[idx].used <= 1.0 + 1e-9
        # First-Fit criterion: every lower-index bin could NOT have fit it
        for j in range(idx):
            assert ff.bins[j].used + s > 1.0 + 1e-9 or j == idx


@given(sizes_strategy)
@settings(max_examples=200, deadline=None)
def test_anyfit_new_bin_only_when_needed(sizes):
    """Algorithm 1: a new bin is generated only when no active bin fits."""
    for cls in (FirstFit, BestFit, WorstFit):
        packer = cls()
        for s in sizes:
            frees_before = [b.free for b in packer.bins]
            n_before = len(packer.bins)
            packer.pack_one(Item(s))
            if len(packer.bins) > n_before:
                assert all(f + 1e-9 < s for f in frees_before)


@given(sizes_strategy)
@settings(max_examples=300, deadline=None)
def test_firstfit_tree_equivalence(sizes):
    """The segment-tree First-Fit is decision-for-decision identical."""
    ff, fft = FirstFit(), FirstFitTree()
    for s in sizes:
        assert ff.pack_one(Item(s)) == fft.pack_one(Item(s))
    assert len(ff.bins) == len(fft.bins)
    assert [b.used for b in ff.bins] == pytest.approx(
        [b.used for b in fft.bins]
    )


@given(sizes_strategy)
@settings(max_examples=200, deadline=None)
def test_quality_envelopes(sizes):
    """lower_bound <= bins_used; First-Fit <= 1.7*OPT + 2 (via LB <= OPT)."""
    lb = lower_bound(sizes)
    for name in ("first-fit", "best-fit", "worst-fit", "next-fit"):
        packer = make_packer(name)
        res = packer.pack([Item(s) for s in sizes])
        assert res.num_bins >= lb
        ratio = ASYMPTOTIC_RATIO[name]
        # LB <= OPT, so R*LB + c is a valid (weaker) upper envelope
        assert res.num_bins <= math.ceil(ratio * lb) + 2


@given(sizes_strategy)
@settings(max_examples=100, deadline=None)
def test_ffd_no_worse_than_ff(sizes):
    items = [Item(s) for s in sizes]
    ff = FirstFit().pack(list(items))
    ffd = FirstFitDecreasing().pack(list(items))
    assert ffd.num_bins <= ff.num_bins
    # all items assigned, nothing lost
    assert len(ffd.assignments) == len(sizes)
    total = sum(b.used for b in ffd.bins)
    assert total == pytest.approx(sum(sizes))


@given(sizes_strategy)
@settings(max_examples=100, deadline=None)
def test_harmonic_class_discipline(sizes):
    """Harmonic(M): a bin of class k holds at most k items, all in class k."""
    h = Harmonic(m=8)
    for s in sizes:
        h.pack_one(Item(s))
    for b in h.bins:
        assert b.used <= 1.0 + 1e-9
        ks = {h._class_of(it.size) for it in b.items}
        assert len(ks) == 1
        (k,) = ks
        assert len(b.items) <= k


@given(
    st.lists(
        st.tuples(
            st.floats(min_value=0.0, max_value=1.0),
            st.floats(min_value=0.01, max_value=1.0),
        ),
        min_size=1,
        max_size=100,
    ),
    st.sampled_from(["first", "dot", "l2"]),
)
@settings(max_examples=100, deadline=None)
def test_vector_firstfit_feasibility(pairs, heuristic):
    vff = VectorFirstFit(capacity=(1.0, 1.0), heuristic=heuristic)
    for a, b in pairs:
        if max(a, b) <= 0:
            continue
        vff.pack_one(VectorItem((a, b)))
    for vb in vff.bins:
        assert all(u <= c + 1e-9 for u, c in zip(vb.used, vb.capacity, strict=True))


def test_vector_item_validation():
    with pytest.raises(ValueError):
        VectorItem(())
    with pytest.raises(ValueError):
        VectorItem((0.0, 0.0))
    with pytest.raises(ValueError):
        VectorItem((1.2, 0.1))


# ---------------------------------------------------------------------------
# Deterministic examples
# ---------------------------------------------------------------------------


def test_firstfit_example():
    """Hand-checked First-Fit run."""
    ff = FirstFit()
    res = ff.pack([Item(s) for s in (0.5, 0.7, 0.5, 0.2, 0.4, 0.2)])
    #  0.5 -> bin0; 0.7 -> bin1; 0.5 -> bin0 (full); 0.2 -> bin1;
    #  0.4 -> bin2; 0.2 -> bin2
    assert res.assignments == [0, 1, 0, 1, 2, 2]
    assert res.num_bins == 3


def test_nextfit_only_looks_at_last():
    nf = NextFit()
    res = nf.pack([Item(0.6), Item(0.6), Item(0.3)])
    # 0.6 -> bin0; 0.6 -> bin1 (bin0 not revisited); 0.3 -> bin1
    assert res.assignments == [0, 1, 1]


def test_bestfit_tightest_bin():
    bf = BestFit()
    bf.pack([Item(0.5), Item(0.7)])  # bins: free 0.5, free 0.3
    idx = bf.pack_one(Item(0.25))
    assert idx == 1  # tightest fit


def test_worstfit_loosest_bin():
    wf = WorstFit()
    wf.pack([Item(0.5), Item(0.7)])
    idx = wf.pack_one(Item(0.25))
    assert idx == 0  # loosest fit


def test_prefilled_bins():
    """The IRM pre-fills bins with active workers' scheduled load."""
    bins = [Bin(1.0, used=0.9), Bin(1.0, used=0.2)]
    ff = FirstFit(bins=bins)
    assert ff.pack_one(Item(0.5)) == 1
    assert ff.pack_one(Item(0.05)) == 0


def test_lower_bound():
    assert lower_bound([]) == 0
    assert lower_bound([0.5, 0.5]) == 1
    assert lower_bound([0.5, 0.51]) == 2
    assert lower_bound([1.0] * 5) == 5


def test_tree_reset_and_regrowth():
    fft = FirstFitTree()
    fft.pack([Item(1.0) for _ in range(9)])  # forces several tree growths
    assert len(fft.bins) == 9
    fft.reset()
    assert fft.pack_one(Item(0.5)) == 0


def test_harmonic_reset_clears_open_bins():
    """Regression: reset() used to leave the stale class->bin map behind,
    so the next pack() dereferenced a bin index past the emptied bin list
    (IndexError: list index out of range)."""
    h = Harmonic(m=8)
    h.pack([Item(0.4), Item(0.3), Item(0.3)])
    assert h.bins
    h.reset()
    assert h.bins == [] and h._open == {}
    # same class as before the reset -> must open a fresh bin 0, not index
    # into the dropped bin list
    assert h.pack_one(Item(0.4)) == 0
    assert h.pack_one(Item(0.4)) == 0  # class 2: two items share the bin
    assert h.pack_one(Item(0.4)) == 1  # third opens the next class-2 bin
