"""Pallas kernel tests: interpret-mode execution vs the pure-jnp oracles.

Every kernel sweeps shapes/dtypes and asserts allclose against its ref.py.
On this CPU container the kernels execute via ``interpret=True`` (the kernel
body runs in Python), which validates the block decomposition, masking, and
online-softmax algebra exactly as it would run on a TPU.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_matmul.kernel import grouped_matmul
from repro.kernels.grouped_matmul.ops import expert_ffn_swiglu
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref
from repro.kernels.packed_attention.kernel import packed_flash_attention
from repro.kernels.packed_attention.ops import packed_attention
from repro.kernels.packed_attention.ref import packed_attention_ref
from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def random_packed_segments(rng, B, S, max_segs=4, pad_frac=0.2):
    """Segment ids like the First-Fit packer emits: contiguous, 0-padded."""
    seg = np.zeros((B, S), np.int32)
    for b in range(B):
        n_real = int(S * (1 - pad_frac * rng.random()))
        cuts = np.sort(rng.choice(np.arange(1, n_real), size=min(max_segs - 1,
                       n_real - 1), replace=False)) if n_real > 1 else []
        bounds = [0, *cuts, n_real]
        for i in range(len(bounds) - 1):
            seg[b, bounds[i]:bounds[i + 1]] = i + 1
    return seg


def make_qkv(rng, B, S, H, KVH, D, dtype):
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), dtype)
    return q, k, v


TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# packed_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,block", [(256, 128), (512, 256), (384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_attention_kernel_vs_ref(S, block, dtype):
    rng = np.random.default_rng(0)
    B, H, D = 2, 4, 64
    q, k, v = make_qkv(rng, B, S, H, H, D, dtype)
    seg = jnp.asarray(random_packed_segments(rng, B, S))

    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    out = packed_flash_attention(
        qt, kt, vt, seg, seg, causal=True,
        block_q=block, block_kv=block, interpret=True,
    )
    ref = packed_attention_ref(qt, kt, vt, seg, seg, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOLS[dtype]
    )


@pytest.mark.parametrize("KVH", [1, 2, 4])
def test_packed_attention_gqa(KVH):
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 256, 4, 32
    q, k, v = make_qkv(rng, B, S, H, KVH, D, jnp.float32)
    seg = jnp.asarray(random_packed_segments(rng, B, S))
    out = packed_attention(q, k, v, seg, seg, interpret=True)
    # oracle with repeated KV heads
    rep = H // KVH
    kf = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3)
    vf = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3)
    ref = packed_attention_ref(
        q.transpose(0, 2, 1, 3), kf, vf, seg, seg, causal=True
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
    )


def test_packed_attention_sliding_window():
    rng = np.random.default_rng(2)
    B, S, H, D = 1, 256, 2, 32
    q, k, v = make_qkv(rng, B, S, H, H, D, jnp.float32)
    seg = jnp.ones((B, S), jnp.int32)
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    out = packed_flash_attention(
        qt, kt, vt, seg, seg, causal=True, window=64,
        block_q=128, block_kv=128, interpret=True,
    )
    ref = packed_attention_ref(qt, kt, vt, seg, seg, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_packed_attention_fully_padded_rows_are_zero():
    """Rows whose segment id is 0 everywhere must produce zero output."""
    rng = np.random.default_rng(3)
    B, S, H, D = 2, 256, 2, 32
    q, k, v = make_qkv(rng, B, S, H, H, D, jnp.float32)
    seg = jnp.zeros((B, S), jnp.int32).at[0].set(1)  # row 1 fully padded
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    out = packed_flash_attention(
        qt, kt, vt, seg, seg, causal=True,
        block_q=128, block_kv=128, interpret=True,
    )
    assert jnp.all(out[1] == 0.0)
    assert jnp.all(jnp.isfinite(out))


def test_packed_attention_blocks_never_cross_segments():
    """Attention output for segment A is independent of segment B's content."""
    rng = np.random.default_rng(4)
    B, S, H, D = 1, 256, 2, 32
    q, k, v = make_qkv(rng, B, S, H, H, D, jnp.float32)
    seg = jnp.asarray(
        np.concatenate([np.ones(128, np.int32), np.full(128, 2, np.int32)])
    )[None]
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    call = functools.partial(
        packed_flash_attention, causal=True,
        block_q=128, block_kv=128, interpret=True,
    )
    out1 = call(qt, kt, vt, seg, seg)
    # scramble segment 2's keys/values; segment 1's output must not change
    k2 = kt.at[:, :, 128:].set(jnp.asarray(rng.normal(size=(1, H, 128, D)),
                                           jnp.float32))
    v2 = vt.at[:, :, 128:].set(jnp.asarray(rng.normal(size=(1, H, 128, D)),
                                           jnp.float32))
    out2 = call(qt, k2, v2, seg, seg)
    np.testing.assert_allclose(
        np.asarray(out1[:, :, :128]), np.asarray(out2[:, :, :128]),
        rtol=1e-6, atol=1e-6,
    )


def test_ops_wrapper_matches_model_layout():
    """ops.packed_attention accepts (B, S, H, D) + separate KV heads."""
    rng = np.random.default_rng(5)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q, k, v = make_qkv(rng, B, S, H, KVH, D, jnp.float32)
    seg = jnp.asarray(random_packed_segments(rng, B, S))
    out_k = packed_attention(q, k, v, seg, seg, use_kernel=True, interpret=True)
    out_r = packed_attention(q, k, v, seg, seg, use_kernel=False)
    assert out_k.shape == (B, S, H, D)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------


def scatter_pages(rng, lens, num_pages, page_size):
    """Random non-overlapping page assignment (a First-Fit allocator state)."""
    B = len(lens)
    max_pages = max(-(-l // page_size) for l in lens) + 1
    perm = rng.permutation(num_pages)
    pt = np.full((B, max_pages), -1, np.int32)
    off = 0
    for b, l in enumerate(lens):
        n = -(-l // page_size)
        pt[b, :n] = perm[off : off + n]
        off += n
    return pt


@pytest.mark.parametrize("H,KVH", [(8, 2), (4, 4), (16, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_vs_ref(H, KVH, dtype):
    rng = np.random.default_rng(0)
    B, D = 3, 64
    num_pages, page_size = 48, 16
    lens = [37, 5, 100]
    q = jnp.asarray(rng.normal(size=(B, H, D)), dtype)
    kp = jnp.asarray(rng.normal(size=(num_pages, page_size, KVH, D)), dtype)
    vp = jnp.asarray(rng.normal(size=(num_pages, page_size, KVH, D)), dtype)
    pt = jnp.asarray(scatter_pages(rng, lens, num_pages, page_size))
    sl = jnp.asarray(lens, jnp.int32)
    out = paged_decode_attention(q, kp, vp, pt, sl, interpret=True)
    ref = paged_attention_ref(q, kp, vp, pt, sl)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOLS[dtype]
    )


def test_paged_attention_from_allocator():
    """End-to-end with the real First-Fit PageAllocator."""
    from repro.kernels.paged_attention.ops import (
        page_table_from_allocator,
        paged_attention,
    )
    from repro.serving.kv_cache import PageAllocator, PagedCacheLayout

    rng = np.random.default_rng(1)
    KVH, D, page_size = 2, 32, 8
    layout = PagedCacheLayout(num_pages=64, page_size=page_size,
                              n_kv_heads=KVH, head_dim=D,
                              max_pages_per_seq=16)
    alloc = PageAllocator(layout)
    lens = {10: 25, 11: 7, 12: 64}
    for sid, l in lens.items():
        assert alloc.allocate(sid, l) is not None
    alloc.free(11)
    alloc.allocate(13, 30)  # reuses freed low pages (fragmented table)
    seq_ids = [10, 12, 13]

    pt, sl = page_table_from_allocator(alloc, seq_ids)
    B, H = len(seq_ids), 4
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(64, page_size, KVH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(64, page_size, KVH, D)), jnp.float32)
    out_k = paged_attention(q, kp, vp, pt, sl, use_kernel=True, interpret=True)
    out_r = paged_attention(q, kp, vp, pt, sl, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_ignores_stale_pages():
    """Content of pages not referenced by the table must not matter."""
    rng = np.random.default_rng(2)
    B, H, KVH, D, page_size = 1, 4, 2, 32, 8
    lens = [20]
    kp = jnp.asarray(rng.normal(size=(32, page_size, KVH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(32, page_size, KVH, D)), jnp.float32)
    pt = jnp.asarray(scatter_pages(rng, lens, 32, page_size))
    sl = jnp.asarray(lens, jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    out1 = paged_decode_attention(q, kp, vp, pt, sl, interpret=True)
    used = set(np.asarray(pt).ravel().tolist()) - {-1}
    unused = [p for p in range(32) if p not in used]
    kp2 = kp.at[jnp.asarray(unused)].set(99.0)
    vp2 = vp.at[jnp.asarray(unused)].set(-99.0)
    out2 = paged_decode_attention(q, kp2, vp2, pt, sl, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# grouped_matmul
# ---------------------------------------------------------------------------


def packed_input(rng, E, C, d, group_sizes, dtype):
    x = rng.normal(size=(E, C, d))
    valid = np.arange(C)[None, :] < np.asarray(group_sizes)[:, None]
    return jnp.asarray(x * valid[..., None], dtype)


@pytest.mark.parametrize(
    "E,C,d,f,blocks",
    [
        (4, 256, 128, 256, (64, 64, 128)),
        (2, 128, 256, 128, (128, 128, 128)),
        (8, 128, 64, 64, (32, 64, 64)),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_kernel_vs_ref(E, C, d, f, blocks, dtype):
    rng = np.random.default_rng(0)
    gs = jnp.asarray(rng.integers(0, C + 1, size=E), jnp.int32)
    x = packed_input(rng, E, C, d, gs, dtype)
    w = jnp.asarray(rng.normal(size=(E, d, f)), dtype)
    bc, bd, bf = blocks
    out = grouped_matmul(x, w, gs, block_c=bc, block_d=bd, block_f=bf,
                         interpret=True)
    ref = grouped_matmul_ref(x, w, gs)
    tol = dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else dict(
        rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


def test_grouped_matmul_empty_bins_cost_nothing_and_zero():
    rng = np.random.default_rng(1)
    E, C, d, f = 4, 128, 64, 64
    gs = jnp.asarray([0, 0, 64, 0], jnp.int32)
    x = packed_input(rng, E, C, d, gs, jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, d, f)), jnp.float32)
    out = grouped_matmul(x, w, gs, block_c=64, block_d=64, block_f=64,
                         interpret=True)
    # empty experts produce exactly zero
    assert np.abs(np.asarray(out)[[0, 1, 3]]).max() == 0.0
    assert np.abs(np.asarray(out)[2, 64:]).max() == 0.0


def test_expert_ffn_swiglu_matches_dense():
    rng = np.random.default_rng(2)
    E, C, d, f = 2, 128, 64, 128
    gs = jnp.asarray([128, 100], jnp.int32)
    x = packed_input(rng, E, C, d, gs, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, jnp.float32)
    out = expert_ffn_swiglu(x, wg, wu, wd, gs, use_kernel=True, interpret=True)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg)) * jnp.einsum(
        "ecd,edf->ecf", x, wu)
    dense = jnp.einsum("ecf,efd->ecd", h, wd)
    valid = (jnp.arange(C)[None, :] < gs[:, None])[..., None]
    dense = jnp.where(valid, dense, 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=2e-3, atol=2e-3)


def test_kernel_matches_model_flash_attention():
    """The Pallas kernel agrees with the model-side chunked flash attention
    (layers.flash_attention) — the two implementations the system actually
    swaps between."""
    from repro.models.layers import flash_attention

    rng = np.random.default_rng(6)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q, k, v = make_qkv(rng, B, S, H, KVH, D, jnp.float32)
    seg = jnp.asarray(random_packed_segments(rng, B, S))
    out_model = flash_attention(q, k, v, seg, seg, causal=True,
                                chunk_q=128, chunk_kv=128)
    out_kernel = packed_attention(q, k, v, seg, seg, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out_model), np.asarray(out_kernel), rtol=3e-5, atol=3e-5
    )
