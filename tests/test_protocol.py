"""The protocol model checker checks itself: extraction fixtures, the
exhaustive bounded model check, seeded mutations, and trace replay.

Four halves, mirroring the package:

1. **Extraction (R7)** — synthetic ``src/repro/runtime/...`` trees prove
   each extraction obligation fires (uncovered emit, stale declaration,
   mirror assignment without a declaration, manifest drift) and that a
   fully annotated tree extracts clean.
2. **Model check** — the *committed* manifest explores clean over every
   interleaving of the bounded configuration, and seeded mutations
   (dropping the requeue edge; harvesting before the drain) provably
   produce counterexample traces.  A model checker that stopped finding
   bugs would otherwise keep reporting "verified" forever.
3. **Conformance (R8)** — unit replays of synthetic event sequences
   (legal, out-of-order, duplicate completion, post-kill activity,
   in-flight at end-of-log) plus the CLI surfaces.
4. **Robustness** — match statements, walrus operators, and unparsable
   files never crash the analyzer; parse failures surface as findings
   alongside every rule, R7/R8 included.
"""

import json
from pathlib import Path

import pytest

from repro.analysis import run_analysis
from repro.analysis.model import RepoIndex
from repro.analysis.protocol import (
    BoundedConfig,
    PROTOCOL_MANIFEST_PATH,
    drop_transition,
    explore,
    extract_findings,
    extract_protocol,
    render_trace,
    replay_events,
)
from repro.analysis.protocol.__main__ import main as protocol_main
from repro.obs.__main__ import main as obs_main

REPO_ROOT = Path(__file__).resolve().parents[1]
COMMITTED = json.loads(
    (REPO_ROOT / PROTOCOL_MANIFEST_PATH).read_text(encoding="utf-8")
)


def _write_tree(root: Path, files: dict) -> Path:
    for rel, content in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content, encoding="utf-8")
    return root


def _messages(findings, rule="R7"):
    return [f.message for f in findings if f.rule == rule]


# ---------------------------------------------------------------------------
# Extraction (R7) fixtures
# ---------------------------------------------------------------------------

_FX_EVENTS = json.dumps({
    "version": 1,
    "events": {
        "msg.enqueued": {"fields": ["msg_id"]},
        "msg.requeued": {"fields": ["msg_id"]},
    },
})

_FX_MASTER_CLEAN = (
    "from .annotations import transition\n"
    "\n"
    '@transition("msg", "msg.enqueued", src="created", dst="enqueued")\n'
    "def push_back(bus, m):\n"
    '    bus.emit("msg.enqueued", msg_id=m.msg_id)\n'
    "\n"
    '@transition("msg", "msg.requeued", src="pulled", dst="requeued")\n'
    "def requeue(bus, m):\n"
    '    bus.emit("msg.requeued", msg_id=m.msg_id)\n'
)


def _extract(tmp_path):
    index = RepoIndex(tmp_path)
    return extract_protocol(index, tmp_path)


@pytest.mark.timeout(30)
def test_annotated_fixture_extracts_clean(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/master.py": _FX_MASTER_CLEAN,
    })
    manifest, findings = _extract(tmp_path)
    assert _messages(findings) == []
    msg = manifest["entities"]["msg"]
    events = {t["event"] for t in msg["transitions"]}
    assert events == {"msg.enqueued", "msg.requeued"}
    assert msg["initial"] == "created" and msg["terminal"] == ["completed"]


@pytest.mark.timeout(30)
def test_uncovered_emit_is_a_finding(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/master.py": (
            "def push_back(bus, m):\n"
            '    bus.emit("msg.enqueued", msg_id=m.msg_id)\n'
        ),
    })
    _, findings = _extract(tmp_path)
    msgs = _messages(findings)
    assert len(msgs) == 1
    assert "not covered by a @transition" in msgs[0]


@pytest.mark.timeout(30)
def test_stale_declaration_without_evidence_is_a_finding(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/master.py": (
            "from .annotations import transition\n"
            '@transition("msg", "msg.requeued", src="pulled", dst="requeued")\n'
            "def requeue(bus, m):\n"
            "    pass\n"
        ),
    })
    _, findings = _extract(tmp_path)
    msgs = _messages(findings)
    assert len(msgs) == 1
    assert "stale @transition" in msgs[0]


@pytest.mark.timeout(30)
def test_unknown_event_and_entity_are_findings(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/master.py": (
            "from .annotations import transition\n"
            '@transition("msg", "msg.vanished", src="a", dst="b")\n'
            "def a(bus, m):\n"
            '    bus.emit("msg.vanished", msg_id=1)\n'
            '@transition("ghost", "msg.enqueued", src="a", dst="b")\n'
            "def b(bus, m):\n"
            '    bus.emit("msg.enqueued", msg_id=1)\n'
        ),
    })
    _, findings = _extract(tmp_path)
    msgs = _messages(findings)
    assert any("is not registered" in m for m in msgs)
    assert any("entity 'ghost' is unknown" in m for m in msgs)


@pytest.mark.timeout(30)
def test_uncovered_mirror_assignment_is_a_finding(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/worker.py": (
            "def harvest(slot):\n"
            "    slot.state = WorkerState.OFF\n"
        ),
    })
    _, findings = _extract(tmp_path)
    msgs = _messages(findings)
    assert len(msgs) == 1
    assert "mirror assignment" in msgs[0] and "WorkerState.OFF" in msgs[0]


@pytest.mark.timeout(30)
def test_data_channel_read_outside_loop_only_is_a_finding(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/transport.py": (
            "def steal(self):\n"
            "    return self.data_q.get()\n"
        ),
    })
    _, findings = _extract(tmp_path)
    msgs = _messages(findings)
    assert len(msgs) == 1
    assert "single-consumer" in msgs[0]


@pytest.mark.timeout(30)
def test_drift_against_committed_manifest_is_a_finding(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/master.py": _FX_MASTER_CLEAN,
    })
    index = RepoIndex(tmp_path)
    manifest, _ = extract_protocol(index, tmp_path)

    # missing manifest first
    msgs = _messages(extract_findings(index, tmp_path))
    assert any("manifest is missing" in m for m in msgs)

    # committed == extracted → clean
    committed_file = tmp_path / PROTOCOL_MANIFEST_PATH
    committed_file.parent.mkdir(parents=True, exist_ok=True)
    committed_file.write_text(json.dumps(manifest), encoding="utf-8")
    assert _messages(extract_findings(index, tmp_path)) == []

    # tamper with a source-state set → drift
    tampered = json.loads(json.dumps(manifest))
    tampered["entities"]["msg"]["transitions"][0]["src"] = ["started"]
    committed_file.write_text(json.dumps(tampered), encoding="utf-8")
    msgs = _messages(extract_findings(index, tmp_path))
    assert len(msgs) == 1 and "protocol drift" in msgs[0]


@pytest.mark.timeout(120)
def test_r7_real_tree_extracts_clean_and_matches_manifest():
    findings = run_analysis(REPO_ROOT, rules=["R7"])
    details = "\n".join(f"{f.path}:{f.line}: {f.message}" for f in findings)
    assert findings == [], details


# ---------------------------------------------------------------------------
# The bounded model check
# ---------------------------------------------------------------------------


@pytest.mark.timeout(120)
def test_bounded_model_check_is_exhaustive_and_clean():
    result = explore(COMMITTED, BoundedConfig())
    assert result.ok, [v.message for v in result.violations]
    # the default 2-worker x 1-PE x 3-message x 1-kill configuration is
    # a real state space, not a trivially-empty walk
    assert result.states > 1500
    assert result.transitions > result.states


@pytest.mark.timeout(120)
def test_dropping_the_requeue_edge_produces_a_counterexample():
    mutated = drop_transition(COMMITTED, "msg.requeued")
    result = explore(mutated, BoundedConfig())
    assert not result.ok
    v = result.violations[0]
    assert v.invariant == "I1"
    assert "requeue" in v.message
    assert len(v.trace) >= 2
    rendered = render_trace(v)
    assert "kill" in rendered and "I1" in rendered


@pytest.mark.timeout(120)
def test_unsafe_harvest_order_produces_a_race_counterexample():
    result = explore(COMMITTED, BoundedConfig(), unsafe_harvest=True)
    assert not result.ok
    assert any(v.invariant == "I4" for v in result.violations)


@pytest.mark.timeout(120)
def test_mutated_manifest_fails_r7_through_run_analysis(tmp_path):
    """End to end: a committed manifest whose requeue edge is gone is
    caught by rule R7 as a model-check finding with a trace."""
    import shutil

    for rel in ("src/repro/runtime/master.py",
                "src/repro/runtime/worker.py",
                "src/repro/runtime/lifecycle.py",
                "src/repro/runtime/transport.py",
                "src/repro/runtime/annotations.py",
                "src/repro/core/sim.py",
                "src/repro/obs/event_manifest.json"):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO_ROOT / rel, dst)
    mutated = drop_transition(COMMITTED, "msg.requeued")
    committed_file = tmp_path / PROTOCOL_MANIFEST_PATH
    committed_file.parent.mkdir(parents=True, exist_ok=True)
    committed_file.write_text(json.dumps(mutated), encoding="utf-8")

    findings = _messages(run_analysis(tmp_path, rules=["R7"]))
    assert any("model-check violation [I1]" in m for m in findings)
    # the extracted machines also drifted from the mutated manifest
    assert any("protocol drift" in m for m in findings)


# ---------------------------------------------------------------------------
# Trace conformance (R8)
# ---------------------------------------------------------------------------


def _ev(ev, seq, **fields):
    return {"ev": ev, "seq": seq, "t": float(seq), **fields}


def _legal_sequence():
    return [
        _ev("worker.boot", 0, worker=0),
        _ev("worker.active", 1, worker=0),
        _ev("pe.spawn", 2, worker=0, pe=0),
        _ev("msg.enqueued", 3, msg_id=0),
        # no explicit PE-ready event: the replay must promote the PE
        # starting→idle over the internal ε-edge before idle→busy
        _ev("msg.pulled", 4, msg_id=0, worker=0, pe=0),
        _ev("msg.started", 5, msg_id=0, worker=0, pe=0),
        _ev("msg.completed", 6, msg_id=0, worker=0, pe=0),
        _ev("pe.exit", 7, worker=0, pe=0),
        _ev("worker.deactivate", 8, worker=0),
    ]


@pytest.mark.timeout(30)
def test_replay_accepts_a_legal_sequence_with_epsilon_promotion():
    summary = replay_events(_legal_sequence(), COMMITTED)
    assert summary.ok, [str(v) for v in summary.violations]
    assert summary.completed == 1 and summary.backlog == 0


@pytest.mark.timeout(30)
def test_replay_flags_pull_without_enqueue():
    events = [
        _ev("worker.boot", 0, worker=0),
        _ev("worker.active", 1, worker=0),
        _ev("pe.spawn", 2, worker=0, pe=0),
        _ev("msg.pulled", 3, msg_id=7, worker=0, pe=0),
    ]
    summary = replay_events(events, COMMITTED, strict_end=False)
    assert any(
        v.entity == "msg" and "illegal from state 'created'" in v.message
        for v in summary.violations
    )


@pytest.mark.timeout(30)
def test_replay_flags_duplicate_completion():
    events = _legal_sequence()
    events.insert(7, _ev("msg.completed", 99, msg_id=0, worker=0, pe=0))
    summary = replay_events(events, COMMITTED, strict_end=False)
    assert any("duplicate completion" in v.message
               for v in summary.violations)


@pytest.mark.timeout(30)
def test_replay_flags_activity_after_a_kill():
    events = [
        _ev("worker.boot", 0, worker=0),
        _ev("worker.active", 1, worker=0),
        _ev("pe.spawn", 2, worker=0, pe=0),
        _ev("msg.enqueued", 3, msg_id=0),
        _ev("msg.pulled", 4, msg_id=0, worker=0, pe=0),
        _ev("worker.kill", 5, worker=0),
        _ev("msg.requeued", 6, msg_id=0),
        # a SIGKILLed slot must never produce further events
        _ev("worker.active", 7, worker=0),
    ]
    summary = replay_events(events, COMMITTED, strict_end=False)
    assert any(
        v.entity == "worker" and "failed worker instance" in v.message
        for v in summary.violations
    )
    # requeued-at-end is backlog, not a violation
    strict = replay_events(events[:-1], COMMITTED)
    assert strict.ok and strict.backlog == 1 and strict.requeued == 1


@pytest.mark.timeout(30)
def test_replay_flags_in_flight_message_at_end_of_log():
    events = [
        _ev("worker.boot", 0, worker=0),
        _ev("worker.active", 1, worker=0),
        _ev("pe.spawn", 2, worker=0, pe=0),
        _ev("msg.enqueued", 3, msg_id=0),
        _ev("msg.pulled", 4, msg_id=0, worker=0, pe=0),
    ]
    summary = replay_events(events, COMMITTED)
    assert any("delivery lost" in v.message for v in summary.violations)
    # lenient end: truncated logs are allowed to stop mid-flight
    assert replay_events(events, COMMITTED, strict_end=False).ok


@pytest.mark.timeout(30)
def test_r8_through_run_analysis(tmp_path):
    good = tmp_path / "good" / "events.jsonl"
    good.parent.mkdir(parents=True)
    good.write_text(
        "\n".join(json.dumps(e) for e in _legal_sequence()) + "\n",
        encoding="utf-8",
    )
    bad = tmp_path / "bad" / "events.jsonl"
    bad.parent.mkdir(parents=True)
    events = _legal_sequence()
    events.insert(7, _ev("msg.completed", 99, msg_id=0, worker=0, pe=0))
    bad.write_text(
        "not json at all\n"
        + "\n".join(json.dumps(e) for e in events) + "\n",
        encoding="utf-8",
    )

    # R8 without logs is a clean no-op
    assert run_analysis(REPO_ROOT, rules=["R8"]) == []
    assert run_analysis(REPO_ROOT, rules=["R8"],
                        events=[good.parent]) == []
    msgs = _messages(
        run_analysis(REPO_ROOT, rules=["R8"], events=[tmp_path]), "R8"
    )
    assert any("duplicate completion" in m for m in msgs)
    assert any("not valid JSON" in m for m in msgs)


# ---------------------------------------------------------------------------
# CLI surfaces
# ---------------------------------------------------------------------------


@pytest.mark.timeout(120)
def test_protocol_cli_extract_and_check(capsys):
    assert protocol_main(
        ["--root", str(REPO_ROOT), "extract", "--diff"]) == 0
    assert protocol_main(["--root", str(REPO_ROOT), "check"]) == 0
    out = capsys.readouterr().out
    assert "all delivery invariants hold" in out

    rc = protocol_main(
        ["--root", str(REPO_ROOT), "check", "--mutate", "msg.requeued"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "[I1]" in out and "counterexample" in out


@pytest.mark.timeout(30)
def test_protocol_and_obs_conformance_clis(tmp_path, capsys):
    log = tmp_path / "events.jsonl"
    log.write_text(
        "\n".join(json.dumps(e) for e in _legal_sequence()) + "\n",
        encoding="utf-8",
    )
    assert protocol_main(
        ["--root", str(REPO_ROOT), "conformance", str(tmp_path)]) == 0
    capsys.readouterr()
    assert obs_main(["conformance", str(log)]) == 0
    assert "0 violation(s)" in capsys.readouterr().out

    events = _legal_sequence()[:-4]  # ends with msg still started
    log.write_text(
        "\n".join(json.dumps(e) for e in events) + "\n", encoding="utf-8")
    assert obs_main(["conformance", str(log)]) == 1
    capsys.readouterr()
    assert obs_main(["conformance", "--lenient-end", str(log)]) == 0
    capsys.readouterr()


# ---------------------------------------------------------------------------
# Robustness: modern syntax and unparsable files
# ---------------------------------------------------------------------------


@pytest.mark.timeout(30)
def test_match_and_walrus_syntax_are_analyzed_not_skipped(tmp_path):
    _write_tree(tmp_path, {
        "src/repro/core/modern.py": (
            "import time\n"
            "def f(x):\n"
            "    match x:\n"
            "        case 1:\n"
            "            if (y := time.time()):\n"
            "                return y\n"
            "        case _:\n"
            "            return 0\n"
        ),
    })
    msgs = _messages(run_analysis(tmp_path, rules=["R5"]), "R5")
    assert len(msgs) == 1 and "wall-clock" in msgs[0]


@pytest.mark.timeout(30)
def test_unparsable_protocol_module_surfaces_for_r7_and_r8(tmp_path):
    import shutil

    _write_tree(tmp_path, {
        "src/repro/obs/event_manifest.json": _FX_EVENTS,
        "src/repro/runtime/master.py": "def oops(:\n",
    })
    committed_file = tmp_path / PROTOCOL_MANIFEST_PATH
    committed_file.parent.mkdir(parents=True, exist_ok=True)
    shutil.copy(REPO_ROOT / PROTOCOL_MANIFEST_PATH, committed_file)

    log = tmp_path / "events.jsonl"
    log.write_text(
        "\n".join(json.dumps(e) for e in _legal_sequence()) + "\n",
        encoding="utf-8",
    )
    for rules in (["R7"], ["R8"]):
        found = run_analysis(tmp_path, rules=rules, events=[log])
        assert any(
            f.rule == "parse" and f.path == "src/repro/runtime/master.py"
            for f in found
        ), f"parse failure invisible under rules={rules}"
