"""Vector bin-packing: the packer family, the factory registry, and the
allocator's multi-resource packing run (pre-filled vector bins, per-dimension
headroom, dominant-dimension lower bound, idle-buffer interaction)."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Resources
from repro.core.allocator import AllocatorConfig, BinPackingManager, idle_buffer
from repro.core.binpack import (
    DominantFit,
    VectorBestFit,
    VectorBin,
    VectorFirstFit,
    VectorFirstFitDecreasing,
    VectorItem,
    VectorNextFit,
    is_vector_policy,
    lower_bound,
    make_packer,
    vector_equivalent,
    vector_lower_bound,
)
from repro.core.queues import HostRequest


# ---------------------------------------------------------------------------
# Factory / registry (satellite: actionable unknown-policy errors)
# ---------------------------------------------------------------------------


def test_make_packer_unknown_lists_scalar_and_vector_names():
    with pytest.raises(ValueError) as ei:
        make_packer("second-fit")
    msg = str(ei.value)
    assert "unknown packing algorithm" in msg
    assert "first-fit" in msg and "best-fit" in msg          # scalar family
    assert "vector-first-fit" in msg and "dominant-fit" in msg  # vector family


def test_make_packer_resolves_vector_names():
    assert isinstance(make_packer("vector-first-fit"), VectorFirstFit)
    assert isinstance(make_packer("vector-best-fit"), VectorBestFit)
    assert isinstance(make_packer("vector-next-fit"), VectorNextFit)
    assert isinstance(make_packer("dominant-fit"), DominantFit)
    assert isinstance(make_packer("vector-ffd"), VectorFirstFitDecreasing)
    # float capacity normalizes to a 1-vector
    assert make_packer("vector-first-fit", capacity=1.0).capacity == (1.0,)


def test_is_vector_policy_and_equivalents():
    assert is_vector_policy("vector-best-fit")
    assert not is_vector_policy("best-fit")
    assert vector_equivalent("first-fit") == "vector-first-fit"
    assert vector_equivalent("first-fit-tree") == "vector-first-fit"
    assert vector_equivalent("best-fit") == "vector-best-fit"
    assert vector_equivalent("worst-fit") == "dominant-fit"
    assert vector_equivalent("vector-ffd") == "vector-ffd"  # already vector
    with pytest.raises(ValueError, match="no vector equivalent"):
        vector_equivalent("harmonic")


# ---------------------------------------------------------------------------
# Vector packers
# ---------------------------------------------------------------------------


def test_vector_bin_prefill():
    b = VectorBin((1.0, 1.0), used=(0.9, 0.2))
    assert b.free == (pytest.approx(0.1), pytest.approx(0.8))
    assert not b.fits((0.2, 0.1))  # blocked by dim 0
    assert b.fits((0.1, 0.5))
    with pytest.raises(ValueError):
        VectorBin((1.0, 1.0), used=(0.5,))  # dims mismatch


def test_vector_first_fit_prefilled_bins():
    bins = [VectorBin((1.0, 1.0), used=(0.2, 0.95)),
            VectorBin((1.0, 1.0), used=(0.5, 0.1))]
    vff = VectorFirstFit((1.0, 1.0), bins=bins)
    # fits bin 0 by cpu but not by mem -> lands on bin 1
    assert vff.pack_one(VectorItem((0.3, 0.3))) == 1
    # fits neither -> opens bin 2
    assert vff.pack_one(VectorItem((0.9, 0.0))) == 2


def test_vector_best_fit_picks_tightest():
    vbf = VectorBestFit((1.0, 1.0))
    vbf.bins = [VectorBin((1.0, 1.0), used=(0.1, 0.1)),
                VectorBin((1.0, 1.0), used=(0.6, 0.7))]
    # both fit; bin 1 leaves the smaller residual
    assert vbf.pack_one(VectorItem((0.2, 0.2))) == 1


def test_dominant_fit_spreads_on_items_bottleneck():
    df = DominantFit((1.0, 1.0))
    df.bins = [VectorBin((1.0, 1.0), used=(0.1, 0.8)),
               VectorBin((1.0, 1.0), used=(0.5, 0.2))]
    # item is mem-dominant: picks the bin with most free *mem* (bin 1)
    assert df.pack_one(VectorItem((0.1, 0.2))) == 1
    # cpu-dominant item picks the bin with most free cpu (bin 0)
    assert df.pack_one(VectorItem((0.3, 0.05))) == 0


def test_vector_next_fit_only_last_bin():
    vnf = VectorNextFit((1.0, 1.0))
    assert vnf.pack_one(VectorItem((0.6, 0.1))) == 0
    assert vnf.pack_one(VectorItem((0.6, 0.1))) == 1  # bin 0 not revisited
    assert vnf.pack_one(VectorItem((0.1, 0.1))) == 1


def test_vector_ffd_sorts_by_dominant_share():
    items = [VectorItem((0.2, 0.2)), VectorItem((0.1, 0.9)),
             VectorItem((0.6, 0.1)), VectorItem((0.3, 0.7))]
    ffd = VectorFirstFitDecreasing((1.0, 1.0))
    res = ffd.pack(items)
    assert len(res.assignments) == 4
    # every item placed within capacity
    for b in ffd.bins:
        assert all(u <= c + 1e-9 for u, c in zip(b.used, b.capacity, strict=True))
    # FFD packs no more bins than online first-fit on the same items
    vff = VectorFirstFit((1.0, 1.0))
    vff.pack(items)
    assert len(ffd.bins) <= len(vff.bins)


def test_oversized_vector_item_raises():
    vff = VectorFirstFit((0.5, 1.0))
    with pytest.raises(ValueError, match="exceed bin capacity"):
        vff.pack_one(VectorItem((0.8, 0.1)))


def test_lower_bound_edge_cases():
    """Edges surfaced by the packer-equivalence suite: empty input needs 0
    bins, a tiny-but-real total still needs 1 (the epsilon slack must not
    round it to 0), an oversized single item raises the bound past 1, and
    a non-positive capacity is a caller error, not a ZeroDivisionError."""
    assert lower_bound([]) == 0
    assert lower_bound([1e-12]) == 1
    assert lower_bound([1.5], 1.0) == 2
    assert lower_bound([0.3], 0.3) == 1  # exact fit stays at 1
    with pytest.raises(ValueError, match="must be positive"):
        lower_bound([0.5], 0.0)
    with pytest.raises(ValueError, match="must be positive"):
        lower_bound([0.5], -1.0)


def test_vector_lower_bound_edge_cases():
    assert vector_lower_bound([(1e-12, 0.0)], (1.0, 1.0)) == 1
    assert vector_lower_bound([(0.5, 2.5)], (1.0, 1.0)) == 3  # oversize item
    # items may carry *fewer* dims than the capacity (zero demand there)...
    assert vector_lower_bound([(0.5,)], (1.0, 1.0)) == 1
    # ...but never more: extra demand must not silently vanish
    with pytest.raises(ValueError, match="more dimensions"):
        vector_lower_bound([(0.1, 0.2, 0.3)], (1.0, 1.0))
    with pytest.raises(ValueError, match="must be positive"):
        vector_lower_bound([(0.1, 0.1)], (1.0, 0.0))


def test_vector_lower_bound_is_dominant_dimension():
    sizes = [(0.5, 0.1), (0.5, 0.1), (0.5, 0.1)]  # cpu 1.5, mem 0.3
    assert vector_lower_bound(sizes, (1.0, 1.0)) == 2
    sizes = [(0.1, 0.9)] * 4  # mem total 3.6 dominates
    assert vector_lower_bound(sizes, (1.0, 1.0)) == 4
    assert vector_lower_bound([], (1.0, 1.0)) == 0


@given(
    st.lists(
        st.tuples(
            st.floats(min_value=0.01, max_value=1.0),
            st.floats(min_value=0.0, max_value=1.0),
        ),
        min_size=1,
        max_size=80,
    ),
    st.sampled_from(["vector-first-fit", "vector-best-fit",
                     "vector-next-fit", "dominant-fit", "vector-ffd"]),
)
@settings(max_examples=100, deadline=None)
def test_vector_packers_never_overflow_and_beat_lower_bound(pairs, name):
    packer = make_packer(name, capacity=(1.0, 1.0))
    items = [VectorItem(p) for p in pairs]
    res = packer.pack(items)
    for b in packer.bins:
        assert all(u <= c + 1e-9 for u, c in zip(b.used, b.capacity, strict=True))
    assert res.num_bins >= vector_lower_bound(pairs, (1.0, 1.0))
    assert len(res.assignments) == len(items)


# ---------------------------------------------------------------------------
# Allocator: vector packing runs
# ---------------------------------------------------------------------------


def req(cpu, ttl=3, **aux):
    return HostRequest("img", size_estimate=Resources.of(cpu=cpu, **aux),
                       ttl=ttl)


def test_vector_run_prefilled_worker_bins():
    mgr = BinPackingManager(AllocatorConfig(keep_idle_buffer=False))
    loads = [Resources.of(cpu=0.2, mem=0.9), Resources.of(cpu=0.0, mem=0.0)]
    reqs = [req(0.1, mem=0.3) for _ in range(3)]
    run = mgr.run(0.0, reqs, worker_loads=loads)
    # worker 0 has mem free 0.1 < 0.3 -> everything lands on worker 1
    assert [r.target_worker for r in run.placements] == [1, 1, 1]
    assert run.num_bins == 2
    assert isinstance(run.scheduled_load[0], Resources)


def test_vector_run_full_in_one_dimension_with_slack_in_another():
    """Satellite: a worker exactly full in one dimension opens a new bin
    even though another dimension has plenty of slack."""
    mgr = BinPackingManager(AllocatorConfig(keep_idle_buffer=False))
    loads = [Resources.of(cpu=0.2, mem=1.0)]  # mem exactly full, cpu slack
    run = mgr.run(0.0, [req(0.1, mem=0.1)], worker_loads=loads)
    assert run.placements[0].target_worker == 1  # not worker 0
    assert run.num_bins == 2
    # CPU-only demand still fits the mem-full worker
    run2 = mgr.run(1.0, [req(0.5, mem=0.0)], worker_loads=loads)
    assert run2.placements[0].target_worker == 0


def test_vector_headroom_applies_per_dimension():
    mgr = BinPackingManager(
        AllocatorConfig(keep_idle_buffer=False, headroom=0.1)
    )
    # worker at mem 0.85: item mem clamped to 0.9 but the *bin* keeps full
    # capacity, so a 0.2-mem item (free 0.15) still fits; a 0.2-mem item on
    # a 0.95-mem worker does not.
    run = mgr.run(0.0, [req(0.1, mem=1.0)], worker_loads=[])
    # oversize estimate clamped to capacity - headroom in every dimension
    assert run.scheduled_load[0].get("mem") == pytest.approx(0.9)
    run2 = mgr.run(1.0, [req(0.1, mem=0.2)],
                   worker_loads=[Resources.of(cpu=0.1, mem=0.95)])
    assert run2.placements[0].target_worker == 1


def test_vector_run_idle_buffer_added_on_top():
    mgr = BinPackingManager(AllocatorConfig(keep_idle_buffer=True))
    run = mgr.run(0.0, [req(0.3, mem=0.8), req(0.3, mem=0.8)],
                  worker_loads=[])
    # two mem-heavy items cannot share a bin
    assert run.num_bins == 2
    assert run.target_workers == 2 + idle_buffer(2)


def test_vector_run_dominant_dimension_ideal_bins():
    mgr = BinPackingManager(AllocatorConfig(keep_idle_buffer=False))
    reqs = [req(0.1, mem=0.6) for _ in range(4)]  # mem 2.4 vs cpu 0.4
    run = mgr.run(0.0, reqs, worker_loads=[])
    assert run.ideal_bins == 3  # ceil(2.4)
    assert run.num_bins == 4    # 0.6-mem items don't pair up


def test_vector_run_triggered_by_policy_name_on_scalar_loads():
    """A vector policy with plain float loads/sizes still works (1-D)."""
    mgr = BinPackingManager(
        AllocatorConfig(algorithm="vector-first-fit", keep_idle_buffer=False)
    )
    reqs = [HostRequest("a", size_estimate=0.5) for _ in range(3)]
    run = mgr.run(0.0, reqs, worker_loads=[0.8, 0.0])
    # identical placement to the scalar first-fit run in test_irm_components
    assert [r.target_worker for r in run.placements] == [1, 1, 2]


def test_scenario_scalar_vs_vector_policy_parity():
    """1-D Resources end-to-end: a vector policy on a scalar scenario
    reproduces the scalar First-Fit time series bit-for-bit."""
    import numpy as np

    from repro.scenarios import get_scenario, run_scenario

    scn = get_scenario("multi-tenant")
    kwargs = dict(n_runs=1, stream_overrides=scn.smoke_overrides,
                  t_max=scn.smoke_t_max)
    a = run_scenario(scn, policy="first-fit", **kwargs).final
    b = run_scenario(scn, policy="vector-first-fit", **kwargs).final
    np.testing.assert_array_equal(a.scheduled_cpu, b.scheduled_cpu)
    np.testing.assert_array_equal(a.measured_cpu, b.measured_cpu)
    np.testing.assert_array_equal(a.queue_len, b.queue_len)
    assert a.makespan == b.makespan
