"""Smoke coverage for the ``python -m repro.scenarios.run`` entry point.

One fast subprocess run pins the actual module invocation (import graph,
argparse wiring, exit codes); the in-process cases cover the CLI surface —
listing, sweeps, error paths — without paying process startup per case.
"""

import os
import subprocess
import sys

import pytest

from repro.scenarios.run import main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cli(*argv):
    return main(list(argv))


def test_module_entry_point_smoke():
    """The real ``python -m`` invocation: single scenario, tiny horizon,
    serial (--jobs 1)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "repro.scenarios.run", "synthetic",
         "--smoke", "--jobs", "1", "--t-max", "240"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    assert "scenario 'synthetic'" in proc.stdout
    assert "makespan_s" in proc.stdout


def test_cli_list_shows_catalogue(capsys):
    assert run_cli("--list") == 0
    out = capsys.readouterr().out
    for name in ("synthetic", "microscopy", "microscopy-mem", "mixed-accel"):
        assert name in out


def test_cli_list_shows_dims_and_policy_family(capsys):
    """--list prints each scenario's resource dims and its policy family."""
    assert run_cli("--list") == 0
    out = capsys.readouterr().out
    header, *rows = out.splitlines()
    assert "dims" in header and "policies" in header
    by_name = {r.split()[0]: r for r in rows if r and not r.startswith("-")}
    # scalar scenario: cpu-only dims, Any-Fit family
    assert "cpu " in by_name["synthetic"] or "cpu\t" in by_name["synthetic"]
    assert "any-fit" in by_name["synthetic"]
    # vector scenarios: their extra dimension and the vector family
    assert "cpu+mem" in by_name["microscopy-mem"]
    assert "vector" in by_name["microscopy-mem"]
    assert "cpu+accel" in by_name["mixed-accel"]


@pytest.mark.timeout(120)
def test_cli_live_backend_smoke(capsys):
    """--backend live drives the asyncio runtime through the same CLI."""
    assert run_cli("microscopy", "--smoke", "--backend", "live",
                   "--time-scale", "0.005", "--jobs", "1") == 0
    out = capsys.readouterr().out
    assert "backend 'live'" in out
    assert "makespan_s" in out


def test_cli_unknown_scenario_exits_2(capsys):
    assert run_cli("no-such-scenario") == 2
    assert "unknown scenario" in capsys.readouterr().err


def test_cli_unknown_policy_exits_2(capsys):
    assert run_cli("synthetic", "--smoke", "--policy", "no-such-fit") == 2
    assert "unknown packing algorithm" in capsys.readouterr().err


def test_cli_vector_scenario_smoke(capsys):
    assert run_cli("microscopy-mem", "--smoke", "--jobs", "1") == 0
    out = capsys.readouterr().out
    assert "mean_scheduled_mem_active" in out
    assert "bottleneck_dim: mem" in out


def test_cli_writes_artifacts(tmp_path, capsys):
    assert run_cli("synthetic", "--smoke", "--jobs", "1",
                   "--t-max", "240", "--out", str(tmp_path)) == 0
    capsys.readouterr()
    files = {p.name for p in tmp_path.iterdir()}
    assert "synthetic_summary.json" in files
    assert any(f.endswith(".csv") for f in files)
