"""End-to-end multi-resource scenarios: the vector IRM driving the cluster
sim (and the serving adapter) on the registered memory-bound and
mixed-accelerator workloads, plus equivalence of the per-dimension time
series between the indexed and reference simulations."""

import dataclasses

import numpy as np
import pytest

from repro.core import simulate
from repro.core.sim_reference import simulate_reference
from repro.scenarios import (
    VECTOR_POLICIES,
    get_scenario,
    policies_for,
    run_scenario,
    sweep_policies,
)

VECTOR_SCENARIOS = ("microscopy-mem", "mixed-accel")


def smoke_kwargs(scn):
    return dict(n_runs=1, stream_overrides=scn.smoke_overrides,
                t_max=scn.smoke_t_max)


# ---------------------------------------------------------------------------
# Registered scenarios run end-to-end with a vector policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", VECTOR_SCENARIOS)
def test_vector_scenario_completes_and_meets_expectations(name):
    scn = get_scenario(name)
    result = run_scenario(scn, **smoke_kwargs(scn))
    assert result.policy == "vector-first-fit"  # the scenario's IRM config
    assert result.ok, result.expectations
    res = result.final
    assert res.completed == res.total > 0
    # per-dimension records exist and never exceed worker capacity
    assert res.scheduled_res is not None and res.measured_res is not None
    D = len(res.resource_dims)
    assert res.scheduled_res.shape == res.measured_cpu.shape + (D,)
    assert (res.scheduled_res <= 1.0 + 1e-9).all()
    # the recorded scalar CPU series is exactly dimension 0
    np.testing.assert_array_equal(res.scheduled_cpu,
                                  res.scheduled_res[:, :, 0])
    np.testing.assert_array_equal(res.measured_cpu,
                                  res.measured_res[:, :, 0])


def test_memory_bound_packing_beats_cpu_only_density():
    """The point of the vector API: on microscopy-mem a worker hosts only
    as many concurrent analyses as its *memory* fits (~2-3), far below the
    8 its CPU alone would allow."""
    scn = get_scenario("microscopy-mem")
    res = run_scenario(scn, **smoke_kwargs(scn)).final
    d = res.resource_dims.index("mem")
    mem = res.measured_res[:, :, d]
    cpu = res.measured_res[:, :, 0]
    assert mem.max() > 0.6          # memory actually fills workers
    assert cpu.max() < 0.7          # CPU never comes close to full
    # rigid dimension: measured memory stays within capacity everywhere
    assert (mem <= 1.0 + 1e-9).all()


def test_mixed_accel_scenario_interleaves_tenants():
    scn = get_scenario("mixed-accel")
    res = run_scenario(scn, **smoke_kwargs(scn)).final
    d = res.resource_dims.index("accel")
    accel = res.scheduled_res[:, :, d]
    cpu = res.scheduled_res[:, :, 0]
    # both dimensions carry real load, and some worker holds both at once
    assert accel.max() > 0.3 and cpu.max() > 0.4
    assert ((accel > 0.2) & (cpu > 0.3)).any()


# ---------------------------------------------------------------------------
# Policy sweeps over the vector family (the CLI's --policy all path)
# ---------------------------------------------------------------------------


def test_policies_for_picks_the_right_family():
    assert tuple(policies_for("microscopy-mem")) == VECTOR_POLICIES
    assert tuple(policies_for("mixed-accel")) == VECTOR_POLICIES
    assert "first-fit" in policies_for("synthetic")
    assert "vector-first-fit" not in policies_for("synthetic")


@pytest.mark.parametrize("name", VECTOR_SCENARIOS)
def test_sweep_policies_over_vector_family(name):
    """Acceptance: both multi-resource scenarios run end-to-end through
    sweep_policies with vector packing policies."""
    scn = get_scenario(name)
    policies = ("vector-first-fit", "vector-best-fit", "dominant-fit",
                "vector-ffd")
    results = sweep_policies(scn, policies, jobs=1, **smoke_kwargs(scn))
    assert list(results) == list(policies)
    for policy, result in results.items():
        assert result.policy == policy
        assert result.ok, (policy, result.expectations)
        assert result.final.completed == result.final.total
        assert (result.final.scheduled_res <= 1.0 + 1e-9).all()


def test_scalar_policy_auto_promotes_on_vector_scenario():
    """A scalar policy name on a multi-resource scenario transparently uses
    its vector generalization (first-fit-tree -> vector-first-fit)."""
    scn = get_scenario("microscopy-mem")
    a = run_scenario(scn, policy="first-fit-tree", **smoke_kwargs(scn)).final
    b = run_scenario(scn, policy="vector-first-fit", **smoke_kwargs(scn)).final
    np.testing.assert_array_equal(a.scheduled_res, b.scheduled_res)
    assert a.makespan == b.makespan


# ---------------------------------------------------------------------------
# Indexed sim == reference sim on the per-dimension series
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", VECTOR_SCENARIOS)
def test_vector_dimension_series_match_reference(name):
    """test_sim_equivalence pins the scalar fields; this pins the new
    per-dimension arrays between the two simulation implementations."""
    scn = get_scenario(name)
    cfg = dataclasses.replace(scn.sim_config(), t_max=scn.smoke_t_max)
    ov = scn.smoke_overrides
    a = simulate(scn.make_stream(0, **ov), cfg)
    b = simulate_reference(scn.make_stream(0, **ov), cfg)
    assert a.resource_dims == b.resource_dims == cfg.resource_dims
    np.testing.assert_array_equal(a.measured_res, b.measured_res)
    np.testing.assert_array_equal(a.scheduled_res, b.scheduled_res)


def test_persistent_irm_carries_scalar_profile_onto_vector_cluster():
    """Regression: the paper's cross-run profiler persistence must survive a
    scalar run followed by a multi-resource run on the same IRM (stale float
    samples used to crash the vector load predictor)."""
    from repro.core import IRM, IRMConfig
    from repro.scenarios import usecase_workload

    irm = IRM(IRMConfig())
    scalar_scn = get_scenario("microscopy")
    cfg = dataclasses.replace(scalar_scn.sim_config(), t_max=600.0)
    res = simulate(usecase_workload(
        seed=0, n_images=20, duration_range=(4.0, 8.0),
        image="haste/cellprofiler-bigimg:3.1.9",
    ), cfg, irm=irm)
    assert res.completed == res.total

    scn = get_scenario("microscopy-mem")  # same image name, now with mem
    vcfg = dataclasses.replace(scn.sim_config(), t_max=scn.smoke_t_max)
    vres = simulate(scn.make_stream(0, **scn.smoke_overrides), vcfg, irm=irm)
    assert vres.completed == vres.total
    assert (vres.scheduled_res <= 1.0 + 1e-9).all()


# ---------------------------------------------------------------------------
# Serving adapter: resource dimensions map onto replica dimensions
# ---------------------------------------------------------------------------


def test_stream_to_requests_maps_mem_to_prompt_and_accel_to_decode():
    from repro.scenarios import Message, Stream, stream_to_requests

    plain = Message(image="a", duration=10.0)
    heavy = Message(image="a", duration=10.0, resources={"mem": 0.5})
    accel = Message(image="a", duration=10.0, resources={"accel": 0.5})
    sched = stream_to_requests(Stream(batches=[(0.0, [plain, heavy, accel])]))
    p, h, a = (r for _, r in sched)
    assert h.prompt_len > p.prompt_len          # memory -> bigger KV demand
    assert h.max_new_tokens == p.max_new_tokens
    assert a.max_new_tokens > p.max_new_tokens  # accel -> more decode work
    assert a.prompt_len == p.prompt_len


def test_serving_backend_drains_vector_scenario():
    from repro.scenarios import run_serving_scenario

    scn = get_scenario("microscopy-mem")
    summary = run_serving_scenario(
        scn, stream_overrides=scn.smoke_overrides, t_max=600.0,
    )
    assert summary["completed"] == summary["submitted"] > 0
    assert summary["peak_replicas"] >= 1
