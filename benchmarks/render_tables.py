"""Render the EXPERIMENTS.md §Roofline tables and build dryrun_opt.json.

Merges the per-layout sweeps (tp baseline, fsdp train/prefill, serve
decode), picks the best layout per cell (minimum roofline-bound step time),
writes ``results/dryrun_opt.json``, and prints the two markdown tables.

Usage:
  PYTHONPATH=src python -m benchmarks.render_tables
"""

from __future__ import annotations

import json
import os

from .common import RESULTS_DIR

ARCH_ORDER = [
    "jamba-v0.1-52b", "qwen3-moe-30b-a3b", "grok-1-314b", "deepseek-67b",
    "olmo-1b", "qwen2-72b", "qwen3-8b", "internvl2-1b", "xlstm-125m",
    "seamless-m4t-medium",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(name):
    path = os.path.join(RESULTS_DIR, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [r for r in json.load(f) if "error" not in r]


def key(r):
    return (r["arch"], r["shape"], r["mesh"])


def fmt(x):
    if x == 0:
        return "0"
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.1f}"
    if x >= 0.01:
        return f"{x:.3f}"
    return f"{x:.1e}"


def table(rows, with_layout=False):
    hdr = "| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | dominant | useful | MFU bound |"
    sep = "|---|---|---|---|---|---|---|---|---|"
    if with_layout:
        hdr += " layout |"
        sep += "---|"
    out = [hdr, sep]
    for r in rows:
        line = (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt(r['t_compute_s'])} | {fmt(r['t_memory_s'])} "
            f"| {fmt(r['t_collective_s'])} | {r['dominant']} "
            f"| {r['useful_flops_fraction']:.2f} "
            f"| {r['model_flops_util']:.4f} |"
        )
        if with_layout:
            line += f" {r.get('layout', 'tp')} |"
        out.append(line)
    return "\n".join(out)


def sort_rows(rows):
    return sorted(rows, key=lambda r: (
        ARCH_ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"]), r["mesh"]
    ))


def main() -> None:
    base = {key(r): r for r in load("dryrun_baseline.json")}
    cand = {}
    for r in load("dryrun_baseline.json"):
        cand.setdefault(key(r), []).append(r)
    for name in ("dryrun_fsdp.json", "dryrun_serve.json"):
        for r in load(name):
            cand.setdefault(key(r), []).append(r)

    opt = []
    for rows in cand.values():
        best = min(rows, key=lambda r: r["roofline_step_s"])
        opt.append(best)
    opt = sort_rows(opt)
    with open(os.path.join(RESULTS_DIR, "dryrun_opt.json"), "w") as f:
        json.dump(opt, f, indent=1)

    print("### Baseline (`tp`) — all cells\n")
    print(table(sort_rows(list(base.values()))))
    print("\n\n### Optimized (best layout per cell)\n")
    print(table(opt, with_layout=True))

    # summary stats
    both = [(base[key(r)], r) for r in opt if key(r) in base]
    speedups = [b["roofline_step_s"] / o["roofline_step_s"] for b, o in both]
    import statistics

    print(f"\ncells: {len(opt)}; median step-bound speedup "
          f"{statistics.median(speedups):.2f}x; "
          f"max {max(speedups):.1f}x; "
          f"best MFU bound {max(r['model_flops_util'] for r in opt):.3f}")


if __name__ == "__main__":
    main()
