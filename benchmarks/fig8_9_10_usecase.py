"""Paper Figs. 8, 9, 10: the real microscopy use case (Section VI-B).

The 767-image CellProfiler batch is streamed 10 times with randomized order
(the profiler persists across runs, as in the paper: "HIO was started fresh
for the first run and remained running for all subsequent runs").  All
figures are produced from the 10th run, as in the paper.

Claims reproduced:
  - Fig. 8: workers scheduled to ~100% before auto-scaling spills to the
    next worker;
  - Fig. 9: error bumps coincide with PE-count increases and settle ~0;
  - Fig. 10: the IRM targets more workers than the 5 available while the
    backlog persists (and tracks the ideal bin count);
  - run 1 (cold profile) is slower than the profiled runs.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.scenarios import get_scenario, run_scenario

SCENARIO = get_scenario("microscopy")
SIM = SCENARIO.sim_config()
N_RUNS = SCENARIO.n_runs


def run(out_dir: str) -> Dict:
    from .common import dump_csv, dump_json

    # 10 back-to-back runs with one persistent IRM (stream seeds 0..9)
    result = run_scenario(SCENARIO)
    res = result.final
    makespans = result.makespans

    W = SIM.max_workers
    dump_csv(
        out_dir, "fig8_scheduled_cpu.csv",
        ["t"] + [f"sched_w{i}" for i in range(W)],
        [(float(t), *map(float, s)) for t, s in zip(res.times,
                                                    res.scheduled_cpu, strict=True)],
    )
    dump_csv(
        out_dir, "fig9_error.csv",
        ["t"] + [f"err_w{i}" for i in range(W)],
        [(float(t), *map(float, e)) for t, e in zip(res.times, res.error, strict=True)],
    )
    dump_csv(
        out_dir, "fig10_workers.csv",
        ["t", "active", "target", "ideal_bins"],
        [
            (float(t), int(a), int(g), int(i))
            for t, a, g, i in zip(res.times, res.active_workers,
                                  res.target_workers, res.ideal_bins, strict=True)
        ],
    )

    # Fig. 8 claim: spill only when the lower-index workers are ~full
    spill_ok = []
    for w in range(1, W):
        started = (res.scheduled_cpu[:, w] > 0.05)
        if started.any():
            t_first = int(np.argmax(started))
            spill_ok.append(
                float(res.scheduled_cpu[t_first, :w].min()) > 0.7
            )
    # Fig. 9 claim: error settles near zero in the steady phase
    active = res.scheduled_cpu > 0.05
    err = res.error
    T = len(res.times)
    mid = slice(T // 4, 3 * T // 4)
    steady_err = (
        float(np.median(np.abs(err[mid][active[mid]])))
        if active[mid].any() else 0.0
    )

    summary = {
        "makespans_s": makespans,
        "run1_vs_best_profiled": float(makespans[0] / min(makespans[1:])),
        "claim_first_run_worse": bool(
            makespans[0] >= min(makespans[1:]) * 0.999
        ),
        "mean_scheduled_utilization_active": float(
            res.scheduled_cpu[active].mean()
        ),
        "claim_workers_filled_before_spill": bool(
            all(spill_ok) if spill_ok else False
        ),
        "steady_median_abs_error_pp": steady_err,
        "claim_error_settles": bool(steady_err < 15.0),
        "max_target_workers": int(res.target_workers.max()),
        "claim_target_exceeds_cap": bool(
            res.target_workers.max() > SIM.max_workers
        ),
        "completed": res.completed,
        "total": res.total,
    }
    dump_json(out_dir, "fig8_9_10_summary.json", summary)
    return summary
