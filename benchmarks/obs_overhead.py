"""Observability overhead: obs-on vs obs-off live throughput + self-check.

The event bus is a guarded list append on the hot paths (pull, start,
complete, IRM tick), and the ``full`` level additionally captures the
allocator's per-run audit snapshot.  This benchmark quantifies what that
costs where it matters — the live runtime's wall-clock throughput — and
**gates** it: obs-enabled messages/s must stay within 10% of obs-off on
the paper's microscopy use case, or the benchmark exits nonzero.

It also closes the analyzer's loop as a self-check: the e2e latency
p50/p95/p99 computed from the obs run's event log *alone*
(``repro.obs.analyze.e2e_percentiles``) must equal the percentiles the
``BENCH_runtime.json`` pipeline computes from the run's in-memory
``Message`` list — byte-for-byte the same numbers, proving the event log
carries everything the throughput benchmark measures.

Writes ``BENCH_obs.json``:

    {
      "schema": "BENCH_obs/v1",
      "smoke": false, "time_scale": 0.01, "scenario": "microscopy",
      "obs_off": {"completed": ..., "wall_s": ..., "messages_per_s": ...},
      "obs_on":  {..., "events": ..., "irm_pack_events": ...},
      "overhead": {"messages_per_s_ratio": ..., "gate": 0.9, "ok": true},
      "latency_selfcheck": {"p50": ..., "p95": ..., "p99": ...,
                            "matches_pipeline": true},
      "meta": {...}
    }

Usage:
    PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke] \
        [--scenario microscopy] [--time-scale 0.01] [--out BENCH_obs.json]
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.obs import EventBus
from repro.obs.analyze import e2e_percentiles, validate_events
from repro.runtime import RuntimeConfig, run_live
from repro.scenarios import get_scenario

#: obs-on live throughput must stay within 10% of obs-off.
GATE_RATIO = 0.9


def _run_once(name: str, *, smoke: bool, time_scale: float, obs: bool):
    scn = get_scenario(name)
    cfg = scn.sim_config()
    overrides: Dict = {}
    if smoke:
        overrides = dict(scn.smoke_overrides or {})
        if scn.smoke_t_max is not None:
            cfg.t_max = scn.smoke_t_max
    stream = scn.make_stream(0, **overrides)
    stats: Dict = {}
    bus = EventBus(level="full") if obs else None
    res = run_live(
        stream, cfg, irm_config=scn.irm_config(),
        runtime=RuntimeConfig(time_scale=time_scale), stats=stats, bus=bus,
    )
    row = {
        "completed": int(res.completed),
        "total": int(res.total),
        "wall_s": float(stats["wall_s"]),
        "messages_per_s": float(stats["messages_per_s"]),
        "makespan_s": float(res.makespan),
    }
    if bus is not None:
        row["events"] = len(bus.events)
        row["irm_pack_events"] = sum(
            1 for e in bus.events if e["ev"] == "irm.pack"
        )
        row["schema_violations"] = validate_events(bus.events)
    return row, res, bus


def run(out: str = "BENCH_obs.json", *, smoke: bool = False,
        scenario: str = "microscopy", time_scale: float = 0.01) -> Dict:
    off_row, _, _ = _run_once(scenario, smoke=smoke, time_scale=time_scale,
                              obs=False)
    on_row, on_res, on_bus = _run_once(scenario, smoke=smoke,
                                       time_scale=time_scale, obs=True)

    ratio = on_row["messages_per_s"] / max(off_row["messages_per_s"], 1e-9)
    ok = (
        ratio >= GATE_RATIO
        and on_row["completed"] >= 0.9 * on_row["total"]
        and off_row["completed"] >= 0.9 * off_row["total"]
        and not on_row["schema_violations"]
    )

    # analyzer self-check: event log alone reproduces the BENCH_runtime
    # pipeline's latency percentiles
    done = [m for m in on_res.messages if m.done_t >= 0]
    lat = np.array([m.done_t - m.arrival for m in done]) if done else np.zeros(1)
    pipeline = {p: float(np.percentile(lat, p)) for p in (50, 95, 99)}
    from_log = e2e_percentiles(on_bus.events)
    matches = all(
        abs(from_log[f"p{p}"] - pipeline[p]) < 1e-9 for p in (50, 95, 99)
    )
    ok &= matches

    result = {
        "schema": "BENCH_obs/v1",
        "smoke": bool(smoke),
        "time_scale": time_scale,
        "scenario": scenario,
        "obs_off": off_row,
        "obs_on": on_row,
        "overhead": {
            "messages_per_s_ratio": ratio,
            "gate": GATE_RATIO,
            "ok": bool(ok),
        },
        "latency_selfcheck": {
            "p50": from_log["p50"], "p95": from_log["p95"],
            "p99": from_log["p99"], "matches_pipeline": bool(matches),
        },
        "meta": {
            "python": platform.python_version(),
            "numpy": np.__version__,
            "platform": platform.platform(),
        },
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(
        f"{scenario}: obs-off {off_row['messages_per_s']:.1f} msgs/s, "
        f"obs-on {on_row['messages_per_s']:.1f} msgs/s "
        f"(ratio {ratio:.3f}, gate {GATE_RATIO}), "
        f"{on_row['events']} events, latency self-check "
        f"{'ok' if matches else 'MISMATCH'}"
    )
    print(f"wrote {out}")
    if not ok:
        print("ERROR: overhead gate or self-check failed", file=sys.stderr)
    return result


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/obs_overhead.py",
        description="Observability overhead gate on the live runtime.",
    )
    ap.add_argument("--out", default="BENCH_obs.json",
                    help="output JSON path (default: ./BENCH_obs.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long run on the scenario's smoke overrides")
    ap.add_argument("--scenario", default="microscopy",
                    help="registered scenario name")
    ap.add_argument("--time-scale", type=float, default=0.01,
                    help="wall seconds per scenario second")
    args = ap.parse_args(argv)
    result = run(args.out, smoke=args.smoke, scenario=args.scenario,
                 time_scale=args.time_scale)
    return 0 if result["overhead"]["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
