"""Shared helpers for the benchmark harness.

Every benchmark module exposes ``run(out_dir) -> dict`` (a JSON-able summary)
and writes its full time-series artifacts under ``out_dir``.  ``main()`` in
``benchmarks.run`` executes all of them and prints the summary table that
EXPERIMENTS.md cites.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def out_path(out_dir: str, name: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, name)


def dump_json(out_dir: str, name: str, payload: Any) -> str:
    path = out_path(out_dir, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return path


def dump_csv(out_dir: str, name: str, header: list, rows) -> str:
    path = out_path(out_dir, name)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(f"{v:.6g}" if isinstance(v, float) else str(v)
                             for v in row) + "\n")
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-able: {type(o)}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
