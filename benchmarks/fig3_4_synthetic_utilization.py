"""Paper Figs. 3 & 4: per-worker CPU utilization over time, synthetic
workloads (Section VI-A).

Claims reproduced:
  - the workload concentrates on low-index workers (Fig. 3),
  - workers peak at 90-100% utilization before spill-over (Fig. 4).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import simulate
from repro.scenarios import get_scenario

SCENARIO = get_scenario("synthetic")
SIM = SCENARIO.sim_config()


def run(out_dir: str) -> Dict:
    from .common import dump_csv, dump_json

    stream = SCENARIO.make_stream(0)
    res = simulate(stream, SIM)

    rows = [
        (float(t), *map(float, sched), *map(float, meas))
        for t, sched, meas in zip(res.times, res.scheduled_cpu,
                                  res.measured_cpu, strict=True)
    ]
    W = SIM.max_workers
    dump_csv(
        out_dir, "fig3_4_utilization.csv",
        ["t"] + [f"sched_w{i}" for i in range(W)]
        + [f"meas_w{i}" for i in range(W)],
        rows,
    )

    per_worker_load = res.scheduled_cpu.sum(axis=0) * SIM.dt  # worker-seconds
    # peak utilization per worker over windows where it is scheduled
    peaks = []
    for w in range(W):
        on = res.scheduled_cpu[:, w] > 0.05
        peaks.append(float(res.scheduled_cpu[on, w].max()) if on.any() else 0.0)

    low_half = float(per_worker_load[: W // 2 + 1].sum())
    high_half = float(per_worker_load[W // 2 + 1:].sum())
    summary = {
        "completed": res.completed,
        "total": res.total,
        "makespan_s": float(res.makespan),
        "per_worker_load_s": [float(x) for x in per_worker_load],
        "low_index_load_fraction": low_half / max(low_half + high_half, 1e-9),
        "worker_peak_scheduled": peaks,
        "claim_low_index_concentration": bool(
            np.argmax(per_worker_load) == 0
            and low_half > high_half
        ),
        "claim_peaks_90_100": bool(
            all(p >= 0.9 for p in peaks if p > 0.5)
        ),
    }
    dump_json(out_dir, "fig3_4_summary.json", summary)
    return summary
