"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §6) plus the framework
benchmarks.  Each writes its artifacts to ``results/bench/`` and returns a
JSON summary; the combined summary lands in ``results/bench/summary.json``.
"""

from __future__ import annotations

import argparse
import importlib
import time
import traceback

from .common import RESULTS_DIR, dump_json, out_path

MODULES = [
    ("fig3_4", "benchmarks.fig3_4_synthetic_utilization"),
    ("fig5", "benchmarks.fig5_synthetic_error"),
    ("fig7_spark", "benchmarks.fig7_spark_baseline"),
    ("fig8_9_10", "benchmarks.fig8_9_10_usecase"),
    ("binpack_quality", "benchmarks.binpack_quality"),
    ("binpack_microbench", "benchmarks.binpack_microbench"),
    ("packing_throughput", "benchmarks.packing_throughput"),
    ("serving_autoscale", "benchmarks.serving_autoscale"),
    ("kernel_bench", "benchmarks.kernel_bench"),
    ("roofline", "benchmarks.roofline"),
]


def _flat(d):
    for k, v in d.items():
        if isinstance(v, dict):
            yield from _flat(v)
        else:
            yield k, v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out or out_path(RESULTS_DIR, "bench")
    selected = set(args.only.split(",")) if args.only else None

    all_summaries = {}
    failures = 0
    for name, module in MODULES:
        if selected and name not in selected:
            continue
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(module)
            summary = mod.run(out_dir)
            dt = time.perf_counter() - t0
            all_summaries[name] = summary
            print(f"\n=== {name} ({dt:.1f}s) ===")
            for k, v in summary.items():
                if isinstance(v, dict):
                    print(f"  {k}:")
                    for kk, vv in v.items():
                        print(f"    {kk}: {vv}")
                else:
                    print(f"  {k}: {v}")
            bad = [k for k, v in _flat(summary)
                   if k.startswith("claim") and v is False]
            if bad:
                print(f"  !! failed claims: {bad}")
                failures += 1
        except Exception as e:
            failures += 1
            all_summaries[name] = {"error": f"{type(e).__name__}: {e}"}
            print(f"\n=== {name} FAILED: {type(e).__name__}: {e} ===")
            traceback.print_exc()

    dump_json(out_dir, "summary.json", all_summaries)
    n = len(all_summaries)
    print(f"\n{n - failures}/{n} benchmarks passed all claims; "
          f"artifacts in {out_dir}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
