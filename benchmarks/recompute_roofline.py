"""Recompute the derived roofline fields of dry-run JSON records in place.

The raw measurements (memory, FLOPs, collective bytes) come from the
compile; the derived fields (roofline terms, MODEL_FLOPS, MFU) are pure
functions of the record — this tool re-derives them after a fix to
``roofline_terms`` without recompiling 64 cells.

Usage:
  PYTHONPATH=src python -m benchmarks.recompute_roofline results/*.json
"""

from __future__ import annotations

import json
import sys

from repro.configs import SHAPES_BY_NAME
from repro.launch.dryrun import roofline_terms


def main() -> None:
    for path in sys.argv[1:]:
        with open(path) as f:
            records = json.load(f)
        n = 0
        for rec in records:
            if "error" in rec or "shape" not in rec:
                continue
            rec.update(roofline_terms(rec, SHAPES_BY_NAME[rec["shape"]]))
            n += 1
        with open(path, "w") as f:
            json.dump(records, f, indent=1)
        print(f"{path}: re-derived {n} records")


if __name__ == "__main__":
    main()
