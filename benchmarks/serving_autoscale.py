"""Framework benchmark: IRM-scheduled serving engine under a bursty load.

The paper's control plane (profiler + load predictor + First-Fit admission)
applied to continuous batching: measures completion latency, replica
auto-scaling behaviour, and slot/page utilization under a two-peak request
pattern — the serving analogue of the paper's synthetic experiment.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.serving import EngineConfig, ReplicaConfig, Request, ServingEngine

CFG = EngineConfig(
    replica=ReplicaConfig(
        max_slots=8, kv_pages=1024, page_size=16,
        prefill_tokens_per_s=100_000.0, decode_tokens_per_s=8_000.0,
        spinup_delay=5.0,
    ),
    max_replicas=8,
    dt=0.1,
)


def run(out_dir: str) -> Dict:
    from .common import dump_csv, dump_json

    rng = np.random.default_rng(0)
    eng = ServingEngine(CFG)

    # steady trickle + two bursts (the paper's two peaks)
    schedule = []
    for t in np.arange(0.0, 60.0, 2.0):
        schedule.append((float(t), 1))
    for burst_t in (15.0, 40.0):
        schedule.append((burst_t, 40))
    schedule.sort()

    idx = 0
    while eng.t < 400.0:
        while idx < len(schedule) and schedule[idx][0] <= eng.t:
            for _ in range(schedule[idx][1]):
                eng.submit(Request(
                    prompt_len=int(rng.integers(128, 1024)),
                    max_new_tokens=int(rng.integers(32, 256)),
                ))
            idx += 1
        eng.step()
        if idx >= len(schedule) and not eng.queue and all(
            not r.active and not r.prefilling
            for r in eng.backend.replicas if not r.retired
        ):
            break

    dump_csv(
        out_dir, "serving_autoscale.csv",
        ["t", "queue", "replicas", "target", "slot_load", "page_load"],
        [
            (m["t"], m["queue"], m["replicas"], m["target"],
             m["mean_slot_load"], m["mean_page_load"])
            for m in eng.metrics
        ],
    )
    s = eng.summary()
    lat = [r.done_t - r.arrival for r in eng.completed]
    replicas = np.array([m["replicas"] for m in eng.metrics])
    summary = {
        **{k: float(v) if isinstance(v, (int, float)) else v
           for k, v in s.items()},
        "mean_latency_s": float(np.mean(lat)),
        "peak_replicas": int(replicas.max()),
        "final_replicas": int(replicas[-1]),
        "claim_scales_up_on_burst": bool(replicas.max() >= 3),
        "claim_scales_back_down": bool(replicas[-1] < replicas.max()),
        "total_submitted": int(sum(n for _, n in schedule)),
    }
    dump_json(out_dir, "serving_autoscale.json", summary)
    return summary
