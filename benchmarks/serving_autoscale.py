"""Framework benchmark: IRM-scheduled serving engine under a bursty load.

The paper's control plane (profiler + load predictor + First-Fit admission)
applied to continuous batching: measures completion latency, replica
auto-scaling behaviour, and slot/page utilization under a two-peak request
pattern — the serving analogue of the paper's synthetic experiment.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.scenarios import run_serving_scenario
from repro.serving import EngineConfig, ReplicaConfig

CFG = EngineConfig(
    replica=ReplicaConfig(
        max_slots=8, kv_pages=1024, page_size=16,
        prefill_tokens_per_s=100_000.0, decode_tokens_per_s=8_000.0,
        spinup_delay=5.0,
    ),
    max_replicas=8,
    dt=0.1,
)


def run(out_dir: str) -> Dict:
    from .common import dump_csv, dump_json

    # the registry's bursty shape, sized to the old hand-rolled schedule: a
    # steady trickle plus the paper's two deterministic peaks over a minute
    result = run_serving_scenario(
        "bursty",
        stream_overrides=dict(
            t_end=60.0, trickle_interval=2.0, trickle_size=(1, 1),
            burst_times=(15.0, 40.0), burst_size=(40, 40),
            duration_range=(2.0, 16.0),
        ),
        engine_cfg=CFG,
        time_scale=1.0,
        t_max=400.0,
        request_kwargs=dict(prompt_tokens_per_s=64.0,
                            decode_tokens_per_s=16.0),
    )
    eng = result["engine"]

    dump_csv(
        out_dir, "serving_autoscale.csv",
        ["t", "queue", "replicas", "target", "slot_load", "page_load"],
        [
            (m["t"], m["queue"], m["replicas"], m["target"],
             m["mean_slot_load"], m["mean_page_load"])
            for m in eng.metrics
        ],
    )
    s = eng.summary()
    lat = [r.done_t - r.arrival for r in eng.completed]
    replicas = np.array([m["replicas"] for m in eng.metrics])
    summary = {
        **{k: float(v) if isinstance(v, (int, float)) else v
           for k, v in s.items()},
        "mean_latency_s": float(np.mean(lat)),
        "peak_replicas": int(replicas.max()),
        "final_replicas": int(replicas[-1]),
        "claim_scales_up_on_burst": bool(replicas.max() >= 3),
        "claim_scales_back_down": bool(replicas[-1] < replicas.max()),
        "total_submitted": int(result["submitted"]),
    }
    dump_json(out_dir, "serving_autoscale.json", summary)
    return summary
