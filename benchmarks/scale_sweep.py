"""Fleet-scale control-plane sweep: IRM decision latency vs worker count.

The paper's IRM makes one online bin-packing decision per tick; this bench
measures what that decision costs as the fleet grows, driving the real
``IRM.step`` loop against a synthetic ndarray-backed cluster view (no sim,
no asyncio — control plane only) at workers ∈ {10², 10³, 10⁴} with message
backlogs up to 10⁶.  Per size it reports wall-clock percentiles for the
full ``IRM.step`` and for the packing engine alone, plus the incremental
repacker's path counters.

The fleet view hands the allocator its per-worker loads as one float64
array (the numpy-engine fast path) and churns a small random fraction of
workers per tick — completions and new placements — so the incremental
repacker sees realistic dirty sets rather than a frozen fleet.

Writes ``BENCH_scale.json``:

    {
      "schema": "BENCH_scale/v1",
      "smoke": false,
      "algorithm": "first-fit",
      "engine": "numpy",
      "ticks": 200,
      "sizes": {
        "100":   {"workers": 100, "backlog": 10000,
                  "irm_step_ms": {"mean": ..., "p50": ..., "p95": ..., "p99": ...},
                  "packer_ms":   {"mean": ..., "p50": ..., "p95": ..., "p99": ...},
                  "placements": ..., "full_repacks": ..., "incremental_runs": ...},
        "1000":  {...}, "10000": {...}
      },
      "scaling": {"p99_ratio_10k_vs_100": ..., "sublinear_ok": true},
      "meta": {...}
    }

``--smoke`` runs only the 10²-worker point (the CI invocation).  On a full
sweep the script exits nonzero when ``IRM.step`` p99 at 10⁴ workers is not
below 10× the p99 at 10² — the sub-linear scaling contract the numpy
engine + incremental repack exist to provide.

Usage:
    PYTHONPATH=src python benchmarks/scale_sweep.py [--smoke] \
        [--ticks 200] [--algorithm first-fit] [--out BENCH_scale.json]
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import IRM, IRMConfig
from repro.core.allocator import AllocatorConfig
from repro.core.binpack import NumpyPacker
from repro.core.queues import HostRequest

SIZES = (100, 1_000, 10_000)
BACKLOG_PER_WORKER = 100  # 10^4 workers -> 10^6-message backlog
SUBLINEAR_MAX_RATIO = 10.0


class SyntheticFleetView:
    """ClusterView over an ndarray fleet: loads as one (n,) float64 array.

    ``worker_scheduled_loads`` returns the array itself, which routes the
    allocator onto the numpy engine; placements and per-tick churn mutate
    a bounded random subset of rows so the incremental repacker's dirty
    tracking is exercised the way a live fleet would.
    """

    def __init__(self, n_workers: int, backlog: int,
                 rng: np.random.Generator):
        self.n = n_workers
        self.backlog = float(backlog)
        self.loads = rng.uniform(0.0, 0.85, size=n_workers)
        self.requested_target = 0
        self.started = 0

    # -- observation ---------------------------------------------------------
    def queue_length(self) -> float:
        return self.backlog

    def queue_image_mix(self) -> Dict[str, float]:
        return {"img": 1.0}

    def worker_scheduled_loads(self) -> np.ndarray:
        return self.loads

    def backlog_resource_demand(self):
        return None

    # -- actuation -----------------------------------------------------------
    def try_start_pe(self, req: HostRequest) -> bool:
        idx = req.target_worker
        if idx is None or idx >= self.n:
            return False  # placement onto a not-yet-booted slot
        est = float(req.size_estimate)
        self.loads[idx] = min(self.loads[idx] + est, 1.0)
        self.started += 1
        return True

    def scale_workers(self, target: int) -> None:
        self.requested_target = target  # fleet size is fixed per sweep point

    # -- synthetic dynamics --------------------------------------------------
    def churn(self, rng: np.random.Generator) -> None:
        """Completions: ~1% of workers (at least one) shed some load."""
        k = max(1, self.n // 100)
        rows = rng.integers(0, self.n, size=k)
        self.loads[rows] = np.maximum(
            self.loads[rows] - rng.uniform(0.1, 0.5, size=k), 0.0
        )


def _percentiles(samples: List[float]) -> Dict[str, float]:
    arr = np.asarray(samples)
    return {
        "mean": float(arr.mean()),
        "p50": float(np.percentile(arr, 50)),
        "p95": float(np.percentile(arr, 95)),
        "p99": float(np.percentile(arr, 99)),
    }


def bench_packer_only(loads: np.ndarray, algorithm: str,
                      rng: np.random.Generator, reps: int) -> Dict[str, float]:
    """Latency of one packing decision alone: build the engine over the
    fleet's prefill and place one drained batch (8 items, the predictor's
    per-decision cap) — the exact work ``BinPackingManager.run`` delegates."""
    sizes = rng.uniform(0.05, 0.6, size=8)
    prefill = np.minimum(loads, 1.0)
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        packer = NumpyPacker(algorithm, capacity=1.0, used=prefill)
        packer.place_batch(sizes)
        lat.append((time.perf_counter() - t0) * 1e3)
    return _percentiles(lat)


def bench_size(n_workers: int, *, ticks: int, algorithm: str,
               seed: int = 0) -> Dict[str, object]:
    rng = np.random.default_rng(seed)
    irm_cfg = IRMConfig()
    irm_cfg.allocator = AllocatorConfig(
        algorithm=algorithm, engine="numpy", incremental=True,
        pack_interval=0.0,  # pack on every tick: every step pays a decision
    )
    irm = IRM(irm_cfg)
    backlog = BACKLOG_PER_WORKER * n_workers
    view = SyntheticFleetView(n_workers, backlog, rng)
    step_ms: List[float] = []
    for i in range(ticks):
        view.churn(rng)
        t0 = time.perf_counter()
        irm.step(float(i), view)
        step_ms.append((time.perf_counter() - t0) * 1e3)
    mgr = irm.packing_manager
    return {
        "workers": n_workers,
        "backlog": backlog,
        "irm_step_ms": _percentiles(step_ms),
        "packer_ms": bench_packer_only(view.loads, algorithm, rng,
                                       reps=min(ticks, 100)),
        "placements": view.started,
        "full_repacks": mgr.full_repacks,
        "incremental_runs": mgr.incremental_runs,
    }


def run(out: str = "BENCH_scale.json", *, smoke: bool = False,
        ticks: int = 200, algorithm: str = "first-fit") -> dict:
    sizes = SIZES[:1] if smoke else SIZES
    payload = {
        "schema": "BENCH_scale/v1",
        "smoke": bool(smoke),
        "algorithm": algorithm,
        "engine": "numpy",
        "ticks": ticks,
        "sizes": {},
    }
    for n in sizes:
        print(f"[scale_sweep] workers={n} ...", flush=True)
        payload["sizes"][str(n)] = bench_size(n, ticks=ticks,
                                              algorithm=algorithm)
        r = payload["sizes"][str(n)]
        print(
            f"[scale_sweep]   irm.step p50={r['irm_step_ms']['p50']:.3f}ms "
            f"p99={r['irm_step_ms']['p99']:.3f}ms  "
            f"packer p99={r['packer_ms']['p99']:.3f}ms  "
            f"incremental={r['incremental_runs']}/{ticks}",
            flush=True,
        )
    if not smoke and "100" in payload["sizes"] and "10000" in payload["sizes"]:
        small = payload["sizes"]["100"]["irm_step_ms"]["p99"]
        big = payload["sizes"]["10000"]["irm_step_ms"]["p99"]
        ratio = big / max(small, 1e-9)
        payload["scaling"] = {
            "p99_ratio_10k_vs_100": ratio,
            "sublinear_ok": bool(ratio < SUBLINEAR_MAX_RATIO),
        }
        print(f"[scale_sweep] p99(10^4)/p99(10^2) = {ratio:.2f}x "
              f"(contract: < {SUBLINEAR_MAX_RATIO:.0f}x)", flush=True)
    payload["meta"] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "numpy": np.__version__,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[scale_sweep] wrote {out}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="IRM decision-latency sweep over fleet sizes"
    )
    ap.add_argument("--smoke", action="store_true",
                    help="run only the 100-worker point (CI)")
    ap.add_argument("--ticks", type=int, default=200,
                    help="IRM steps timed per fleet size (default 200)")
    ap.add_argument("--algorithm", default="first-fit",
                    help="packing policy under test (default first-fit)")
    ap.add_argument("--out", default="BENCH_scale.json",
                    help="output JSON path (default: ./BENCH_scale.json)")
    args = ap.parse_args(argv)
    payload = run(args.out, smoke=args.smoke, ticks=args.ticks,
                  algorithm=args.algorithm)
    scaling = payload.get("scaling")
    if scaling is not None and not scaling["sublinear_ok"]:
        print("[scale_sweep] FAIL: decision cost is not sub-linear",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
