"""Paper Fig. 7 + the headline wall-time comparison (Section VI-B).

Reproduces the Spark Streaming dynamic-allocation baseline on the 767-image
CellProfiler workload and compares its end-to-end makespan against HIO+IRM:
the paper reports "the execution time of the entire batch of images is
nearly halved" for HIO.

Fig. 7 phenomena reproduced: executor ramp-up, visible per-batch CPU gaps,
the initial 2-executor stall, and idle-timeout scale-downs (red circles).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import SparkConfig, simulate, simulate_spark
from repro.scenarios import get_scenario

SCENARIO = get_scenario("microscopy")
HIO_SIM = SCENARIO.sim_config()


def run(out_dir: str) -> Dict:
    from .common import dump_csv, dump_json

    stream = SCENARIO.make_stream(0)  # 767 images, 10-20 s each
    spark = simulate_spark(SCENARIO.make_stream(0), SparkConfig())
    hio = simulate(stream, HIO_SIM)

    dump_csv(
        out_dir, "fig7_spark.csv",
        ["t", "executor_cores", "used_cores", "pending"],
        [
            (float(t), float(c), float(u), int(p))
            for t, c, u, p in zip(spark.times, spark.executor_cores,
                                  spark.used_cores, spark.pending_tasks, strict=True)
        ],
    )

    # batch gaps: fraction of the busy period where used cores < 25% of
    # registered cores (the "idle gaps in between" the paper observes)
    busy_span = spark.used_cores > 0
    if busy_span.any():
        t_first = np.argmax(busy_span)
        t_last = len(busy_span) - np.argmax(busy_span[::-1])
        span = slice(t_first, t_last)
        gap_frac = float(
            (spark.used_cores[span] < 0.25 * spark.executor_cores[span]).mean()
        )
    else:
        gap_frac = 0.0

    summary = {
        "spark_makespan_s": float(spark.makespan),
        "hio_makespan_s": float(hio.makespan),
        "speedup_hio_over_spark": float(spark.makespan / hio.makespan),
        "spark_scaledown_events": len(spark.scale_downs),
        "spark_idle_gap_fraction": gap_frac,
        "spark_peak_cores": float(spark.executor_cores.max()),
        "spark_completed": spark.completed,
        "hio_completed": hio.completed,
        "claim_hio_roughly_2x": bool(
            1.5 <= spark.makespan / hio.makespan <= 3.0
        ),
        "claim_spurious_scaledowns": bool(len(spark.scale_downs) >= 1),
        "claim_scales_to_40_cores": bool(spark.executor_cores.max() == 40.0),
    }
    dump_json(out_dir, "fig7_summary.json", summary)
    return summary
