"""Roofline reader: renders EXPERIMENTS.md §Roofline from the dry-run JSON.

Reads ``results/dryrun_baseline.json`` (and, when present, the optimized
records in ``results/dryrun_opt.json``) and prints per (arch x shape x mesh):
compute / memory / collective terms in seconds, the dominant term, the
MODEL_FLOPS / HLO_FLOPs usefulness ratio, and the roofline-bound MFU.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import RESULTS_DIR, dump_json

BASELINE = os.path.join(RESULTS_DIR, "dryrun_baseline.json")
OPTIMIZED = os.path.join(RESULTS_DIR, "dryrun_opt.json")


def load(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [r for r in json.load(f) if "error" not in r]


def fmt_row(r: Dict) -> str:
    return (
        f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<8} "
        f"{r['t_compute_s']:>9.3g} {r['t_memory_s']:>9.3g} "
        f"{r['t_collective_s']:>9.3g} {r['dominant']:<10} "
        f"{r['useful_flops_fraction']:>6.2f} {r['model_flops_util']:>7.4f}"
    )


HEADER = (
    f"{'arch':<22} {'shape':<12} {'mesh':<8} "
    f"{'t_comp(s)':>9} {'t_mem(s)':>9} {'t_coll(s)':>9} {'dominant':<10} "
    f"{'useful':>6} {'MFU':>7}"
)


def run(out_dir: str) -> Dict:
    base = load(BASELINE)
    opt = load(OPTIMIZED)

    print("\n--- Roofline (baseline dry-run) ---")
    print(HEADER)
    for r in base:
        print(fmt_row(r))
    if opt:
        print("\n--- Roofline (optimized cells) ---")
        print(HEADER)
        for r in opt:
            print(fmt_row(r))

    dominant_counts: Dict[str, int] = {}
    for r in base:
        dominant_counts[r["dominant"]] = dominant_counts.get(
            r["dominant"], 0) + 1

    def best(rows, key):
        return max(rows, key=lambda r: r.get(key, 0.0)) if rows else None

    summary = {
        "baseline_cells": len(base),
        "optimized_cells": len(opt),
        "dominant_term_histogram": dominant_counts,
        "best_baseline_mfu": best(base, "model_flops_util")["model_flops_util"]
        if base else 0.0,
        "best_optimized_mfu": best(opt, "model_flops_util")["model_flops_util"]
        if opt else 0.0,
    }
    if opt:
        # before/after for the hillclimbed cells
        improvements = []
        for o in opt:
            match = [
                b for b in base
                if (b["arch"], b["shape"], b["mesh"])
                == (o["arch"], o["shape"], o["mesh"])
            ]
            if match:
                b = match[0]
                improvements.append(
                    {
                        "cell": f"{o['arch']} x {o['shape']} x {o['mesh']}",
                        "bound_before_s": b["roofline_step_s"],
                        "bound_after_s": o["roofline_step_s"],
                        "speedup": b["roofline_step_s"] / o["roofline_step_s"],
                        "mfu_before": b["model_flops_util"],
                        "mfu_after": o["model_flops_util"],
                    }
                )
        summary["hillclimb"] = improvements
    dump_json(out_dir, "roofline_summary.json", summary)
    return summary
