"""Simulation-core throughput: indexed hot path vs the pre-refactor sim.

Times ``repro.core.sim.simulate`` (per-image FIFO deques + PE event indices
+ preallocated recording buffers) against the frozen baseline
``repro.core.sim_reference.simulate_reference`` on the paper's two
experiment scenarios, checks the outputs are bit-for-bit identical, and
writes ``BENCH_sim.json``:

    {
      "schema": "BENCH_sim/v1",
      "smoke": false,
      "scenarios": {
        "microscopy": {
          "ticks": 568, "messages": 767, "sim_seconds": 284.0,
          "indexed":   {"wall_s": ..., "ticks_per_s": ..., "messages_per_s": ...},
          "reference": {"wall_s": ..., "ticks_per_s": ..., "messages_per_s": ...},
          "speedup": 4.2, "identical": true
        }, ...
      },
      "meta": {"python": ..., "numpy": ..., "platform": ..., "reps": ...}
    }

Wall times are best-of-``--reps`` (default 3); ``speedup`` is
``reference.wall_s / indexed.wall_s``.  ``--smoke`` shrinks every scenario
to its registered smoke overrides for a seconds-long CI run; CI uploads
the resulting JSON as an artifact so the perf trajectory is tracked per
commit (see ``.github/workflows/ci.yml``).

Usage:
    PYTHONPATH=src python benchmarks/sim_throughput.py [--smoke] \
        [--scenarios microscopy,synthetic] [--reps 3] [--out BENCH_sim.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import simulate
from repro.core.sim_reference import simulate_reference
from repro.scenarios import get_scenario

DEFAULT_SCENARIOS = ("synthetic", "microscopy")

_RESULT_FIELDS = ("times", "measured_cpu", "scheduled_cpu", "queue_len",
                  "active_workers", "target_workers", "ideal_bins", "pe_count")


def _identical(a, b) -> bool:
    return (
        all(np.array_equal(getattr(a, f), getattr(b, f))
            for f in _RESULT_FIELDS)
        and a.completed == b.completed
        and a.makespan == b.makespan
    )


def _bench_one(sim_fn, scn, cfg, overrides: Dict, reps: int):
    """Best-of-``reps`` wall time; a fresh stream + IRM per repetition."""
    best = float("inf")
    result = None
    for _ in range(reps):
        stream = scn.make_stream(0, **overrides)
        t0 = time.perf_counter()
        result = sim_fn(stream, cfg)
        best = min(best, time.perf_counter() - t0)
    return best, result


def bench_scenario(name: str, *, smoke: bool, reps: int) -> Dict:
    scn = get_scenario(name)
    cfg = scn.sim_config()
    overrides: Dict = {}
    if smoke:
        overrides = dict(scn.smoke_overrides or {})
        if scn.smoke_t_max is not None:
            cfg = dataclasses.replace(cfg, t_max=scn.smoke_t_max)

    new_wall, new_res = _bench_one(simulate, scn, cfg, overrides, reps)
    ref_wall, ref_res = _bench_one(simulate_reference, scn, cfg, overrides,
                                   reps)

    ticks = int(len(new_res.times))
    messages = int(new_res.completed)
    row = {
        "ticks": ticks,
        "messages": messages,
        "sim_seconds": float(new_res.times[-1]) if ticks else 0.0,
        "indexed": {
            "wall_s": new_wall,
            "ticks_per_s": ticks / new_wall,
            "messages_per_s": messages / new_wall,
        },
        "reference": {
            "wall_s": ref_wall,
            "ticks_per_s": ticks / ref_wall,
            "messages_per_s": messages / ref_wall,
        },
        "speedup": ref_wall / new_wall,
        "identical": _identical(new_res, ref_res),
    }
    return row


def run(out: str = "BENCH_sim.json", *, smoke: bool = False,
        scenarios: Optional[List[str]] = None, reps: int = 3) -> Dict:
    names = list(scenarios or DEFAULT_SCENARIOS)
    payload = {
        "schema": "BENCH_sim/v1",
        "smoke": bool(smoke),
        "scenarios": {},
        "meta": {
            "python": platform.python_version(),
            "numpy": np.__version__,
            "platform": platform.platform(),
            "reps": reps,
        },
    }
    ok = True
    for name in names:
        row = bench_scenario(name, smoke=smoke, reps=reps)
        payload["scenarios"][name] = row
        ok &= row["identical"]
        print(
            f"{name:<12} ticks={row['ticks']:>6} "
            f"indexed={row['indexed']['wall_s']*1e3:8.1f}ms "
            f"({row['indexed']['ticks_per_s']:>9,.0f} ticks/s) "
            f"reference={row['reference']['wall_s']*1e3:8.1f}ms "
            f"speedup={row['speedup']:.2f}x "
            f"identical={row['identical']}"
        )
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {out}")
    if not ok:
        print("ERROR: indexed and reference sims disagree", file=sys.stderr)
    return payload


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/sim_throughput.py",
        description="Time the indexed sim core against the pre-refactor sim.",
    )
    ap.add_argument("--out", default="BENCH_sim.json",
                    help="output JSON path (default: ./BENCH_sim.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long run on each scenario's smoke overrides")
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS),
                    help="comma-separated registered scenario names")
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per cell; best wall time is reported")
    args = ap.parse_args(argv)
    names = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    payload = run(args.out, smoke=args.smoke, scenarios=names, reps=args.reps)
    return 0 if all(r["identical"] for r in payload["scenarios"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
