"""Framework benchmark: First-Fit sequence packing efficiency + throughput.

The data-pipeline analogue of the paper's 90-100% worker utilization:
packing efficiency (real tokens / row capacity) for First-Fit vs Next-Fit vs
the no-packing (one-doc-per-row) baseline, over realistic document-length
distributions, plus host-side packing throughput in documents/s.
"""

from __future__ import annotations

import time
from typing import Dict

from repro.data import (
    bimodal_documents,
    pack_documents,
    packing_efficiency,
    synthetic_documents,
)

SEQ_LEN = 4096
N_DOCS = 2000


def run(out_dir: str) -> Dict:
    from .common import dump_json

    sources = {
        "lognormal_700": lambda: synthetic_documents(
            50000, mean_len=700, seed=0, limit=N_DOCS
        ),
        "bimodal_128_3000": lambda: bimodal_documents(
            50000, seed=0, limit=N_DOCS
        ),
    }
    table: Dict[str, Dict[str, float]] = {}
    throughput = {}
    for name, make in sources.items():
        docs = list(make())
        row = {}
        for algo in ("first-fit", "best-fit", "next-fit"):
            t0 = time.perf_counter()
            batches = list(pack_documents(docs, SEQ_LEN, 8, algorithm=algo))
            dt = time.perf_counter() - t0
            row[algo] = packing_efficiency(batches)
            if algo == "first-fit":
                throughput[name] = len(docs) / dt
        row["no_packing"] = sum(min(len(d), SEQ_LEN) for d in docs) / (
            len(docs) * SEQ_LEN
        )
        # offline FFD as the achievable reference (the L1 bound is not
        # attainable when two long docs cannot share a row)
        ffd = list(pack_documents(
            sorted(docs, key=len, reverse=True), SEQ_LEN, 8,
            algorithm="first-fit",
        ))
        row["ffd_offline"] = packing_efficiency(ffd)
        table[name] = row

    summary = {
        "seq_len": SEQ_LEN,
        "efficiency": table,
        "first_fit_docs_per_s": {k: float(v) for k, v in throughput.items()},
        "claim_ff_above_95pct_lognormal": bool(
            table["lognormal_700"]["first-fit"] > 0.95
        ),
        "claim_ff_within_5pct_of_offline": bool(
            all(table[s]["first-fit"] > 0.95 * table[s]["ffd_offline"]
                for s in sources)
        ),
        "claim_ff_beats_no_packing_3x": bool(
            all(table[s]["first-fit"] > 3 * table[s]["no_packing"]
                for s in sources)
        ),
    }
    dump_json(out_dir, "packing_throughput.json", summary)
    return summary
