"""Bin-packing quality benchmark (paper Section IV + the Sec. VII vector
direction).

Measures the empirical bin-count ratio vs the L1 lower bound for every
implemented algorithm across item-size distributions, verifying the
theoretical ordering the paper quotes: First-Fit/Best-Fit (R = 1.7) pack no
worse than Next-Fit/Worst-Fit (R = 2), FFD (offline, R = 11/9) is the
quality reference, Harmonic sits near 1.69.

The vector section sweeps the multi-dimensional packers against the
*dominant-dimension* L1 lower bound on correlated, anti-correlated, and
skewed two-dimensional item distributions — the regimes where co-packing
complementary items (Panigrahy et al.) pays off.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.binpack import (
    FirstFitDecreasing,
    Item,
    VectorItem,
    lower_bound,
    make_packer,
    vector_lower_bound,
)

ALGOS = ("first-fit", "first-fit-tree", "best-fit", "worst-fit", "next-fit",
         "harmonic")

VECTOR_ALGOS = ("vector-first-fit", "vector-best-fit", "vector-next-fit",
                "dominant-fit", "vector-ffd")

DISTS = {
    "uniform(0,1]": lambda rng, n: rng.uniform(0.01, 1.0, n),
    "uniform(0,0.5]": lambda rng, n: rng.uniform(0.01, 0.5, n),
    "bimodal(0.3/0.6)": lambda rng, n: np.where(
        rng.random(n) < 0.5,
        rng.normal(0.3, 0.03, n), rng.normal(0.6, 0.03, n)
    ).clip(0.01, 1.0),
    "lognormal": lambda rng, n: np.clip(
        rng.lognormal(-1.5, 0.6, n), 0.01, 1.0
    ),
    "adversarial_ff": lambda rng, n: np.concatenate(
        [np.full(n // 3, 1 / 7 + 0.003), np.full(n // 3, 1 / 3 + 0.003),
         np.full(n - 2 * (n // 3), 1 / 2 + 0.003)]
    ),
}


# Two-dimensional (cpu, mem) item distributions for the vector sweep.
VECTOR_DISTS = {
    # cpu and mem rise together: behaves like scalar packing
    "correlated": lambda rng, n: np.clip(
        np.stack([u := rng.uniform(0.05, 0.6, n),
                  u + rng.normal(0.0, 0.05, n)], axis=1),
        0.01, 1.0,
    ),
    # cpu-heavy items pair with mem-heavy items: co-packing pays
    "anti-correlated": lambda rng, n: np.clip(
        np.stack([u := rng.uniform(0.05, 0.75, n), 0.8 - u], axis=1),
        0.01, 1.0,
    ),
    # one rigid dimension dominates (the microscopy-mem regime)
    "mem-heavy": lambda rng, n: np.clip(
        np.stack([rng.uniform(0.05, 0.2, n),
                  rng.uniform(0.25, 0.45, n)], axis=1),
        0.01, 1.0,
    ),
    # independent dimensions
    "independent": lambda rng, n: np.clip(
        np.stack([rng.uniform(0.05, 0.5, n),
                  rng.uniform(0.05, 0.5, n)], axis=1),
        0.01, 1.0,
    ),
}


def run(out_dir: str) -> Dict:
    from .common import dump_json

    rng = np.random.default_rng(0)
    n = 2000
    table: Dict[str, Dict[str, float]] = {}
    for dist_name, gen in DISTS.items():
        sizes = gen(rng, n)
        lb = lower_bound(sizes)
        row = {"lower_bound": lb}
        for algo in ALGOS:
            packer = make_packer(algo)
            res = packer.pack([Item(float(s)) for s in sizes])
            row[algo] = res.num_bins / lb
        ffd = FirstFitDecreasing().pack([Item(float(s)) for s in sizes])
        row["ffd_offline"] = ffd.num_bins / lb
        table[dist_name] = row

    # aggregate means over distributions
    means = {
        algo: float(np.mean([table[d][algo] for d in DISTS]))
        for algo in ALGOS + ("ffd_offline",)
    }

    # ---- vector packers vs the dominant-dimension lower bound -------------
    vec_table: Dict[str, Dict[str, float]] = {}
    for dist_name, gen in VECTOR_DISTS.items():
        pairs = gen(rng, n)
        vlb = vector_lower_bound(pairs, (1.0, 1.0))
        row = {"lower_bound": vlb}
        for algo in VECTOR_ALGOS:
            packer = make_packer(algo, capacity=(1.0, 1.0))
            res = packer.pack([VectorItem(tuple(map(float, p))) for p in pairs])
            row[algo] = res.num_bins / vlb
        vec_table[dist_name] = row
    vec_means = {
        algo: float(np.mean([vec_table[d][algo] for d in VECTOR_DISTS]))
        for algo in VECTOR_ALGOS
    }

    summary = {
        "per_distribution": table,
        "mean_ratio_vs_lb": means,
        "claim_ff_beats_nf": bool(means["first-fit"] <= means["next-fit"]),
        "claim_ffd_best": bool(
            means["ffd_offline"] <= min(means[a] for a in ALGOS)
        ),
        "claim_ff_within_1_7": bool(
            all(table[d]["first-fit"] <= 1.7 + 0.05 for d in DISTS)
        ),
        "claim_tree_identical": bool(
            all(table[d]["first-fit"] == table[d]["first-fit-tree"]
                for d in DISTS)
        ),
        "vector_per_distribution": vec_table,
        "vector_mean_ratio_vs_dominant_lb": vec_means,
        "claim_vector_all_above_lb": bool(
            all(vec_table[d][a] >= 1.0 - 1e-9
                for d in VECTOR_DISTS for a in VECTOR_ALGOS)
        ),
        "claim_vector_ff_beats_nf": bool(
            vec_means["vector-first-fit"] <= vec_means["vector-next-fit"]
        ),
        "claim_vector_ffd_no_worse_than_ff": bool(
            vec_means["vector-ffd"] <= vec_means["vector-first-fit"] + 1e-9
        ),
    }
    dump_json(out_dir, "binpack_quality.json", summary)
    return {
        k: v for k, v in summary.items()
        if k not in ("per_distribution", "vector_per_distribution")
    }
