"""Bin-packing quality benchmark (paper Section IV).

Measures the empirical bin-count ratio vs the L1 lower bound for every
implemented algorithm across item-size distributions, verifying the
theoretical ordering the paper quotes: First-Fit/Best-Fit (R = 1.7) pack no
worse than Next-Fit/Worst-Fit (R = 2), FFD (offline, R = 11/9) is the
quality reference, Harmonic sits near 1.69.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.binpack import (
    FirstFitDecreasing,
    Item,
    lower_bound,
    make_packer,
)

ALGOS = ("first-fit", "first-fit-tree", "best-fit", "worst-fit", "next-fit",
         "harmonic")

DISTS = {
    "uniform(0,1]": lambda rng, n: rng.uniform(0.01, 1.0, n),
    "uniform(0,0.5]": lambda rng, n: rng.uniform(0.01, 0.5, n),
    "bimodal(0.3/0.6)": lambda rng, n: np.where(
        rng.random(n) < 0.5,
        rng.normal(0.3, 0.03, n), rng.normal(0.6, 0.03, n)
    ).clip(0.01, 1.0),
    "lognormal": lambda rng, n: np.clip(
        rng.lognormal(-1.5, 0.6, n), 0.01, 1.0
    ),
    "adversarial_ff": lambda rng, n: np.concatenate(
        [np.full(n // 3, 1 / 7 + 0.003), np.full(n // 3, 1 / 3 + 0.003),
         np.full(n - 2 * (n // 3), 1 / 2 + 0.003)]
    ),
}


def run(out_dir: str) -> Dict:
    from .common import dump_json

    rng = np.random.default_rng(0)
    n = 2000
    table: Dict[str, Dict[str, float]] = {}
    for dist_name, gen in DISTS.items():
        sizes = gen(rng, n)
        lb = lower_bound(sizes)
        row = {"lower_bound": lb}
        for algo in ALGOS:
            packer = make_packer(algo)
            res = packer.pack([Item(float(s)) for s in sizes])
            row[algo] = res.num_bins / lb
        ffd = FirstFitDecreasing().pack([Item(float(s)) for s in sizes])
        row["ffd_offline"] = ffd.num_bins / lb
        table[dist_name] = row

    # aggregate means over distributions
    means = {
        algo: float(np.mean([table[d][algo] for d in DISTS]))
        for algo in ALGOS + ("ffd_offline",)
    }
    summary = {
        "per_distribution": table,
        "mean_ratio_vs_lb": means,
        "claim_ff_beats_nf": bool(means["first-fit"] <= means["next-fit"]),
        "claim_ffd_best": bool(
            means["ffd_offline"] <= min(means[a] for a in ALGOS)
        ),
        "claim_ff_within_1_7": bool(
            all(table[d]["first-fit"] <= 1.7 + 0.05 for d in DISTS)
        ),
        "claim_tree_identical": bool(
            all(table[d]["first-fit"] == table[d]["first-fit-tree"]
                for d in DISTS)
        ),
    }
    dump_json(out_dir, "binpack_quality.json", summary)
    return {k: v for k, v in summary.items() if k != "per_distribution"}
