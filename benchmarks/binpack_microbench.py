"""Bin-packing cost microbenchmark (paper Section IV-A).

The paper quotes First-Fit at O(n log n) time / O(n) space.  This benchmark
times the naive O(n*m) scan vs the segment-tree O(n log m) implementation
across n, verifying (a) absolute cost is microseconds per item — packing
never belongs on the accelerator — and (b) the tree variant's growth rate
is compatible with O(log m) per item while the naive scan grows ~linearly
in m for workloads that keep many bins nearly full.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core.binpack import FirstFit, FirstFitTree, Item


def _time_once(packer_cls, sizes) -> float:
    packer = packer_cls()
    t0 = time.perf_counter()
    for s in sizes:
        packer.pack_one(Item(s))
    return time.perf_counter() - t0


def run(out_dir: str) -> Dict:
    from .common import dump_json

    rng = np.random.default_rng(0)
    ns = (1000, 4000, 16000)
    rows = []
    for n in ns:
        # adversarial-ish: many small items keep lots of bins open
        sizes = rng.uniform(0.01, 0.12, n)
        t_naive = min(_time_once(FirstFit, sizes) for _ in range(3))
        t_tree = min(_time_once(FirstFitTree, sizes) for _ in range(3))
        rows.append(
            {
                "n": n,
                "naive_us_per_item": 1e6 * t_naive / n,
                "tree_us_per_item": 1e6 * t_tree / n,
            }
        )

    # growth of per-item cost from smallest to largest n
    naive_growth = rows[-1]["naive_us_per_item"] / rows[0]["naive_us_per_item"]
    tree_growth = rows[-1]["tree_us_per_item"] / rows[0]["tree_us_per_item"]
    summary = {
        "rows": rows,
        "naive_per_item_growth_16x_n": float(naive_growth),
        "tree_per_item_growth_16x_n": float(tree_growth),
        "claim_tree_scales_better": bool(tree_growth < naive_growth),
        "claim_microseconds_per_item": bool(
            rows[-1]["tree_us_per_item"] < 100.0
        ),
    }
    dump_json(out_dir, "binpack_microbench.json", summary)
    return summary
