"""Kernel structural benchmark (no TPU available: dry-run profiling style).

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock numbers would be meaningless.  What IS measurable and transfers
to hardware is the *structural* work saved by the bin-packing-aware designs:

  - packed_attention: fraction of (q, kv) tile pairs skipped by the causal
    block-skip, and the FLOPs a dense (non-packed, padded) batch would have
    cost vs the packed batch at equal token throughput;
  - paged_attention: pages touched vs pages a dense cache would scan
    (= occupancy of the KV bins);
  - grouped_matmul: capacity blocks skipped at realistic router skew.

Each quantity is an exact block count from the kernels' grid logic.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.data import bimodal_documents, pack_documents, packing_efficiency


def packed_attention_stats(S=4096, block=256) -> Dict[str, float]:
    n = S // block
    total = n * n
    # causal block-skip: tile (iq, ik) runs iff ik*block <= iq*block + block-1
    run = sum(1 for iq in range(n) for ik in range(n) if ik <= iq)
    return {
        "seq_len": S,
        "block": block,
        "causal_block_skip_fraction": 1.0 - run / total,
        "flops_vs_full_rectangle": run / total,
    }


def packing_vs_padding_flops(S=4096, B=8, n_docs=800) -> Dict[str, float]:
    docs = list(bimodal_documents(50000, seed=0, limit=n_docs))
    batches = list(pack_documents(docs, S, B))
    eff = packing_efficiency(batches)
    rows_packed = sum(1 for _ in batches) * B
    rows_padded = len(docs)  # one doc per row
    real_tokens = sum(min(len(d), S) for d in docs)
    # attention FLOPs scale with rows * S^2 (dense causal): padded batches
    # burn rows_padded/rows_packed more matmul work per real token
    return {
        "packing_efficiency": eff,
        "rows_packed": rows_packed,
        "rows_padded_baseline": rows_padded,
        "attention_flops_saved_fraction": 1.0 - rows_packed / rows_padded,
        "real_tokens": real_tokens,
    }


def paged_attention_stats(page_size=16) -> Dict[str, float]:
    rng = np.random.default_rng(0)
    # realistic decode batch: mixed-length sequences in a 32k-slot cache
    lens = rng.integers(64, 32768, size=128)
    max_len = 32768
    pages_touched = int(np.ceil(lens / page_size).sum())
    pages_dense = 128 * (max_len // page_size)
    return {
        "page_size": page_size,
        "pages_touched": pages_touched,
        "pages_dense_scan": pages_dense,
        "kv_read_saved_fraction": 1.0 - pages_touched / pages_dense,
    }


def grouped_matmul_stats(E=128, top_k=8, T=8192, cap_factor=1.25,
                         block_c=128, skew=1.5) -> Dict[str, float]:
    rng = np.random.default_rng(0)
    # Zipf-ish router skew over experts
    w = (1.0 / np.arange(1, E + 1) ** (skew / 4.0))
    w /= w.sum()
    counts = rng.multinomial(T * top_k, w)
    C = max(128, int(np.ceil(T * top_k * cap_factor / E / 128)) * 128)
    blocks_total = E * (C // block_c)
    blocks_run = int(np.minimum(np.ceil(counts / block_c), C // block_c).sum())
    return {
        "experts": E,
        "capacity": C,
        "occupied_block_fraction": blocks_run / blocks_total,
        "gmm_flops_saved_fraction": 1.0 - blocks_run / blocks_total,
        "dropped_fraction": float(
            np.maximum(counts - C, 0).sum() / (T * top_k)
        ),
    }


def run(out_dir: str) -> Dict:
    from .common import dump_json

    summary = {
        "packed_attention": packed_attention_stats(),
        "packing_vs_padding": packing_vs_padding_flops(),
        "paged_attention": paged_attention_stats(),
        "grouped_matmul_qwen3_moe": grouped_matmul_stats(),
    }
    summary["claims"] = {
        "causal_skip_near_half": bool(
            0.4 <= summary["packed_attention"]["causal_block_skip_fraction"]
            <= 0.5
        ),
        "packing_saves_attention_flops": bool(
            summary["packing_vs_padding"]["attention_flops_saved_fraction"]
            > 0.5
        ),
        "paging_saves_kv_reads": bool(
            summary["paged_attention"]["kv_read_saved_fraction"] > 0.3
        ),
    }
    dump_json(out_dir, "kernel_bench.json", summary)
    return summary
