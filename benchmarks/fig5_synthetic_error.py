"""Paper Fig. 5: scheduled-vs-measured CPU error, synthetic workloads.

Claim reproduced: the error is noisy (start/stop transients of PEs under
bursty streaming) but centered near zero — the paper attributes the noise to
"the delay in starting and stopping containers compared to when they are
scheduled" and to irregular streaming ("PEs often starting and finishing").
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import simulate

from .fig3_4_synthetic_utilization import SCENARIO, SIM


def run(out_dir: str) -> Dict:
    from .common import dump_csv, dump_json

    res = simulate(SCENARIO.make_stream(0), SIM)
    err = res.error  # (T, W) percentage points

    W = err.shape[1]
    dump_csv(
        out_dir, "fig5_error.csv",
        ["t"] + [f"err_w{i}" for i in range(W)],
        [(float(t), *map(float, e)) for t, e in zip(res.times, err, strict=True)],
    )

    active = res.scheduled_cpu > 0.05
    err_active = err[active]
    summary = {
        "mean_error_pp": float(err_active.mean()) if err_active.size else 0.0,
        "mean_abs_error_pp": float(np.abs(err_active).mean())
        if err_active.size else 0.0,
        "p95_abs_error_pp": float(np.percentile(np.abs(err_active), 95))
        if err_active.size else 0.0,
        # transient vs steady: error within 2*pe_start_delay of a PE-count
        # change vs elsewhere
        "claim_error_centered": bool(
            abs(err_active.mean()) < 15.0 if err_active.size else True
        ),
    }
    # split transient/steady by PE-count changes
    dpe = np.abs(np.diff(res.pe_count, prepend=res.pe_count[0]))
    transient = np.zeros(len(res.times), bool)
    halo = int(2 * SIM.pe_start_delay / SIM.dt)
    for i in np.nonzero(dpe > 0)[0]:
        transient[max(0, i - 1): i + halo] = True
    steady = ~transient
    if (steady[:, None] & active).any():
        summary["steady_mean_abs_error_pp"] = float(
            np.abs(err[steady[:, None] & active]).mean()
        )
    if (transient[:, None] & active).any():
        summary["transient_mean_abs_error_pp"] = float(
            np.abs(err[transient[:, None] & active]).mean()
        )
    summary["claim_transients_noisier"] = bool(
        summary.get("transient_mean_abs_error_pp", 0.0)
        >= summary.get("steady_mean_abs_error_pp", 0.0)
    )
    dump_json(out_dir, "fig5_summary.json", summary)
    return summary
