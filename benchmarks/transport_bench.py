"""Transport cost: in-process asyncio vs. OS-process workers, head to head.

Runs the same scenario through the live runtime twice — once per
transport (``InProcTransport`` vs. ``MultiprocTransport``) — and records
what the process promotion actually costs and buys:

  - **messages/s** — completed messages per wall second on each transport
    (the multiproc column pays pickling + queue hops + OS scheduling);
  - **end-to-end latency** — per-message ``done - arrival`` in scenario
    seconds, p50/p95/p99 (IPC latency shows up here if it is ever large
    relative to the scheduling delays);
  - **serialization** — bytes and milliseconds per message over the data
    channel, both directions (the multiproc transport's explicit pickle
    accounting; zero by construction for inproc);
  - **profiler drift** — emulated model CPU vs. the *real* per-message
    thread CPU measured inside the worker processes, in percentage
    points of one worker — the measured-vs-emulated gap the process
    backend exists to expose (``measurement="os"`` would feed the real
    samples to the profiler instead; this benchmark keeps the default so
    both columns pack identically and the drift is a pure observation).

Writes ``BENCH_transport.json``:

    {
      "schema": "BENCH_transport/v1",
      "smoke": false, "scenario": "microscopy", "time_scale": ...,
      "payload": "sleep",
      "transports": {
        "inproc":    {"completed": ..., "messages_per_s": ...,
                      "latency_s": {...}, "wall_s": ...},
        "multiproc": {..., "serialization": {"bytes_per_msg": ...,
                      "ms_per_msg": ..., "bytes_out": ..., "bytes_in": ...},
                      "profiler_drift_pp": ..., "real_cpu_core_s": ...,
                      "emulated_cpu_core_s": ..., "proc_cpu_s": ...,
                      "workers_spawned": ...}
      },
      "comparison": {"throughput_ratio": ..., "latency_p50_ratio": ...},
      "meta": {...}
    }

Exits nonzero if either transport completes < 90% of the stream — a
transport that drops work is broken, not slow.

Usage:
    PYTHONPATH=src python benchmarks/transport_bench.py [--smoke] \
        [--scenario microscopy] [--time-scale 0.01] [--payload sleep] \
        [--out BENCH_transport.json]
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.runtime import RuntimeConfig, run_live
from repro.scenarios import get_scenario


def bench_transport(
    name: str, transport: str, *, smoke: bool, time_scale: float,
    payload: str,
) -> Dict:
    scn = get_scenario(name)
    cfg = scn.sim_config()
    overrides: Dict = {}
    if smoke:
        overrides = dict(scn.smoke_overrides or {})
        if scn.smoke_t_max is not None:
            cfg.t_max = scn.smoke_t_max

    stream = scn.make_stream(0, **overrides)
    stats: Dict = {}
    res = run_live(
        stream, cfg, irm_config=scn.irm_config(),
        runtime=RuntimeConfig(time_scale=time_scale, payload=payload,
                              transport=transport),
        stats=stats,
    )
    done = [m for m in res.messages if m.done_t >= 0]
    lat = np.array([m.done_t - m.arrival for m in done]) if done \
        else np.zeros(1)
    t = stats["transport"]
    row = {
        "completed": int(res.completed),
        "total": int(res.total),
        "requeued": int(res.requeued),
        "wall_s": float(stats["wall_s"]),
        "messages_per_s": float(stats["messages_per_s"]),
        "makespan_s": float(res.makespan),
        "latency_s": {
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99)),
        },
        "max_target_workers": int(res.target_workers.max()),
        "peak_pe_count": int(res.pe_count.max()),
    }
    if transport == "multiproc":
        row["serialization"] = {
            "bytes_per_msg": float(t["ser_bytes_per_msg"]),
            "ms_per_msg": float(t["ser_ms_per_msg"]),
            "bytes_out": int(t["data_bytes_out"]),
            "bytes_in": int(t["data_bytes_in"]),
            "msgs_out": int(t["data_msgs_out"]),
            "msgs_in": int(t["data_msgs_in"]),
        }
        row["profiler_drift_pp"] = float(t["profiler_drift_pp"])
        row["real_cpu_core_s"] = float(t["real_cpu_core_s"])
        row["emulated_cpu_core_s"] = float(t["emulated_cpu_core_s"])
        row["proc_cpu_s"] = float(t["proc_cpu_s"])
        row["workers_spawned"] = int(t["workers_spawned"])
        row["start_method"] = t["start_method"]
    return row


def run(out: str = "BENCH_transport.json", *, smoke: bool = False,
        scenario: str = "microscopy", time_scale: float = 0.01,
        payload: str = "sleep") -> Dict:
    result = {
        "schema": "BENCH_transport/v1",
        "smoke": bool(smoke),
        "scenario": scenario,
        "time_scale": time_scale,
        "payload": payload,
        "transports": {},
        "meta": {
            "python": platform.python_version(),
            "numpy": np.__version__,
            "platform": platform.platform(),
        },
    }
    for transport in ("inproc", "multiproc"):
        row = bench_transport(scenario, transport, smoke=smoke,
                              time_scale=time_scale, payload=payload)
        result["transports"][transport] = row
        extra = ""
        if transport == "multiproc":
            ser = row["serialization"]
            extra = (f" ser={ser['bytes_per_msg']:.0f}B/"
                     f"{ser['ms_per_msg']:.3f}ms per msg "
                     f"drift={row['profiler_drift_pp']:+.1f}pp")
        print(
            f"{transport:<10} done={row['completed']:>4}/{row['total']:<4} "
            f"wall={row['wall_s']:6.2f}s "
            f"msgs/s={row['messages_per_s']:7.1f} "
            f"lat p50/p99={row['latency_s']['p50']:6.1f}/"
            f"{row['latency_s']['p99']:6.1f}s{extra}"
        )
    ip = result["transports"]["inproc"]
    mp = result["transports"]["multiproc"]
    result["comparison"] = {
        "throughput_ratio": mp["messages_per_s"] / max(ip["messages_per_s"],
                                                       1e-9),
        "latency_p50_ratio": mp["latency_s"]["p50"] / max(
            ip["latency_s"]["p50"], 1e-9),
        "profiler_drift_pp": mp["profiler_drift_pp"],
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"\nwrote {out}")
    ok = all(r["completed"] >= 0.9 * r["total"]
             for r in result["transports"].values())
    if not ok:
        print("ERROR: a transport completed < 90% of its stream",
              file=sys.stderr)
    return result


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/transport_bench.py",
        description="Head-to-head cost of inproc vs. multiproc transports.",
    )
    ap.add_argument("--out", default="BENCH_transport.json",
                    help="output JSON path (default: ./BENCH_transport.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long run on the scenario's smoke overrides")
    ap.add_argument("--scenario", default="microscopy",
                    help="registered scenario name (default: microscopy)")
    ap.add_argument("--time-scale", type=float, default=0.01,
                    help="wall seconds per scenario second")
    ap.add_argument("--payload", default="sleep",
                    help="PE payload: sleep (calibrated) or jax (real kernel)")
    args = ap.parse_args(argv)
    result = run(args.out, smoke=args.smoke, scenario=args.scenario,
                 time_scale=args.time_scale, payload=args.payload)
    return 0 if all(
        r["completed"] >= 0.9 * r["total"]
        for r in result["transports"].values()
    ) else 1


if __name__ == "__main__":
    raise SystemExit(main())
