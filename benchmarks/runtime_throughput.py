"""Live-runtime throughput: messages/s, latency percentiles, IRM overhead.

Runs registered scenarios on the live asyncio backend (``repro.runtime``)
and records what a streaming operator actually cares about:

  - **messages/s** — completed messages per *wall* second (broker + PE
    task + payload + control-loop overhead, all real);
  - **end-to-end latency** — per-message ``done - arrival`` in scenario
    seconds, p50/p95/p99 (queueing + start delays + service time);
  - **IRM decision latency** — wall milliseconds per ``IRM.step`` against
    the live cluster view (the control plane's own cost, which the
    discrete sim can never measure: there it *is* the simulation loop).

Writes ``BENCH_runtime.json``:

    {
      "schema": "BENCH_runtime/v1",
      "smoke": true,
      "time_scale": 0.01,
      "payload": "sleep",
      "scenarios": {
        "microscopy": {
          "completed": 40, "total": 40, "wall_s": ...,
          "messages_per_s": ..., "ticks": ..., "makespan_s": ...,
          "latency_s": {"p50": ..., "p95": ..., "p99": ...},
          "irm_step_ms": {"mean": ..., "p50": ..., "p99": ...},
          "max_target_workers": ..., "peak_pe_count": ...
        }, ...
      },
      "meta": {...}
    }

``--smoke`` uses each scenario's registered smoke overrides (the CI
invocation; the artifact is uploaded next to ``BENCH_sim.json``).  Exits
nonzero if any scenario fails to complete ≥90% of its stream — a live
backend that drops work is broken, not slow.

Usage:
    PYTHONPATH=src python benchmarks/runtime_throughput.py --smoke \
        [--scenarios microscopy,synthetic] [--time-scale 0.01] \
        [--payload sleep|jax] [--out BENCH_runtime.json]
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.runtime import RuntimeConfig, run_live
from repro.scenarios import get_scenario

DEFAULT_SCENARIOS = ("synthetic", "microscopy", "microscopy-mem")


def bench_scenario(
    name: str, *, smoke: bool, time_scale: float, payload: str
) -> Dict:
    scn = get_scenario(name)
    cfg = scn.sim_config()
    overrides: Dict = {}
    if smoke:
        overrides = dict(scn.smoke_overrides or {})
        if scn.smoke_t_max is not None:
            cfg.t_max = scn.smoke_t_max

    stream = scn.make_stream(0, **overrides)
    stats: Dict = {}
    res = run_live(
        stream, cfg, irm_config=scn.irm_config(),
        runtime=RuntimeConfig(time_scale=time_scale, payload=payload),
        stats=stats,
    )
    # wall/throughput come from the driver's own stats, which start the
    # clock *after* payload construction — otherwise JaxPayload's one-off
    # jit warm-up would deflate messages/s on short runs
    wall = float(stats["wall_s"])

    done = [m for m in res.messages if m.done_t >= 0]
    lat = np.array([m.done_t - m.arrival for m in done]) if done else np.zeros(1)
    return {
        "completed": int(res.completed),
        "total": int(res.total),
        "wall_s": wall,
        "messages_per_s": float(stats["messages_per_s"]),
        "ticks": int(stats.get("ticks", len(res.times))),
        "makespan_s": float(res.makespan),
        "latency_s": {
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99)),
        },
        "irm_step_ms": {
            "mean": stats.get("irm_step_ms_mean", 0.0),
            "p50": stats.get("irm_step_ms_p50", 0.0),
            "p99": stats.get("irm_step_ms_p99", 0.0),
        },
        "max_target_workers": int(res.target_workers.max()),
        "peak_pe_count": int(res.pe_count.max()),
    }


def run(out: str = "BENCH_runtime.json", *, smoke: bool = False,
        scenarios: Optional[List[str]] = None, time_scale: float = 0.01,
        payload: str = "sleep") -> Dict:
    names = list(scenarios or DEFAULT_SCENARIOS)
    result = {
        "schema": "BENCH_runtime/v1",
        "smoke": bool(smoke),
        "time_scale": time_scale,
        "payload": payload,
        "scenarios": {},
        "meta": {
            "python": platform.python_version(),
            "numpy": np.__version__,
            "platform": platform.platform(),
        },
    }
    ok = True
    for name in names:
        row = bench_scenario(
            name, smoke=smoke, time_scale=time_scale, payload=payload
        )
        result["scenarios"][name] = row
        ok &= row["completed"] >= 0.9 * row["total"]
        print(
            f"{name:<15} done={row['completed']:>4}/{row['total']:<4} "
            f"wall={row['wall_s']:6.2f}s "
            f"msgs/s={row['messages_per_s']:7.1f} "
            f"lat p50/p99={row['latency_s']['p50']:6.1f}/"
            f"{row['latency_s']['p99']:6.1f}s "
            f"irm p50/p99={row['irm_step_ms']['p50']:.2f}/"
            f"{row['irm_step_ms']['p99']:.2f}ms"
        )
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"\nwrote {out}")
    if not ok:
        print("ERROR: a scenario completed < 90% of its stream",
              file=sys.stderr)
    return result


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/runtime_throughput.py",
        description="Throughput/latency of the live asyncio runtime backend.",
    )
    ap.add_argument("--out", default="BENCH_runtime.json",
                    help="output JSON path (default: ./BENCH_runtime.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long run on each scenario's smoke overrides")
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS),
                    help="comma-separated registered scenario names")
    ap.add_argument("--time-scale", type=float, default=0.01,
                    help="wall seconds per scenario second")
    ap.add_argument("--payload", default="sleep",
                    help="PE payload: sleep (calibrated) or jax (real kernel)")
    args = ap.parse_args(argv)
    names = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    result = run(args.out, smoke=args.smoke, scenarios=names,
                 time_scale=args.time_scale, payload=args.payload)
    return 0 if all(
        r["completed"] >= 0.9 * r["total"]
        for r in result["scenarios"].values()
    ) else 1


if __name__ == "__main__":
    raise SystemExit(main())
