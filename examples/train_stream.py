"""End-to-end driver: stream -> First-Fit packing -> train a ~100M LM.

The paper's full loop at trainable-on-CPU scale:

  - documents stream in from a synthetic scientific-corpus source,
  - the IRM-instrumented pipeline profiles document sizes, auto-scales
    packer shards from queue pressure, and First-Fit-packs rows,
  - a ~100M-parameter decoder (same code path as the assigned archs) trains
    with the fault-tolerant controller: async checkpoints, automatic
    restart, straggler tracking.

Usage:
  PYTHONPATH=src python examples/train_stream.py --steps 300
  PYTHONPATH=src python examples/train_stream.py --steps 50 --fail-at 30
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import StreamingPipeline, synthetic_documents
from repro.models import build_model, init_params
from repro.training import OptimizerConfig, init_opt_state, make_train_step
from repro.training.controller import TrainController, TrainControllerConfig

# ~100M-parameter decoder-only LM (untied embeddings: 2*50304*640 = 64M,
# blocks: 10 * (4*640^2 + 3*640*2560) = 66M  ->  ~130M total)
LM_100M = ArchConfig(
    name="lm-100m",
    family="dense",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=10,
    d_ff=2560,
    vocab_size=50304,
    norm_type="rmsnorm",
    act="swiglu",
    source="examples/train_stream.py",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (restart demo)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_stream")
    args = ap.parse_args()

    cfg = LM_100M
    model = build_model(cfg)
    n_params, _ = cfg.param_counts()
    print(f"model: {cfg.name} ({n_params / 1e6:.0f}M params)")

    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    step_fn = jax.jit(
        make_train_step(
            model,
            OptimizerConfig(learning_rate=3e-4, warmup_steps=50,
                            decay_steps=args.steps),
            remat_policy="nothing",
        ),
        donate_argnums=(0, 1),
    )

    docs = synthetic_documents(cfg.vocab_size, mean_len=180, max_len=1024,
                               seed=0, limit=None)
    pipe = StreamingPipeline(
        docs, seq_len=args.seq_len, batch_size=args.batch_size, prefetch=4
    )

    def batches():
        for pb in pipe:
            yield {
                "tokens": jnp.asarray(pb.tokens),
                "labels": jnp.asarray(pb.labels),
                "segment_ids": jnp.asarray(pb.segment_ids),
                "positions": jnp.asarray(pb.positions),
            }

    ctl = TrainController(
        step_fn,
        TrainControllerConfig(
            checkpoint_dir=args.ckpt_dir, checkpoint_every=50,
            async_checkpoint=True,
        ),
    )
    params, opt_state, start = ctl.init_state(lambda: (params, opt_state))
    if start:
        print(f"resumed from checkpoint at step {start}")

    t0 = time.perf_counter()
    losses = []

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == start + 1:
            dt = time.perf_counter() - t0
            tput = (step - start) * args.batch_size * args.seq_len / dt
            print(
                f"step {step:>5}  loss {metrics['loss']:.4f}  "
                f"grad_norm {metrics['grad_norm']:.3f}  "
                f"lr {metrics['lr']:.2e}  {tput:,.0f} tok/s"
            )

    params, opt_state, summary = ctl.run(
        params, opt_state, batches(),
        num_steps=args.steps, start_step=start,
        fail_at=args.fail_at, on_metrics=on_metrics,
    )

    stats = pipe.stats()
    print("\n--- done ---")
    print(f"final step: {summary['final_step']}  "
          f"restarts: {summary['restarts']}  "
          f"stragglers: {len(summary['stragglers'])}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"pipeline: {stats['docs_in']} docs, {stats['rows_out']} rows, "
          f"mean doc fill {stats['mean_doc_fill']:.2%}, "
          f"packer shards {stats['active_shards']}")
    if args.steps >= 100:  # shorter runs sit inside the lr warmup
        assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
