"""Quickstart: the paper's Intelligent Resource Manager in 60 seconds.

Runs the three layers of the reproduction end to end at toy scale:

  1. the online bin-packing core (First-Fit over a pre-loaded cluster),
  2. the IRM scheduling a simulated streaming workload (paper Sec. VI-B),
  3. the same First-Fit engine packing documents into training rows.

Usage:
  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import FirstFit, Item, SimConfig, lower_bound, simulate
from repro.data import pack_documents, packing_efficiency, synthetic_documents
from repro.scenarios import get_scenario


def demo_binpacking() -> None:
    print("=" * 64)
    print("1. Online First-Fit bin-packing (paper Section IV)")
    print("=" * 64)
    sizes = [0.5, 0.7, 0.5, 0.2, 0.4, 0.2, 0.8, 0.3]
    ff = FirstFit()
    result = ff.pack([Item(s) for s in sizes])
    print(f"items: {sizes}")
    print(f"assignments (item -> worker): {result.assignments}")
    print(f"workers used: {result.num_bins} "
          f"(ideal lower bound: {lower_bound(sizes)})")
    for i, b in enumerate(result.bins):
        bar = "#" * int(b.used * 40)
        print(f"  worker {i}: [{bar:<40}] {b.used:.0%}")


def demo_irm_simulation() -> None:
    print()
    print("=" * 64)
    print("2. IRM scheduling the microscopy stream (paper Section VI-B)")
    print("=" * 64)
    stream = get_scenario("microscopy").make_stream(
        0, n_images=120, duration_range=(5.0, 10.0)
    )
    res = simulate(
        stream,
        SimConfig(dt=0.5, cores_per_worker=8, max_workers=5,
                  worker_boot_delay=10.0, pe_start_delay=2.0, t_max=1200.0),
    )
    print(f"processed {res.completed}/{res.total} images "
          f"in {res.makespan:.0f}s (5-worker cap)")
    active = res.scheduled_cpu > 0.05
    print(f"mean scheduled utilization while active: "
          f"{res.scheduled_cpu[active].mean():.0%}")
    print(f"peak target workers requested by the IRM: "
          f"{res.target_workers.max()} (cap 5 — the IRM keeps asking, "
          f"paper Fig. 10)")
    err = res.error[active]
    print(f"scheduled-vs-measured error: mean {err.mean():+.1f}pp, "
          f"median |err| {np.median(np.abs(err)):.1f}pp (paper Fig. 9)")


def demo_sequence_packing() -> None:
    print()
    print("=" * 64)
    print("3. First-Fit sequence packing for training data (framework layer)")
    print("=" * 64)
    docs = list(synthetic_documents(50000, mean_len=700, seed=0, limit=500))
    batches = list(pack_documents(docs, seq_len=4096, batch_size=8))
    eff = packing_efficiency(batches)
    naive = sum(min(len(d), 4096) for d in docs) / (len(docs) * 4096)
    print(f"{len(docs)} documents -> {len(batches)} batches of 8x4096")
    print(f"packing efficiency: {eff:.1%} (one-doc-per-row baseline: "
          f"{naive:.1%})")
    print(f"attention-FLOP reduction at equal tokens: "
          f"{1 - (1 / eff) * naive / (naive / eff if naive else 1):.0%}"
          if False else
          f"rows saved vs padding: {1 - len(batches) * 8 / len(docs):.0%}")


if __name__ == "__main__":
    demo_binpacking()
    demo_irm_simulation()
    demo_sequence_packing()
    print("\nDone. Next: examples/train_stream.py, examples/serve_microscopy.py")
