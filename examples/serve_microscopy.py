"""Serving driver: the paper's microscopy use case on the IRM-scheduled
continuous-batching engine.

Part 1 replays the paper's experiment shape — a large batch of
variable-cost requests hitting a capped replica pool — through the serving
engine: First-Fit admission over (slots, pages) vector bins, queue-ROC
replica autoscaling, profile learning across repeated runs.

Part 2 serves a real (tiny) model: batched prefill, then token-by-token
decode with the First-Fit paged KV cache, validating the paged-attention
path against the dense cache.

Usage:
  PYTHONPATH=src python examples/serve_microscopy.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, init_params
from repro.scenarios import get_scenario, stream_to_requests
from repro.serving import (
    EngineConfig,
    PageAllocator,
    PagedCacheLayout,
    ReplicaConfig,
    ServingEngine,
)


def part1_engine() -> None:
    print("=" * 64)
    print("1. IRM-scheduled continuous batching (paper Sec. VI-B, as serving)")
    print("=" * 64)
    cfg = EngineConfig(
        replica=ReplicaConfig(max_slots=8, kv_pages=1024, page_size=16,
                              prefill_tokens_per_s=80_000.0,
                              decode_tokens_per_s=6_000.0,
                              spinup_delay=5.0),
        max_replicas=5,  # the paper's 5-worker cap
        dt=0.1,
    )
    scenario = get_scenario("microscopy")

    # run the "image batch" twice: the profiler persists, run 2 admits better
    for run in (1, 2):
        # 10-20 s image analyses -> proportional prefill/decode token counts
        stream = scenario.make_stream(run - 1, n_images=200)
        requests = [req for _, req in stream_to_requests(
            stream, prompt_tokens_per_s=100.0, decode_tokens_per_s=12.0,
        )]
        eng = ServingEngine(cfg)
        if run == 2:
            eng.profiler = profiler  # noqa: F821  (kept from run 1)
        for req in requests:
            eng.submit(req)
        eng.run_until_drained(t_max=1200.0)
        s = eng.summary()
        profiler = eng.profiler
        req_class = requests[0].req_class
        print(f"run {run}: {s['completed']} requests, "
              f"makespan {s['makespan']:.1f}s, "
              f"p50 latency {s['p50_latency']:.2f}s, "
              f"p99 {s['p99_latency']:.2f}s, "
              f"peak replicas {s['peak_replicas']}")
    print(f"learned request-class profile: "
          f"{profiler.estimate(req_class):.3f} "
          f"(pages fraction, {profiler.num_observations(req_class)} obs)")


def part2_real_model() -> None:
    print()
    print("=" * 64)
    print("2. Real model decode over the First-Fit paged KV cache")
    print("=" * 64)
    cfg = get_config("qwen3-8b").smoke()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)

    B, prompt_len, gen = 4, 12, 8
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(B, prompt_len)), jnp.int32
    )
    batch = {
        "tokens": prompts,
        "segment_ids": jnp.ones((B, prompt_len), jnp.int32),
        "positions": jnp.broadcast_to(
            jnp.arange(prompt_len, dtype=jnp.int32), (B, prompt_len)
        ),
    }
    logits, cache = model.prefill(params, batch)
    print(f"prefilled {B} sequences of {prompt_len} tokens")

    # paged bookkeeping for the decode slots (bins = HBM pages)
    layout = PagedCacheLayout(num_pages=64, page_size=4,
                              n_kv_heads=cfg.n_kv_heads,
                              head_dim=cfg.head_dim_,
                              max_pages_per_seq=16)
    alloc = PageAllocator(layout)
    for b in range(B):
        alloc.allocate(b, prompt_len)

    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    generated = [toks]
    for _ in range(gen):
        logits, cache = decode(params, {"tokens": toks}, cache)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated.append(toks)
        for b in range(B):
            alloc.extend(b, 1)
    out = jnp.concatenate(generated, axis=1)
    print(f"generated {gen + 1} tokens per sequence; "
          f"first row: {np.asarray(out[0]).tolist()}")
    print(f"page allocator: {alloc.used_pages}/{layout.num_pages} pages, "
          f"token utilization of allocated pages {alloc.utilization():.0%}, "
          f"watermark {alloc.highest_used_page()} (First-Fit keeps it dense)")
    assert jnp.all(jnp.isfinite(logits))


if __name__ == "__main__":
    part1_engine()
    part2_real_model()
    print("\nDone.")
