"""Fault-tolerance walkthrough: checkpoint/restart, elastic resharding,
worker failure in the streaming cluster, TTL requeue.

Four scenarios, all runnable on one CPU:

  1. training crash -> automatic restart from the latest async checkpoint,
  2. elastic restore: the same checkpoint restored onto a different mesh
     (device_put against the current topology's shardings),
  3. a worker VM dying mid-stream: in-flight messages bounce back to the
     master queue (at-least-once) and the workload still completes —
     on the discrete-event sim, the live asyncio runtime, or both
     (``--backend``; ``tests/test_backend_parity.py`` pins the two
     backends to *identical* requeue counts on the registered scenario),
  4. failed container placements TTL-requeueing through the container queue.

Usage:
  PYTHONPATH=src python examples/fault_tolerance.py
  PYTHONPATH=src python examples/fault_tolerance.py --backend live
  PYTHONPATH=src python examples/fault_tolerance.py --backend both --smoke

``--smoke`` runs only the streaming scenarios (3 and 4) — the CI
live-smoke job uses it to keep the kill path exercised without paying
for model training.
"""

import argparse
import tempfile

from repro.core import (
    AllocationQueue,
    ContainerQueue,
    HostRequest,
    SimConfig,
    simulate,
)
from repro.scenarios import get_scenario


def scenario_1_crash_restart(tmp: str) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import build_model, init_params, make_batch
    from repro.training import (
        OptimizerConfig,
        init_opt_state,
        make_train_step,
    )
    from repro.training.controller import (
        TrainController,
        TrainControllerConfig,
    )

    print("=" * 64)
    print("1. Training crash -> restart from latest checkpoint")
    print("=" * 64)
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, OptimizerConfig()))
    ctl = TrainController(step_fn, TrainControllerConfig(
        checkpoint_dir=tmp, checkpoint_every=5, async_checkpoint=True,
    ))

    def batches():
        i = 0
        while True:
            yield make_batch(cfg, "train", 2, 64, seed=i)
            i += 1

    _, opt, summary = ctl.run(
        params, init_opt_state(params), batches(),
        num_steps=12, fail_at=8,
    )
    print(f"injected failure at step 8 -> restarts: {summary['restarts']}, "
          f"completed step {summary['final_step']} anyway\n")


def scenario_2_elastic_restore(tmp: str) -> None:
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.distributed import param_shardings
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model, init_params

    print("=" * 64)
    print("2. Elastic restore onto the current mesh")
    print("=" * 64)
    cfg = get_config("olmo-1b").smoke()
    model = build_model(cfg)
    specs = model.param_specs()
    params = init_params(specs, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp + "/elastic")
    mgr.save(1, {"p": params})

    mesh = make_local_mesh()  # whatever topology this host has
    shardings = {"p": param_shardings(specs, mesh)}
    restored = mgr.restore(1, {"p": params}, shardings)
    leaf = jax.tree.leaves(restored["p"])[0]
    print(f"restored onto mesh {dict(mesh.shape)}; "
          f"first leaf sharding: {leaf.sharding}\n")


def scenario_3_worker_failure(backends) -> None:
    print("=" * 64)
    print("3. Worker VM failure mid-stream (messages requeued, run completes)")
    print("=" * 64)
    cfg = SimConfig(
        dt=0.5, cores_per_worker=4, max_workers=5,
        worker_boot_delay=5.0, pe_start_delay=1.0, t_max=1500.0,
        fail_worker_at=(0, 25.0),  # kill the busiest worker at t=25s
    )
    make_stream = get_scenario("microscopy").make_stream
    for backend in backends:
        stream = make_stream(0, n_images=80, duration_range=(4.0, 8.0))
        if backend == "live":
            from repro.runtime import RuntimeConfig, run_live

            res = run_live(stream, cfg,
                           runtime=RuntimeConfig(time_scale=0.01))
        else:
            res = simulate(stream, cfg)
        print(f"[{backend:>4}] worker 0 killed at t=25s; "
              f"{res.requeued} in-flight messages requeued at the head; "
              f"completed {res.completed}/{res.total} in {res.makespan:.0f}s")
    print()


def scenario_4_ttl_requeue() -> None:
    print("=" * 64)
    print("4. TTL requeue of failed placements (paper V-B.2)")
    print("=" * 64)
    cq, aq = ContainerQueue(), AllocationQueue()
    req = HostRequest("haste/cellprofiler:3.1.9", size_estimate=0.4, ttl=3,
                      target_worker=2)
    aq.push(req)
    attempts = []

    def try_start(r):
        attempts.append(r.ttl)
        return len(attempts) >= 3  # worker becomes ready on the 3rd try

    for _ in range(3):
        aq.consume(try_start=try_start, on_fail=cq.requeue)
        for r in cq.drain():
            r.target_worker = 2
            aq.push(r)
        if not len(aq):
            break
    print(f"placement attempts (ttl at attempt): {attempts} -> started")
    print(f"dropped requests: {len(cq.dropped)} (TTL never exhausted)\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("sim", "live", "both"),
                    default="sim",
                    help="streaming backend(s) for the worker-failure "
                    "scenario (default: sim)")
    ap.add_argument("--smoke", action="store_true",
                    help="streaming scenarios only (skip model training)")
    args = ap.parse_args()
    backends = ("sim", "live") if args.backend == "both" else (args.backend,)

    if not args.smoke:
        with tempfile.TemporaryDirectory() as tmp:
            scenario_1_crash_restart(tmp)
            scenario_2_elastic_restore(tmp)
    scenario_3_worker_failure(backends)
    scenario_4_ttl_requeue()
    print("Done.")


if __name__ == "__main__":
    main()
