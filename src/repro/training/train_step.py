"""Training step builder: mixed precision, microbatching, grad compression.

``make_train_step`` returns a pure ``(params, opt_state, batch) -> (params,
opt_state, metrics)`` function suitable for ``jax.jit`` with shardings.

Distributed-optimization features (all optional, all off by default for the
paper-faithful baseline; see EXPERIMENTS.md §Perf for their effect):
  - ``microbatches > 1``: gradient accumulation over a ``lax.scan``; under
    the XLA latency-hiding scheduler the per-microbatch reduce-scatter of
    the previous slice overlaps the next slice's compute.
  - ``compress_grads``: int8-quantized gradient reduction with error
    feedback (``distributed/compression.py``).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .optimizer import OptimizerConfig, adamw_update

__all__ = ["make_train_step", "cast_params_for_compute"]

Pytree = Any


def cast_params_for_compute(params: Pytree, dtype=jnp.bfloat16) -> Pytree:
    """Cast >=2D float params to bf16 for compute; keep vectors in fp32.

    Master params stay fp32 in the optimizer; autodiff through the cast
    produces fp32 gradients automatically.
    """

    def cast(p: jax.Array) -> jax.Array:
        if p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dtype)
        return p

    return jax.tree.map(cast, params)


def _microbatch_split(batch: Pytree, n: int) -> Pytree:
    """(B, ...) -> (n, B/n, ...) for every leaf."""

    def split(x: jax.Array) -> jax.Array:
        B = x.shape[0]
        if B % n:
            raise ValueError(f"batch dim {B} not divisible by {n} microbatches")
        return x.reshape((n, B // n) + x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    model: Any,
    opt_cfg: OptimizerConfig,
    *,
    remat_policy: Optional[str] = "nothing",
    microbatches: int = 1,
    compute_dtype=jnp.bfloat16,
    compressor: Optional[Any] = None,
    grad_shardings: Optional[Pytree] = None,
    grad_reduce_dtype: str = "bf16",
) -> Callable[[Pytree, Dict[str, Any], Pytree], Tuple[Pytree, Dict[str, Any], Dict]]:
    """Build the train step for a model with a ``.loss(params, batch)``.

    ``grad_shardings`` (same tree as params) pins the gradients to the
    parameter shardings right at the autodiff output.  Under SPMD this
    pushes the cross-batch-shard gradient combine toward a reduce-scatter
    into the FSDP shards instead of a full all-reduce.

    ``grad_reduce_dtype="bf16"`` differentiates *through the bf16 compute
    params* (the fp32 master cast happens outside autodiff), so the
    per-layer cross-shard gradient reduction moves bf16 on the wire — half
    the bytes of the fp32 reduce (EXPERIMENTS.md §Perf it.3).  The fp32
    conversion for the optimizer happens after the reduce; Adam moments and
    master params stay fp32.  ``"f32"`` keeps the paper-faithful baseline
    behaviour (cast inside autodiff, fp32 reduce).
    """

    def loss_fn(params: Pytree, batch: Pytree) -> Tuple[jax.Array, Dict]:
        return model.loss(params, batch, remat_policy=remat_policy)

    def loss_fn_master(params: Pytree, batch: Pytree) -> Tuple[jax.Array, Dict]:
        compute_params = cast_params_for_compute(params, compute_dtype)
        return model.loss(compute_params, batch, remat_policy=remat_policy)

    bf16_reduce = grad_reduce_dtype == "bf16"
    grad_fn = jax.value_and_grad(
        loss_fn if bf16_reduce else loss_fn_master, has_aux=True
    )

    def compute_grads(params: Pytree, batch: Pytree):
        if bf16_reduce:
            cp = cast_params_for_compute(params, compute_dtype)
            if grad_shardings is not None:
                # pin the bf16 copy to the parameter shardings AND force it
                # to materialize (optimization_barrier): the ZeRO weight
                # all-gathers then move bf16 shards, not fp32 masters with
                # a fused convert (halves AG wire — §Perf it.4; costs one
                # sharded bf16 copy ≈ params/2N bytes of HBM per device)
                cp = jax.tree.map(
                    jax.lax.with_sharding_constraint, cp, grad_shardings
                )
                cp = jax.lax.optimization_barrier(cp)
            out, grads = grad_fn(cp, batch)
        else:
            out, grads = grad_fn(params, batch)
        if grad_shardings is not None:
            grads = jax.tree.map(
                jax.lax.with_sharding_constraint, grads, grad_shardings
            )
        return out, grads

    def train_step(
        params: Pytree, opt_state: Dict[str, Any], batch: Pytree
    ) -> Tuple[Pytree, Dict[str, Any], Dict[str, jax.Array]]:
        if microbatches > 1:
            micro = _microbatch_split(batch, microbatches)

            def body(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = compute_grads(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads
                )
                return (gsum, lsum + loss), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = lax.scan(
                body, (gzero, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics: Dict[str, jax.Array] = {"loss": loss}
        else:
            (loss, metrics), grads = compute_grads(params, batch)

        ef_state = opt_state.get("ef")
        opt_core = {k: v for k, v in opt_state.items() if k != "ef"}
        if compressor is not None:
            grads, ef_state = compressor.apply(grads, ef_state)

        params_new, opt_new, opt_metrics = adamw_update(
            params, grads, opt_core, opt_cfg
        )
        if ef_state is not None:
            opt_new["ef"] = ef_state
        metrics = dict(metrics, **opt_metrics)
        return params_new, opt_new, metrics

    return train_step
