"""Training substrate: sharded AdamW, train step, microbatching."""

from .optimizer import OptimizerConfig, adamw_update, global_norm, init_opt_state, lr_at
from .train_step import cast_params_for_compute, make_train_step

__all__ = [
    "OptimizerConfig",
    "adamw_update",
    "global_norm",
    "init_opt_state",
    "lr_at",
    "cast_params_for_compute",
    "make_train_step",
]
