"""Fault-tolerant training controller.

Runs the training loop with:
  - periodic (async) checkpointing through ``checkpoint.CheckpointManager``,
  - automatic restart from the latest checkpoint after a (simulated or real)
    failure — the restart path is the same code as cold start,
  - TTL'd retry of failed steps (the paper's requeue mechanism applied to
    training steps: a step that dies — e.g. a preempted worker — is retried
    from the last checkpoint up to ``step_ttl`` times before aborting),
  - straggler mitigation hook: a step exceeding ``straggler_factor`` x the
    moving-average step time is recorded and (on a real cluster) would
    trigger backup re-dispatch; here it feeds the profiler metrics.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.profiler import MasterProfiler, ProfilerConfig

__all__ = ["TrainController", "TrainControllerConfig"]

Pytree = Any


@dataclasses.dataclass
class TrainControllerConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    async_checkpoint: bool = True
    step_ttl: int = 3
    straggler_factor: float = 3.0
    keep_checkpoints: int = 3


class TrainController:
    def __init__(
        self,
        train_step: Callable[[Pytree, Pytree, Pytree], Tuple[Pytree, Pytree, Dict]],
        config: Optional[TrainControllerConfig] = None,
    ):
        self.cfg = config or TrainControllerConfig()
        self.train_step = train_step
        self.ckpt = CheckpointManager(
            self.cfg.checkpoint_dir, keep=self.cfg.keep_checkpoints
        )
        self.profiler = MasterProfiler(ProfilerConfig(window=32, default_size=0.5))
        self.stragglers: List[int] = []
        self.restarts: int = 0

    # ---- restore-or-init ---------------------------------------------------------
    def init_state(
        self,
        init_fn: Callable[[], Tuple[Pytree, Pytree]],
        shardings: Optional[Tuple[Pytree, Pytree]] = None,
    ) -> Tuple[Pytree, Pytree, int]:
        """Restore from the latest checkpoint if present, else cold-start."""
        latest = self.ckpt.latest_step()
        params, opt_state = init_fn()
        if latest is None:
            return params, opt_state, 0
        shard_tree = (
            {"p": shardings[0], "o": shardings[1]} if shardings else None
        )
        combined = self.ckpt.restore(
            latest, {"p": params, "o": opt_state}, shard_tree
        )
        return combined["p"], combined["o"], latest

    # ---- main loop -----------------------------------------------------------------
    def run(
        self,
        params: Pytree,
        opt_state: Pytree,
        batches: Iterator[Pytree],
        *,
        num_steps: int,
        start_step: int = 0,
        fail_at: Optional[int] = None,   # simulated failure injection (tests)
        on_metrics: Optional[Callable[[int, Dict], None]] = None,
    ) -> Tuple[Pytree, Pytree, Dict[str, Any]]:
        cfg = self.cfg
        step = start_step
        step_times: List[float] = []
        metrics: Dict[str, Any] = {}
        attempts = 0

        while step < num_steps:
            try:
                batch = next(batches)
            except StopIteration:
                break
            t0 = time.perf_counter()
            try:
                if fail_at is not None and step == fail_at and attempts == 0:
                    attempts += 1
                    raise RuntimeError(f"injected failure at step {step}")
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch
                )
                jax.block_until_ready(jax.tree.leaves(params)[0])
            except Exception:
                # failure path: restart from the latest checkpoint (TTL'd)
                self.restarts += 1
                if self.restarts > cfg.step_ttl:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is not None:
                    combined = self.ckpt.restore(
                        latest, {"p": params, "o": opt_state}
                    )
                    params, opt_state = combined["p"], combined["o"]
                    step = latest
                continue

            dt = time.perf_counter() - t0
            if step_times and dt > cfg.straggler_factor * float(
                np.mean(step_times[-16:])
            ):
                self.stragglers.append(step)
            step_times.append(dt)
            self.profiler.observe("train_step", min(1.0, dt))

            step += 1
            if step % cfg.checkpoint_every == 0 or step == num_steps:
                self.ckpt.save(
                    step,
                    {"p": params, "o": opt_state},
                    blocking=not cfg.async_checkpoint,
                )
            if on_metrics is not None:
                on_metrics(step, metrics)

        self.ckpt.wait()
        summary = {
            "final_step": step,
            "restarts": self.restarts,
            "stragglers": self.stragglers,
            "mean_step_time": float(np.mean(step_times)) if step_times else 0.0,
            "last_metrics": metrics,
        }
        return params, opt_state, summary
