"""Sharded AdamW with warmup+cosine schedule and global-norm clipping.

Optimizer state mirrors the parameter tree, so it inherits the parameter
shardings (ZeRO-style: with FSDP-sharded params the m/v moments are sharded
identically — no extra work needed under pjit).  Master params are fp32;
the forward cast to bf16 happens in the train step.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "init_opt_state", "adamw_update", "lr_at"]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.decay_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(math.pi * frac)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_opt_state(params: Pytree) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Pytree,
    grads: Pytree,
    state: Dict[str, Any],
    cfg: OptimizerConfig,
) -> Tuple[Pytree, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    t = step.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    lr = lr_at(cfg, step)

    def upd(p, m_, v_):
        u = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": m, "v": v, "step": step}, metrics
