"""Production serving driver.

Runs the IRM-scheduled continuous-batching engine against either the
discrete-time simulated backend (capacity planning / control-plane soak,
``--backend sim``) or a real model executing prefill + decode on the local
devices (``--backend local``, reduced config on CPU).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --backend sim --requests 500
  PYTHONPATH=src python -m repro.launch.serve --backend local \
      --arch qwen3-8b --smoke --requests 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..configs import ARCH_NAMES, get_config
from ..serving import EngineConfig, ReplicaConfig, Request, ServingEngine


def run_sim(args: argparse.Namespace) -> None:
    cfg = EngineConfig(
        replica=ReplicaConfig(
            max_slots=args.slots, kv_pages=args.pages,
            prefill_tokens_per_s=100_000.0, decode_tokens_per_s=8_000.0,
            spinup_delay=5.0,
        ),
        max_replicas=args.replicas,
        dt=0.1,
    )
    eng = ServingEngine(cfg)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(Request(prompt_len=int(rng.integers(128, 2048)),
                           max_new_tokens=int(rng.integers(32, 512))))
    eng.run_until_drained(t_max=3600.0)
    s = eng.summary()
    print(f"completed {s['completed']}/{args.requests}  "
          f"makespan {s['makespan']:.1f}s  p50 {s['p50_latency']:.2f}s  "
          f"p99 {s['p99_latency']:.2f}s  peak replicas {s['peak_replicas']}")


def run_local(args: argparse.Namespace) -> None:
    import jax
    import jax.numpy as jnp

    from ..models import build_model, init_params

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    B = min(args.requests, 8)
    prompt_len, gen = 16, args.gen_tokens
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(B, prompt_len)), jnp.int32
    )
    batch = {
        "tokens": prompts,
        "segment_ids": jnp.ones((B, prompt_len), jnp.int32),
        "positions": jnp.broadcast_to(
            jnp.arange(prompt_len, dtype=jnp.int32), (B, prompt_len)
        ),
    }
    if cfg.encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, prompt_len, cfg.d_model)) * 0.02, jnp.float32)
        batch["enc_segment_ids"] = jnp.ones((B, prompt_len), jnp.int32)
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.float32)

    t0 = time.perf_counter()
    logits, cache = model.prefill(params, batch)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for _ in range(gen):
        logits, cache = decode(params, {"tokens": toks}, cache)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"served {B} sequences x {gen} tokens in {dt:.2f}s "
          f"({B * gen / dt:.1f} tok/s on {jax.default_backend()})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sim", choices=["sim", "local"])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--replicas", type=int, default=5)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--pages", type=int, default=1024)
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()
    if args.backend == "sim":
        run_sim(args)
    else:
        run_local(args)


if __name__ == "__main__":
    main()
