import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: parameters and
inputs are ``ShapeDtypeStruct`` stand-ins (zero allocation), the jit'd step
is lowered with the production shardings and compiled by XLA's SPMD
partitioner for the 16x16 (single-pod) and 2x16x16 (multi-pod) meshes.
``memory_analysis()`` proves the per-device footprint fits; the cost /
collective numbers feed EXPERIMENTS.md §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, SHAPES_BY_NAME, cells_for, get_config
from ..distributed.context import activation_sharding
from ..distributed.sharding import (
    batch_shardings,
    cache_shardings,
    make_rules,
    param_shardings,
)
from ..models import abstract_params, build_model, cache_specs, input_specs
from ..models.params import Spec, tree_bytes
from ..training import OptimizerConfig, make_train_step
from .analysis import HW, cost_summary, memory_summary
from .hlo_analysis import analyze_hlo_text
from .mesh import make_production_mesh

PER_POD_CHIPS = 256


def _abstract_opt_state(param_specs_tree: Any) -> Any:
    def sds(s: Spec) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(s.shape, jnp.float32)

    is_spec = lambda x: isinstance(x, Spec)  # noqa: E731
    return {
        "m": jax.tree.map(sds, param_specs_tree, is_leaf=is_spec),
        "v": jax.tree.map(sds, param_specs_tree, is_leaf=is_spec),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    remat_policy: str = "nothing",
    microbatches: int = 1,
    param_dtype=jnp.float32,
    keep_hlo: bool = False,
    layout: str = "tp",
) -> Dict[str, Any]:
    """Lower + compile one cell; returns the roofline record."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, layout)
    n_chips = mesh.devices.size

    specs = model.param_specs()
    p_shard = param_shardings(specs, mesh, rules)
    batch = input_specs(cfg, shape)
    b_shard = batch_shardings(batch, mesh, rules, decode=(shape.kind == "decode"))

    t0 = time.time()
    if shape.kind == "train":
        params = abstract_params(specs)  # fp32 master
        opt_state = _abstract_opt_state(specs)
        o_shard = {
            "m": p_shard,
            "v": p_shard,
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        step_fn = make_train_step(
            model,
            OptimizerConfig(),
            remat_policy=remat_policy,
            microbatches=microbatches,
            grad_shardings=p_shard,
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, b_shard),
            donate_argnums=(0, 1),
        )
        with mesh, activation_sharding(mesh, rules):
            lowered = jitted.lower(params, opt_state, batch)
    elif shape.kind == "prefill":
        params = abstract_params(specs, dtype=jnp.bfloat16)
        jitted = jax.jit(
            lambda p, b: model.prefill(p, b),
            in_shardings=(p_shard, b_shard),
        )
        with mesh, activation_sharding(mesh, rules):
            lowered = jitted.lower(params, batch)
    else:  # decode
        params = abstract_params(specs, dtype=jnp.bfloat16)
        cache = cache_specs(cfg, shape)
        c_shard = cache_shardings(cache, mesh, rules)
        jitted = jax.jit(
            lambda p, b, c: model.decode_step(p, b, c),
            in_shardings=(p_shard, b_shard, c_shard),
            donate_argnums=(2,),
        )
        with mesh, activation_sharding(mesh, rules):
            lowered = jitted.lower(params, batch, cache)

    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = memory_summary(compiled)
    cost = cost_summary(compiled)  # XLA's own (loop bodies counted once)
    hlo = analyze_hlo_text(
        compiled.as_text(), pod_size=PER_POD_CHIPS if multi_pod else 10**9
    )

    total_params, active_params = cfg.param_counts()
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips),
        "compile_seconds": round(compile_s, 1),
        "param_count": total_params,
        "active_param_count": active_params,
        "param_bytes_global": tree_bytes(abstract_params(specs, dtype=param_dtype)),
        "memory": mem,
        "xla_cost": cost,
        "flops_per_dev": hlo.flops,
        "dot_bytes_per_dev": hlo.dot_bytes,
        "collectives": dict(hlo.coll, total=hlo.coll_bytes,
                            ici=hlo.ici_bytes, dcn=hlo.dcn_bytes,
                            count=hlo.coll_count),
        "remat_policy": remat_policy,
        "microbatches": microbatches,
        "layout": layout,
    }
    record.update(roofline_terms(record, shape))
    if keep_hlo:
        record["_hlo_text"] = compiled.as_text()
    return record


def roofline_terms(record: Dict[str, Any], shape) -> Dict[str, Any]:
    """Three roofline terms (seconds per step, per chip).

    FLOPs/bytes come from the trip-count-aware HLO analysis (XLA's
    cost_analysis counts loop bodies once — see hlo_analysis.py).  The
    memory term uses dot operand/result traffic as the HBM proxy (weights,
    activations, KV reads are all dot operands; elementwise traffic is
    fusion-resident).  The collective term takes the slower of the ICI and
    DCN paths.
    """
    flops = record["flops_per_dev"]
    bytes_acc = max(
        record["dot_bytes_per_dev"], record["xla_cost"]["bytes_accessed"]
    )
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_collective = (
        record["collectives"]["ici"] / HW["ici_bw"]
        + record["collectives"]["dcn"] / HW["dcn_bw"]
    )
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    # MODEL_FLOPS: 6*N*D for training, 2*N*D for inference (per step, global)
    n_active = record["active_param_count"]
    tokens = (
        shape.global_batch * shape.seq_len
        if shape.kind in ("train", "prefill")
        else shape.global_batch
    )
    # enc-dec (seamless): S is split S/2 encoder + S/2 decoder and each
    # half only passes through its own stack — 6*N_total*(S/2) overall
    if get_config(record["arch"]).encdec and shape.kind in ("train", "prefill"):
        tokens //= 2
    mult = 6 if shape.kind == "train" else 2
    model_flops_global = mult * n_active * tokens
    model_flops_per_chip = model_flops_global / record["chips"]
    useful = model_flops_per_chip / flops if flops else 0.0
    bound = max(t_compute, t_memory, t_collective)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "model_flops_global": model_flops_global,
        "useful_flops_fraction": useful,
        "roofline_step_s": bound,
        "model_flops_util": (
            model_flops_per_chip / HW["peak_flops_bf16"] / bound if bound else 0.0
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_NAMES + [None])
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--shapes", default=None,
                    help="comma-separated shape filter for --all")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--layout", default="tp",
                    choices=["tp", "fsdp", "serve"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        keep = set(args.shapes.split(",")) if args.shapes else None
        for arch in ARCH_NAMES:
            cfg = get_config(arch)
            for shape in cells_for(cfg):
                if keep and shape.name not in keep:
                    continue
                cells.append((arch, shape.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]

    results = []
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
            try:
                rec = lower_cell(
                    arch, shape_name, multi_pod=mp,
                    remat_policy=args.remat, microbatches=args.microbatches,
                    layout=args.layout,
                )
                results.append(rec)
                print(
                    f"[OK] {tag}: compile={rec['compile_seconds']}s "
                    f"hbm/dev={rec['memory']['total_hbm_bytes']/1e9:.2f}GB "
                    f"flops/dev={rec['flops_per_dev']:.3e} "
                    f"coll/dev={rec['collectives']['total']/1e6:.1f}MB "
                    f"dominant={rec['dominant']} "
                    f"useful={rec['useful_flops_fraction']:.2f} "
                    f"mfu_bound={rec['model_flops_util']:.3f}",
                    flush=True,
                )
            except Exception as e:  # a failure here is a bug in the system
                results.append(
                    {"arch": arch, "shape": shape_name,
                     "mesh": "2x16x16" if mp else "16x16",
                     "error": f"{type(e).__name__}: {e}"}
                )
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records to {args.out}")

    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n{len(results) - n_fail}/{len(results)} cells OK")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
