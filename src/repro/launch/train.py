"""Production training driver.

On a real TPU slice this is the per-host entry point: it builds the
production mesh, shards params/optimizer with the rule table, wires the
IRM-packed streaming pipeline, and runs the fault-tolerant controller
(async checkpoints, restart-on-failure).  On this CPU container it runs the
same code path on the local mesh with a reduced config — the same launcher,
smaller geometry (``--smoke``).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 20
  # on hardware:
  python -m repro.launch.train --arch qwen2-72b --shape train_4k \
      --mesh single-pod
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, SHAPES_BY_NAME, get_config
from ..data import StreamingPipeline, synthetic_documents
from ..distributed.context import activation_sharding
from ..distributed.sharding import batch_shardings, make_rules, param_shardings
from ..models import build_model, init_params
from ..training import OptimizerConfig, init_opt_state, make_train_step
from ..training.controller import TrainController, TrainControllerConfig
from .mesh import make_local_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single-pod", "multi-pod"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="nothing",
                    choices=["nothing", "dots", "everything"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = SHAPES_BY_NAME[args.shape]
    seq_len = args.seq_len or (256 if args.smoke else shape.seq_len)
    batch = args.batch_size or (4 if args.smoke else shape.global_batch)

    mesh = (
        make_local_mesh()
        if args.mesh == "local"
        else make_production_mesh(multi_pod=args.mesh == "multi-pod")
    )
    rules = make_rules(mesh)
    model = build_model(cfg)
    specs = model.param_specs()
    p_shard = param_shardings(specs, mesh, rules)

    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"seq={seq_len} batch={batch}")
    with mesh, activation_sharding(mesh, rules):
        params = jax.jit(
            lambda k: init_params(specs, k), out_shardings=p_shard
        )(jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)
        step_fn = jax.jit(
            make_train_step(
                model,
                OptimizerConfig(decay_steps=max(args.steps, 100)),
                remat_policy=args.remat,
                microbatches=args.microbatches,
            ),
            donate_argnums=(0, 1),
        )

        pipe = StreamingPipeline(
            synthetic_documents(cfg.vocab_size, mean_len=seq_len // 3,
                                max_len=4 * seq_len, seed=0),
            seq_len=seq_len, batch_size=batch, prefetch=4,
        )
        b_shard = None

        def batches():
            nonlocal b_shard
            for pb in pipe:
                host = {
                    "tokens": pb.tokens,
                    "labels": pb.labels,
                    "segment_ids": pb.segment_ids,
                    "positions": pb.positions,
                }
                if b_shard is None:
                    b_shard = batch_shardings(
                        {k: jax.ShapeDtypeStruct(v.shape, jnp.int32)
                         for k, v in host.items()},
                        mesh, rules,
                    )
                yield {
                    k: jax.device_put(v, b_shard[k]) for k, v in host.items()
                }

        ctl = TrainController(step_fn, TrainControllerConfig(
            checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every,
        ))
        params, opt_state, start = ctl.init_state(
            lambda: (params, opt_state),
        )

        t0 = time.perf_counter()

        def on_metrics(step, metrics):
            if step % 10 == 0 or step == start + 1:
                print(f"step {step:>5}  loss {float(metrics['loss']):.4f}  "
                      f"grad_norm {float(metrics['grad_norm']):.3f}")

        params, opt_state, summary = ctl.run(
            params, opt_state, batches(), num_steps=args.steps,
            start_step=start, on_metrics=on_metrics,
        )
        dt = time.perf_counter() - t0
        done = summary["final_step"] - start
        print(f"\n{done} steps in {dt:.1f}s "
              f"({done * batch * seq_len / dt:,.0f} tok/s); "
              f"restarts={summary['restarts']}")


if __name__ == "__main__":
    main()
