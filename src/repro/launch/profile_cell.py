import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Dry-run profiler: the per-cell debugging view for the §Perf loop.

Lowers one (arch x shape x mesh) cell exactly like dryrun.py and prints the
LARGEST collective contributors (with loop multipliers applied), the
roofline terms, and memory.  This is the 'profile' on a CPU-only container:
the optimized HLO is the ground truth for what the SPMD partitioner will
move over the wire.

Usage:
  PYTHONPATH=src python -m repro.launch.profile_cell --arch qwen2-72b \
      --shape train_4k [--multi-pod] [--remat dots] [--microbatches 4]
"""

import argparse

from .dryrun import lower_cell
from .hlo_analysis import top_collectives


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--layout", default="tp",
                    choices=["tp", "fsdp", "serve"])
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    import json
    # lower_cell recompiles; reuse its record and re-lower for the text
    rec = lower_cell(
        args.arch, args.shape, multi_pod=args.multi_pod,
        remat_policy=args.remat, microbatches=args.microbatches,
        keep_hlo=True, layout=args.layout,
    )
    print(json.dumps(
        {k: rec[k] for k in (
            "arch", "shape", "mesh", "chips", "compile_seconds",
            "t_compute_s", "t_memory_s", "t_collective_s", "dominant",
            "useful_flops_fraction", "model_flops_util",
        )}, indent=1))
    print("memory/dev: "
          f"{rec['memory']['total_hbm_bytes'] / 1e9:.2f} GB "
          f"(peak {rec['memory']['peak_memory_in_bytes'] / 1e9:.2f} GB, "
          f"temp {rec['memory']['temp_size_in_bytes'] / 1e9:.2f} GB)")
    print("collectives/dev: "
          + ", ".join(f"{k}={v / 1e9:.2f}GB"
                      for k, v in rec["collectives"].items()
                      if k not in ("count",) and v))

    hlo = rec["_hlo_text"]
    print(f"\ntop {args.top} collective contributors "
          "(bytes x loop multipliers, per device):")
    pod = 256 if args.multi_pod else 10 ** 9
    for name, kind, wire, mult in top_collectives(hlo, n=args.top,
                                                  pod_size=pod):
        print(f"  {wire / 1e9:>9.3f} GB  x{mult:<6.0f} {kind:<18} {name}")


if __name__ == "__main__":
    main()
