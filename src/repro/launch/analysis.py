"""Compiled-artifact analysis: cost, memory, and collective bytes.

``collective_bytes`` parses the optimized HLO text and sums the operand
sizes of every cross-device collective (all-gather, all-reduce,
reduce-scatter, all-to-all, collective-permute) — the quantity
``cost_analysis()`` does not report, needed for the roofline's collective
term.  Shapes are parsed from the HLO type syntax (``bf16[16,1024]{...}``).
"""

from __future__ import annotations

import re
from typing import Any, Dict

__all__ = [
    "collective_bytes",
    "cost_summary",
    "memory_summary",
    "DTYPE_BYTES",
    "HW",
]

DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# TPU v5e hardware constants (per chip) — the roofline denominators.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link (~3D torus links)
    "dcn_bw": 6.25e9,            # B/s per chip across pods (25 GB/s / host)
    "hbm_bytes": 16e9,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _parse_shape_bytes(type_str: str) -> float:
    """Bytes of one HLO type like ``bf16[16,1024]`` (tuples handled upstream)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes per collective kind over the optimized HLO.

    Uses the *result* shape of each collective op (for all-gather this is the
    gathered size; for all-reduce the reduced tensor; for reduce-scatter the
    scattered shard) — a consistent, conservative proxy for bytes moved per
    device.  Fusion-internal ops are not collectives, so line-level scanning
    is exact for this purpose.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_OPS}
    out["count"] = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        # match `%name = TYPE op-name(...)` forms; skip -start/-done pairs'
        # duplicates by counting only the -start (or the sync form)
        for op in _COLLECTIVE_OPS:
            if f" {op}(" in s or f" {op}-start(" in s:
                lhs = s.split("=", 1)
                if len(lhs) != 2:
                    continue
                # result type is between '=' and the op name
                rhs = lhs[1]
                idx = rhs.find(op)
                type_str = rhs[:idx]
                out[op] += _parse_shape_bytes(type_str)
                out["count"] += 1
                break
    out["total"] = sum(v for k, v in out.items() if k in _COLLECTIVE_OPS)
    return out


def cost_summary(compiled: Any) -> Dict[str, float]:
    """Normalize cost_analysis() across jax versions (dict or list-of-dict)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes_accessed": bytes_accessed, "raw_keys": len(ca)}


def memory_summary(compiled: Any) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out: Dict[str, float] = {}
    for key in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "peak_memory_in_bytes",
        "generated_code_size_in_bytes",
    ):
        out[key] = float(getattr(ma, key, 0.0))
    out["total_hbm_bytes"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    return out
