"""Trip-count-aware static analysis of optimized HLO.

``compiled.cost_analysis()`` counts a while-loop body ONCE, independent of
its trip count (verified empirically — see tests/test_hlo_analysis.py), which
silently undercounts every ``lax.scan``-based program: our layer stacks,
flash-attention chunk loops, chunked recurrent scans and chunked CE are all
scans.  This module re-derives the per-device cost from the HLO text with
loop multipliers:

  - dot/convolution FLOPs (the dominant terms) computed from shapes,
  - collective wire bytes per kind, ICI vs DCN classified from replica
    groups (a group whose members span >= one pod crosses the DCN),
  - dot operand/result bytes as an HBM-traffic proxy,

all accumulated recursively: fusions/calls x1, while bodies x trip count
(extracted from the loop condition's comparison constant — exact for scans),
conditionals take the max branch.

Wire-byte conventions per device (ring algorithms, documented in
EXPERIMENTS.md): all-reduce 2x tensor bytes (RS+AG), all-gather = output
bytes, reduce-scatter = input bytes, all-to-all / collective-permute =
tensor bytes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo_text", "analyze_module"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(.*?)\s([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _first_shape(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ("", [])
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return (m.group(1), dims)


def _all_shapes_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    dot_bytes: float = 0.0           # dot operand+result bytes (HBM proxy)
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    coll_count: float = 0.0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            flops=self.flops * k,
            dot_bytes=self.dot_bytes * k,
            coll={key: v * k for key, v in self.coll.items()},
            ici_bytes=self.ici_bytes * k,
            dcn_bytes=self.dcn_bytes * k,
            coll_count=self.coll_count * k,
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.dot_bytes += other.dot_bytes
        for k, v in other.coll.items():
            self.coll[k] += v
        self.ici_bytes += other.ici_bytes
        self.dcn_bytes += other.dcn_bytes
        self.coll_count += other.coll_count

    @property
    def coll_bytes(self) -> float:
        return self.ici_bytes + self.dcn_bytes


class _Op:
    __slots__ = ("name", "rtype", "opcode", "operands", "attrs", "raw")

    def __init__(self, name: str, rtype: str, opcode: str, operands: List[str],
                 attrs: str, raw: str = ""):
        self.name = name
        self.rtype = rtype
        self.opcode = opcode
        self.operands = operands
        self.attrs = attrs
        self.raw = raw


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None
    ops: List[_Op] = []
    for raw in text.splitlines():
        line = raw.split(", metadata=")[0].rstrip()
        if current is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                current = m.group(2)
                if m.group(1):
                    entry = current
                ops = []
            continue
        if line.strip() == "}":
            comps[current] = ops
            current = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        mo = _OPCODE_RE.match(rest)
        if not mo:
            continue
        rtype, opcode = mo.group(1).strip(), mo.group(2)
        # operands: content of the first (...) after the opcode
        start = rest.find(opcode + "(") + len(opcode) + 1
        depth, i = 1, start
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str = rest[start : i - 1]
        # Operand references come in two printer styles: bare (``%p0``) and
        # typed (``f32[128,256]{1,0} %Arg_0.1`` — jax 0.4.x compiled text).
        # Either way the %name token ends the operand chunk.
        operands = []
        for o in re.split(r",\s*(?![^{]*})", operand_str):
            o = o.strip()
            if o.startswith("%"):
                operands.append(o.lstrip("%"))
            elif o:
                mo2 = re.search(r"%([\w\.\-]+)\s*$", o)
                if mo2:
                    operands.append(mo2.group(1))
        attrs = rest[i:]
        ops.append(_Op(name, rtype, opcode, operands, attrs, raw=line))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _cond_trips(comps: Dict[str, List[_Op]], cond_name: str) -> int:
    """Max integer constant in the loop condition (exact for lax.scan:
    the induction variable starts at 0, steps by 1, compares LT bound)."""
    best = 1
    ops = comps.get(cond_name, [])
    text_parts = []
    for op in ops:
        text_parts.append(op.raw)
        # follow called fusions (the compare often lives inside one)
        m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
        if m:
            for sub in comps.get(m.group(1), []):
                text_parts.append(sub.raw)
    for m in _CONST_RE.finditer(" ".join(text_parts)):
        best = max(best, int(m.group(1)))
    return best


def _replica_groups_cross_pod(attrs: str, pod_size: int) -> bool:
    """True if any replica group spans devices >= pod_size apart."""
    m = re.search(r"replica_groups=\{(.*?)\}\}", attrs)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in re.findall(r"\d+", grp)]
            if ids and (max(ids) - min(ids)) >= pod_size:
                return True
        return False
    # iota format: replica_groups=[2,256]<=[512] etc.
    m = re.search(r"replica_groups=\[([\d,]+)\]<=\[(\d+)\]", attrs)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        total = int(m.group(2))
        # group size = dims[-1]? iota grouping: first dim = num groups
        if len(dims) >= 2:
            group_sz = dims[-1]
            # conservative: a group that is not contiguous within a pod
            return group_sz > pod_size or total > pod_size and dims[0] < (
                total // pod_size
            )
    return False


def _build_consumers(ops: List[_Op]) -> Dict[str, List[_Op]]:
    out: Dict[str, List[_Op]] = {}
    for op in ops:
        for operand in op.operands:
            out.setdefault(operand, []).append(op)
    return out


def _ar_is_scatterable(
    op: _Op, consumers: Dict[str, List[_Op]]
) -> bool:
    """True if this all-reduce is the AR half of an AR+dynamic-slice pair.

    The XLA *TPU* pipeline rewrites ``all-reduce`` whose result is
    immediately (dynamic-)sliced to the consumer's shard into a
    ``reduce-scatter`` (ReduceScatterCreator); the CPU pipeline this
    dry-run compiles under does not run that pass.  Detecting the pattern
    keeps the collective roofline term faithful to the TPU target: wire =
    1x tensor bytes (ring RS) instead of 2x (ring AR).

    Pattern matched: every transitive consumer (through get-tuple-element
    and async -done hops) is a dynamic-slice / dynamic-update-slice op or
    a fusion named for one.
    """
    frontier = list(consumers.get(op.name, []))
    effective: List[_Op] = []
    hops = 0
    while frontier and hops < 1000:
        c = frontier.pop()
        hops += 1
        if c.opcode == "get-tuple-element" or c.opcode.endswith("-done"):
            frontier.extend(consumers.get(c.name, []))
        else:
            effective.append(c)
    if not effective:
        return False
    for c in effective:
        if c.opcode in ("dynamic-slice", "dynamic-update-slice"):
            continue
        if c.opcode == "fusion" and (
            "dynamic-update-slice" in c.name or "dynamic-slice" in c.name
        ):
            continue
        return False
    return True


def _is_bf16_promoted(
    name: str, by_name: Dict[str, _Op], comps: Dict[str, List[_Op]]
) -> bool:
    """True if the named f32 value is a CPU-promoted bf16 tensor.

    The CPU backend (the dry-run vehicle) has no native bf16 compute: XLA
    promotes bf16 values to f32 via ``convert`` round-trips (usually fused
    as ``convert_convert`` kLoop fusions).  On the TPU target the same
    value is bf16.  Detection: the producer is a convert-from-bf16, or a
    fusion whose body contains a bf16 value.
    """
    producer = by_name.get(name)
    if producer is None:
        return False
    if producer.opcode == "convert" and producer.operands:
        src = by_name.get(producer.operands[0])
        if src is not None and src.rtype.strip().startswith("bf16"):
            return True
    if producer.opcode != "fusion":
        return False
    m = re.search(r"calls=%?([\w\.\-]+)", producer.attrs)
    if not m:
        return False
    sub = comps.get(m.group(1), [])
    return any(o.rtype.strip().startswith("bf16") for o in sub)


def _payload_scale(
    op: _Op, by_name: Dict[str, _Op], comps: Dict[str, List[_Op]]
) -> float:
    """0.5 if this f32 collective carries a semantically-bf16 payload."""
    if not op.rtype.strip().startswith(("f32", "(f32")):
        return 1.0
    if not op.operands:
        return 1.0
    return 0.5 if _is_bf16_promoted(op.operands[0], by_name, comps) else 1.0


def analyze_module(
    comps: Dict[str, List[_Op]], *, pod_size: int = 10**9
) -> HloCost:
    memo: Dict[str, HloCost] = {}

    def shapes_of(ops: List[_Op]) -> Dict[str, str]:
        return {op.name: op.rtype for op in ops}

    def cost_of(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        memo[name] = HloCost()  # break cycles defensively
        ops = comps.get(name, [])
        table = shapes_of(ops)
        consumers = _build_consumers(ops)
        by_name = {op.name: op for op in ops}
        total = HloCost()
        for op in ops:
            oc = op.opcode
            if oc == "dot":
                _, rdims = _first_shape(op.rtype)
                lhs_type = table.get(op.operands[0], "") if op.operands else ""
                _, ldims = _first_shape(lhs_type)
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
                k = 1
                if m and ldims:
                    for idx in m.group(1).split(","):
                        if idx:
                            k *= ldims[int(idx)]
                total.flops += 2.0 * _numel(rdims) * k
                rhs_type = table.get(op.operands[1], "") if len(op.operands) > 1 else ""
                # HBM proxy: operand/result bytes, discounted to bf16 where
                # the f32 operand is a CPU-promoted bf16 value (see
                # _is_bf16_promoted — the TPU target reads bf16)
                lhs_scale = (
                    0.5 if _is_bf16_promoted(op.operands[0], by_name, comps)
                    else 1.0
                ) if op.operands else 1.0
                rhs_scale = (
                    0.5 if len(op.operands) > 1 and _is_bf16_promoted(
                        op.operands[1], by_name, comps) else 1.0
                )
                total.dot_bytes += (
                    _all_shapes_bytes(op.rtype)
                    + lhs_scale * _all_shapes_bytes(lhs_type)
                    + rhs_scale * _all_shapes_bytes(rhs_type)
                )
            elif oc == "convolution":
                _, rdims = _first_shape(op.rtype)
                m = re.search(r"size=([\dx]+)", op.attrs)
                window = 1
                if m:
                    for w in m.group(1).split("x"):
                        window *= int(w)
                total.flops += 2.0 * _numel(rdims) * window
            elif oc.removesuffix("-start") in _COLLECTIVES:
                base = oc[:-6] if oc.endswith("-start") else oc
                if base not in _COLLECTIVES:
                    continue
                out_bytes = _all_shapes_bytes(op.rtype)
                if oc.endswith("-start"):
                    out_bytes /= 2.0  # tuple of (operand, result) buffers
                if base == "all-reduce":
                    if _ar_is_scatterable(op, consumers):
                        wire = out_bytes  # TPU pipeline: AR+DS -> RS
                    else:
                        wire = 2.0 * out_bytes
                elif base == "reduce-scatter":
                    in_bytes = (
                        _all_shapes_bytes(table.get(op.operands[0], ""))
                        if op.operands
                        else out_bytes
                    )
                    wire = in_bytes
                else:
                    wire = out_bytes
                wire *= _payload_scale(op, by_name, comps)
                total.coll[base] += wire
                total.coll_count += 1
                if _replica_groups_cross_pod(op.attrs, pod_size):
                    total.dcn_bytes += wire
                else:
                    total.ici_bytes += wire
            elif oc == "fusion" or oc == "call":
                m = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.attrs)
                if m:
                    total.add(cost_of(m.group(1)))
            elif oc == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                if mb:
                    trips = _cond_trips(comps, mc.group(1)) if mc else 1
                    total.add(cost_of(mb.group(1)).scaled(trips))
            elif oc == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
                if m:
                    branches = [
                        b.strip().lstrip("%") for b in m.group(1).split(",")
                    ]
                    costs = [cost_of(b) for b in branches]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.coll_bytes)
                        total.add(best)
        memo[name] = total
        return total

    return cost_of("__entry__")


def analyze_hlo_text(text: str, *, pod_size: int = 10**9) -> HloCost:
    return analyze_module(_parse_computations(text), pod_size=pod_size)


def top_collectives(
    text: str, n: int = 20, *, pod_size: int = 10**9
) -> List[Tuple[str, str, float, float]]:
    """Largest collective contributors: (comp/op, kind, wire_bytes, multiplier).

    Loop multipliers are propagated down to each op so the listed bytes are
    whole-program contributions — the debugging view for the perf loop.
    """
    comps = _parse_computations(text)

    # compute the total loop multiplier of each computation (entry = 1)
    mult: Dict[str, float] = {"__entry__": 1.0}
    order = ["__entry__"]
    seen = {"__entry__"}
    while order:
        name = order.pop(0)
        m = mult.get(name, 0.0)
        for op in comps.get(name, []):
            for attr_key in ("calls", "to_apply", "body"):
                mm = re.search(rf"{attr_key}=%?([\w\.\-]+)", op.attrs)
                if not mm:
                    continue
                child = mm.group(1)
                k = 1.0
                if attr_key == "body":
                    mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                    k = _cond_trips(comps, mc.group(1)) if mc else 1.0
                mult[child] = mult.get(child, 0.0) + m * k
                if child not in seen:
                    seen.add(child)
                    order.append(child)

    rows: List[Tuple[str, str, float, float]] = []
    for cname, ops in comps.items():
        if cname == "__entry__":
            continue
        k = mult.get(cname, 0.0)
        if k <= 0 and cname != "__entry__":
            continue
        table = {op.name: op.rtype for op in ops}
        consumers = _build_consumers(ops)
        by_name = {op.name: op for op in ops}
        for op in ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base not in _COLLECTIVES:
                continue
            out_bytes = _all_shapes_bytes(op.rtype)
            if op.opcode.endswith("-start"):
                out_bytes /= 2.0
            if base == "all-reduce":
                if _ar_is_scatterable(op, consumers):
                    base = "all-reduce(rs)"
                    wire = out_bytes
                else:
                    wire = 2.0 * out_bytes
            elif base == "reduce-scatter" and op.operands:
                wire = _all_shapes_bytes(table.get(op.operands[0], ""))
            else:
                wire = out_bytes
            scale = _payload_scale(op, by_name, comps)
            if scale != 1.0:
                base += "[bf16]"
            rows.append((f"{cname}/{op.name}", base, wire * scale * k, k))
    # entry-level ops too
    for op in comps.get("__entry__", []):
        base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
        if base in _COLLECTIVES:
            out_bytes = _all_shapes_bytes(op.rtype)
            wire = 2.0 * out_bytes if base == "all-reduce" else out_bytes
            rows.append((f"entry/{op.name}", base, wire, 1.0))
    rows.sort(key=lambda r: -r[2])
    return rows[:n]
