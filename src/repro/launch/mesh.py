"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
initialization, while smoke tests and benchmarks see the real single device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    The ``pod`` axis extends data parallelism across the DCN: gradient
    reduction crosses pods, everything else stays pod-local.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """All local devices on the data axis (CPU smoke / small runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
