"""Multi-dimensional resource vectors — the value type of the vector IRM.

The paper's stated future-work direction (Section VII) is *multi-dimensional
vector bin-packing*: a worker is not just "1.0 of CPU" but a vector of named
capacities (CPU, memory, accelerator, ...), and a container hosting request
consumes a little of each.  ``Resources`` is the value type that flows
through the whole control plane for that mode: profiler estimates, host
request sizes, pre-filled allocator bins, scheduled worker loads, and the
load predictor's backlog demand are all either plain floats (the paper's
scalar CPU fraction — unchanged) or ``Resources`` vectors.

Design constraints, in order:

  1. **Scalar compatibility.**  Every dimension is a fraction of one worker
     in [0, 1]; dimension 0 is always ``"cpu"`` so a plain float and a 1-D
     ``Resources`` mean the same thing, and arithmetic on a 1-D vector is
     bit-for-bit the same IEEE-754 double math as the float path.
  2. **Value semantics.**  Instances are treated as immutable: every
     operation returns a new ``Resources``; nothing in the control plane
     mutates ``values`` in place.
  3. **Small.**  Backed by a tiny float64 ndarray (2-4 dims in practice);
     this is host-side control-plane data, never accelerator data.
"""

from __future__ import annotations

from typing import Iterable, Mapping, Sequence, Tuple, Union

import numpy as np

__all__ = ["Resources", "as_resources", "ResourceLike"]

#: What the control plane accepts wherever a size flows: the paper's scalar
#: CPU fraction, or a named resource vector.
ResourceLike = Union[float, "Resources"]


class Resources:
    """A named, fixed-order vector of per-worker resource fractions."""

    __slots__ = ("dims", "values")

    def __init__(self, dims: Sequence[str], values: Iterable[float]):
        self.dims: Tuple[str, ...] = tuple(dims)
        v = np.asarray(list(values) if not isinstance(values, np.ndarray)
                       else values, dtype=np.float64)
        if v.shape != (len(self.dims),):
            raise ValueError(
                f"values shape {v.shape} does not match dims {self.dims}"
            )
        if not self.dims:
            raise ValueError("Resources needs at least one dimension")
        self.values = v

    # -- constructors --------------------------------------------------------
    @classmethod
    def cpu(cls, x: float) -> "Resources":
        """1-D CPU-only vector — interchangeable with a plain float."""
        return cls(("cpu",), (float(x),))

    @classmethod
    def of(cls, **fractions: float) -> "Resources":
        """``Resources.of(cpu=0.3, mem=0.5)`` — dims in keyword order."""
        return cls(tuple(fractions), tuple(fractions.values()))

    @classmethod
    def zeros(cls, dims: Sequence[str]) -> "Resources":
        return cls(dims, np.zeros(len(tuple(dims))))

    @classmethod
    def full(cls, dims: Sequence[str], value: float) -> "Resources":
        return cls(dims, np.full(len(tuple(dims)), float(value)))

    # -- views ---------------------------------------------------------------
    def get(self, dim: str, default: float = 0.0) -> float:
        try:
            return float(self.values[self.dims.index(dim)])
        except ValueError:
            return default

    def align(self, dims: Sequence[str]) -> "Resources":
        """Reorder/extend to ``dims``; missing dimensions are zero."""
        dims = tuple(dims)
        if dims == self.dims:
            return self
        return Resources(dims, [self.get(d) for d in dims])

    def as_tuple(self) -> Tuple[float, ...]:
        return tuple(float(x) for x in self.values)

    def as_dict(self) -> Mapping[str, float]:
        return {d: float(v) for d, v in zip(self.dims, self.values, strict=True)}

    def to_float(self) -> float:
        """The scalar CPU fraction; only valid for 1-D vectors."""
        if len(self.dims) != 1:
            raise ValueError(
                f"cannot collapse {self.dims} to a scalar; use .get('cpu')"
            )
        return float(self.values[0])

    @property
    def is_scalar(self) -> bool:
        return len(self.dims) == 1

    # -- resource math -------------------------------------------------------
    def dominant(self, capacity: "Resources" = None) -> Tuple[str, float]:
        """(dimension, fraction) of the most-loaded dimension.

        With a ``capacity`` the fractions are utilizations ``v_d / cap_d`` —
        the *dominant resource* of dominant-resource fairness / the
        dominant-dimension lower bound.
        """
        if capacity is not None:
            caps = capacity.align(self.dims).values
            fracs = self.values / np.maximum(caps, 1e-12)
        else:
            fracs = self.values
        i = int(fracs.argmax())
        return self.dims[i], float(fracs[i])

    def clamp(self, lo_cpu: float, hi: float) -> "Resources":
        """Per-dimension clip to [0, hi]; dim 0 (cpu) floored at ``lo_cpu``.

        This is the profiler's size-clamp generalized: a packed item must be
        non-zero in CPU (the paper's (0, 1] item domain) while auxiliary
        dimensions may legitimately be zero.
        """
        v = np.minimum(np.maximum(self.values, 0.0), hi)
        v[0] = min(max(float(self.values[0]), lo_cpu), hi)
        return Resources(self.dims, v)

    # -- arithmetic (value semantics; scalar rhs only for * and /) -----------
    def __add__(self, other: "Resources") -> "Resources":
        if not isinstance(other, Resources):
            return NotImplemented
        if other.dims != self.dims:
            other = other.align(self.dims)
        return Resources(self.dims, self.values + other.values)

    def __radd__(self, other) -> "Resources":
        # supports sum() over Resources (starts at int 0)
        if other == 0:
            return self
        return NotImplemented

    def __sub__(self, other: "Resources") -> "Resources":
        if not isinstance(other, Resources):
            return NotImplemented
        if other.dims != self.dims:
            other = other.align(self.dims)
        return Resources(self.dims, self.values - other.values)

    def __mul__(self, k: float) -> "Resources":
        return Resources(self.dims, self.values * float(k))

    __rmul__ = __mul__

    def __truediv__(self, k: float) -> "Resources":
        return Resources(self.dims, self.values / float(k))

    # -- comparison ----------------------------------------------------------
    def __eq__(self, other) -> bool:
        return (
            isinstance(other, Resources)
            and self.dims == other.dims
            and bool(np.array_equal(self.values, other.values))
        )

    __hash__ = None  # mutable ndarray inside; value type, not a dict key

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        body = ", ".join(f"{d}={v:.3f}" for d, v in zip(self.dims, self.values, strict=True))
        return f"Resources({body})"


def as_resources(x: ResourceLike, dims: Sequence[str]) -> Resources:
    """Coerce a scalar CPU fraction or a ``Resources`` onto ``dims``.

    A plain float is the paper's CPU item size: it lands in dimension 0
    (``"cpu"``) with zero demand in every auxiliary dimension.
    """
    if isinstance(x, Resources):
        return x.align(dims)
    dims = tuple(dims)
    v = np.zeros(len(dims))
    v[0] = float(x)
    return Resources(dims, v)
