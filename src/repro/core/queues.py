"""Container queue and allocation queue (paper Sections V-B.1 / V-B.2).

``ContainerQueue`` — FIFO queue of container hosting requests.  Each request
carries the container image name, a time-to-live (TTL) counter used when a
request is requeued following a failed hosting attempt, and the current
profiled size estimate.  While waiting, requests are periodically updated with
metric changes (``refresh_estimates``) and finally consumed by the periodic
bin-packing run.  The queue holds both auto-scaling requests (from the load
predictor) and manual hosting requests from users.

``AllocationQueue`` — placement orders produced by a bin-packing run, each
with the destination worker attached.  As orders are consumed the allocator
attempts to start the PE on the destination worker; on failure (e.g. the
target worker is a new VM still initializing) the target info is stripped and
the request is sent back to the container queue with its TTL decremented —
this TTL-requeue loop is the paper's fault-tolerance mechanism and is reused
verbatim for failed-worker handling in the serving engine.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Callable, Deque, Iterator, List, Optional

from .profiler import MasterProfiler
from .resources import ResourceLike

__all__ = ["HostRequest", "ContainerQueue", "AllocationQueue"]

_req_ids = itertools.count()


@dataclasses.dataclass
class HostRequest:
    """A request to host one PE container of class ``image``.

    ``size_estimate`` is the profiled size the bin-packing run uses: a plain
    float (the paper's CPU fraction) or a ``Resources`` vector on a
    multi-resource cluster.  ``refresh_estimates`` keeps it in whichever
    shape the profiler currently produces.
    """

    image: str
    size_estimate: ResourceLike = 0.5
    ttl: int = 3
    target_worker: Optional[int] = None
    enqueue_time: float = 0.0
    source: str = "autoscale"  # "autoscale" | "user"
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    meta: dict = dataclasses.field(default_factory=dict)

    def strip_target(self) -> "HostRequest":
        """Remove placement info before a TTL requeue (paper V-B.2)."""
        self.target_worker = None
        return self


class ContainerQueue:
    """FIFO queue of host requests with TTL-based drop accounting."""

    def __init__(self) -> None:
        self._q: Deque[HostRequest] = deque()
        self.dropped: List[HostRequest] = []

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self) -> Iterator[HostRequest]:
        return iter(self._q)

    def push(self, req: HostRequest) -> bool:
        """Enqueue; returns False (and records the drop) if TTL is exhausted."""
        if req.ttl <= 0:
            self.dropped.append(req)
            return False
        self._q.append(req)
        return True

    def requeue(self, req: HostRequest) -> bool:
        """TTL-decrement requeue after a failed hosting attempt."""
        req.ttl -= 1
        return self.push(req.strip_target())

    def refresh_estimates(self, profiler: MasterProfiler) -> None:
        """Propagate updated profile averages to waiting requests."""
        for req in self._q:
            req.size_estimate = profiler.estimate(req.image)

    def drain(self, limit: Optional[int] = None) -> List[HostRequest]:
        """Consume up to ``limit`` requests (FIFO) for a bin-packing run."""
        n = len(self._q) if limit is None else min(limit, len(self._q))
        return [self._q.popleft() for _ in range(n)]

    def push_front(self, reqs: List[HostRequest]) -> None:
        """Return unplaced requests to the head, preserving FIFO order."""
        for req in reversed(reqs):
            self._q.appendleft(req)


class AllocationQueue:
    """Placement orders (request + destination worker) awaiting execution."""

    def __init__(self) -> None:
        self._q: Deque[HostRequest] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self) -> Iterator[HostRequest]:
        return iter(self._q)

    def push(self, req: HostRequest) -> None:
        if req.target_worker is None:
            raise ValueError("allocation queue requires a destination worker")
        self._q.append(req)

    def refresh_estimates(self, profiler: MasterProfiler) -> None:
        for req in self._q:
            req.size_estimate = profiler.estimate(req.image)

    def consume(
        self,
        try_start: Callable[[HostRequest], bool],
        on_fail: Callable[[HostRequest], Any],
    ) -> int:
        """Attempt every queued placement; returns the number started.

        ``try_start(req)`` must return True if the PE was started on
        ``req.target_worker``.  Failures are passed to ``on_fail`` (normally
        ``ContainerQueue.requeue``).
        """
        started = 0
        pending = len(self._q)
        for _ in range(pending):
            req = self._q.popleft()
            if try_start(req):
                started += 1
            else:
                on_fail(req)
        return started
