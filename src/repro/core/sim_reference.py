"""Reference simulation: the original per-tick full-scan implementation.

This module preserves, verbatim, the simulation hot path as it existed
before the indexed rewrite in ``sim.py``: every tick scans every worker and
every PE, a P2P pull is an O(queue) linear scan + ``list.pop(i)``, and the
recorded time series grow as Python lists.  It exists for two reasons:

  1. **Equivalence testing** — ``tests/test_sim_equivalence.py`` asserts the
     indexed simulation reproduces this implementation's time series
     bit-for-bit (same seeds, same RNG draw order) on every registered
     scenario, so the fast path can never silently drift from the paper's
     semantics.
  2. **Speedup measurement** — ``benchmarks/sim_throughput.py`` times both
     implementations on the paper's scenarios and reports the ratio in
     ``BENCH_sim.json``.

Do not optimize this module; it is the frozen baseline.  The shared
dataclasses (``SimConfig``, ``SimResult``) and the state enums are imported
from ``sim.py`` so results from both paths are directly comparable.

Multi-resource (vector) mode mirrors ``sim.py``'s semantics in this
module's full-scan style — same pull gating (the shared
``worker_fits_message``), same RNG draw order, same float-summation order —
so the equivalence suite pins the vector path exactly like the scalar one.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .irm import IRM, IRMConfig
from .profiler import MasterProfiler, clamp_estimate
from .queues import HostRequest
from .resources import Resources
from .sim import PEState, SimConfig, SimResult, WorkerState, worker_fits_message
from .workloads import Message, Stream

__all__ = ["ReferenceSimCluster", "simulate_reference"]


class _RefProbe:
    """Pre-refactor ``WorkerProbe``: per-tick sample lists, mean at report."""

    def __init__(self) -> None:
        self._acc: Dict[str, list] = {}

    def sample(self, pe_usages) -> None:
        for image, usage in pe_usages:
            self._acc.setdefault(image, []).append(
                usage if isinstance(usage, np.ndarray) else float(usage)
            )

    def report(self) -> Dict[str, float]:
        out = {
            image: sum(vals) / len(vals)
            for image, vals in self._acc.items()
            if vals
        }
        self._acc = {}
        return out


class _RefProfiler(MasterProfiler):
    """Pre-refactor ``MasterProfiler.estimate``: recompute the moving
    average on every query (no memoization).  Values are identical; only
    the per-call cost differs."""

    def estimate(self, image: str):
        dq = self._samples.get(image)
        if not dq:
            est = self._default_estimate()
        else:
            est = sum(dq) / len(dq)
        return clamp_estimate(est, self.config)


class _RefPE:
    __slots__ = ("image", "state", "ready_t", "msg", "idle_since", "estimate")

    def __init__(self, image: str, t: float, start_delay: float, estimate: float):
        self.image = image
        self.state = PEState.STARTING
        self.ready_t = t + start_delay
        self.msg: Optional[Message] = None
        self.idle_since = -1.0
        self.estimate = estimate  # size estimate at placement time (scheduled)


class _RefWorker:
    __slots__ = ("idx", "state", "ready_t", "pes", "probe")

    def __init__(self, idx: int, t: float, boot_delay: float):
        self.idx = idx
        self.state = WorkerState.BOOTING if boot_delay > 0 else WorkerState.ACTIVE
        self.ready_t = t + boot_delay
        self.pes: List[_RefPE] = []
        self.probe = _RefProbe()


class ReferenceSimCluster:
    """ClusterView implementation backed by the simulation state."""

    def __init__(self, config: SimConfig, irm: IRM):
        self.cfg = config
        self.irm = irm
        self.t = 0.0
        self.rng = np.random.default_rng(config.seed)
        self.queue: List[Message] = []
        self.workers: List[_RefWorker] = []
        self.completed: List[Message] = []
        self.requested_target = 0
        self._failed: set = set()
        # ---- multi-resource mode (mirrors SimCluster) ---------------------
        self._dims = tuple(config.resource_dims)
        self._multi = len(self._dims) > 1
        if self._multi:
            if self._dims[0] != "cpu":
                raise ValueError(
                    f"resource_dims[0] must be 'cpu', got {self._dims}"
                )
            irm.profiler.set_resource_dims(self._dims)
        self.last_dim_measure: Optional[np.ndarray] = None

    # ---- ClusterView protocol -------------------------------------------------
    def queue_length(self) -> float:
        return float(len(self.queue))

    def backlog_resource_demand(self) -> Optional[Resources]:
        """Aggregate estimated demand of the backlog head (vector mode)."""
        if not self._multi:
            return None
        est = self.irm.profiler.estimate
        total: Optional[Resources] = None
        for msg in self.queue[:64]:
            v = est(msg.image)
            total = v if total is None else total + v
        return total

    def queue_image_mix(self) -> Dict[str, float]:
        mix: Dict[str, float] = {}
        for m in self.queue:
            mix[m.image] = mix.get(m.image, 0.0) + 1.0
        n = max(1.0, float(len(self.queue)))
        return {k: v / n for k, v in mix.items()}

    def worker_scheduled_loads(self) -> List:
        # Bins are pre-filled with the *current* profiled usage of the PEs
        # they host — the paper propagates updated moving averages to all
        # scheduling state, not placement-time snapshots (Section V-B.3).
        est = self.irm.profiler.estimate
        if self._multi:
            out = []
            for w in self.workers:
                if w.state == WorkerState.OFF:
                    out.append(Resources(self._dims, np.zeros(len(self._dims))))
                    continue
                load = np.zeros(len(self._dims))
                for pe in w.pes:
                    if pe.state != PEState.STOPPED:
                        load = load + est(pe.image).values
                out.append(Resources(self._dims, load))
            return out
        return [
            sum(est(pe.image) for pe in w.pes if pe.state != PEState.STOPPED)
            if w.state != WorkerState.OFF
            else 0.0
            for w in self.workers
        ]

    def try_start_pe(self, req: HostRequest) -> bool:
        idx = req.target_worker
        if idx is None or idx >= len(self.workers):
            return False
        w = self.workers[idx]
        if w.state != WorkerState.ACTIVE:
            return False  # e.g. "a new VM still initializing" (paper V-B.2)
        w.pes.append(
            _RefPE(req.image, self.t, self.cfg.pe_start_delay, req.size_estimate)
        )
        return True

    def scale_workers(self, target: int) -> None:
        self.requested_target = target
        capped = min(target, self.cfg.max_workers)
        n_alive = sum(1 for w in self.workers if w.state != WorkerState.OFF)
        # boot additional workers
        while n_alive < capped:
            # reuse the lowest OFF slot if any, else append
            slot = next(
                (w for w in self.workers if w.state == WorkerState.OFF), None
            )
            if slot is not None and slot.idx not in self._failed:
                slot.state = WorkerState.BOOTING
                slot.ready_t = self.t + self.cfg.worker_boot_delay
            else:
                self.workers.append(
                    _RefWorker(len(self.workers), self.t, self.cfg.worker_boot_delay)
                )
            n_alive += 1
        # deactivate empty workers above the target (highest index first)
        if n_alive > capped:
            for w in reversed(self.workers):
                if n_alive <= capped:
                    break
                if w.state == WorkerState.ACTIVE and not w.pes:
                    w.state = WorkerState.OFF
                    n_alive -= 1

    # ---- simulation dynamics ---------------------------------------------------
    def _inject_failure(self) -> None:
        if self.cfg.fail_worker_at is None:
            return
        idx, when = self.cfg.fail_worker_at
        if self.t >= when and idx < len(self.workers) and idx not in self._failed:
            w = self.workers[idx]
            # in-flight messages are lost back to the master queue (at-least-once)
            for pe in w.pes:
                if pe.msg is not None:
                    pe.msg.start_t = -1.0
                    self.queue.insert(0, pe.msg)
            w.pes = []
            w.state = WorkerState.OFF
            self._failed.add(idx)

    def tick(self, arrivals: List[Message]) -> None:
        cfg = self.cfg
        self.queue.extend(arrivals)
        self._inject_failure()

        # worker/PE lifecycle
        for w in self.workers:
            if w.state == WorkerState.BOOTING and self.t >= w.ready_t:
                w.state = WorkerState.ACTIVE
            if w.state != WorkerState.ACTIVE:
                continue
            for pe in w.pes:
                if pe.state == PEState.STARTING and self.t >= pe.ready_t:
                    pe.state = PEState.IDLE
                    pe.idle_since = self.t
                if pe.state == PEState.BUSY and pe.msg is not None:
                    if self.t >= pe.msg.done_t:
                        self.completed.append(pe.msg)
                        pe.msg = None
                        pe.state = PEState.IDLE
                        pe.idle_since = self.t
                if pe.state == PEState.IDLE:
                    # P2P pull: match backlog messages of this image (FIFO).
                    # Vector mode: rigid non-CPU dimensions gate the pull
                    # (head-blocking — a blocked first match is not skipped).
                    for i, m in enumerate(self.queue):
                        if m.image == pe.image:
                            if self._multi and not worker_fits_message(
                                w.pes, m, self._dims, self.t
                            ):
                                break
                            m.start_t = self.t
                            m.done_t = self.t + m.duration
                            pe.msg = self.queue.pop(i)
                            pe.state = PEState.BUSY
                            break
                if (
                    pe.state == PEState.IDLE
                    and self.t - pe.idle_since >= cfg.container_idle_timeout
                ):
                    pe.state = PEState.STOPPED  # graceful self-termination
            w.pes = [pe for pe in w.pes if pe.state != PEState.STOPPED]

    def measure(self) -> np.ndarray:
        """Instantaneous measured CPU per worker (fraction of the worker)."""
        if self._multi:
            return self._measure_multi()
        cfg = self.cfg
        out = np.zeros(max(len(self.workers), 1))
        for w in self.workers:
            if w.state != WorkerState.ACTIVE:
                continue
            cores = 0.0
            samples = []
            for pe in w.pes:
                if pe.state == PEState.BUSY and pe.msg is not None:
                    draw = pe.msg.cpu_cores * float(
                        self.rng.normal(1.0, cfg.cpu_noise_std * cfg.cores_per_worker)
                    )
                elif pe.state == PEState.IDLE:
                    draw = cfg.idle_pe_cpu_cores
                else:  # STARTING draws ~nothing: the paper's transient error
                    draw = 0.0
                draw = float(np.clip(draw, 0.0, cfg.cores_per_worker))
                cores += draw
                samples.append((pe.image, draw / cfg.cores_per_worker))
            out[w.idx] = min(1.0, cores / cfg.cores_per_worker)
            w.probe.sample(samples)
        return out

    def _measure_multi(self) -> np.ndarray:
        """Vector-mode measurement mirroring ``SimCluster._measure_multi``:
        noisy CPU draws (same RNG order), exact auxiliary dimensions, the
        per-PE fraction vectors sampled into the probe."""
        cfg = self.cfg
        dims = self._dims
        D = len(dims)
        cores_per_worker = float(cfg.cores_per_worker)
        noise_std = cfg.cpu_noise_std * cfg.cores_per_worker
        idle_draw = min(max(cfg.idle_pe_cpu_cores, 0.0), cores_per_worker)
        n = max(len(self.workers), 1)
        out = np.zeros(n)
        dim_out = np.zeros((n, D))
        for w in self.workers:
            if w.state != WorkerState.ACTIVE:
                continue
            totals = np.zeros(D)
            samples = []
            for pe in w.pes:
                vec = np.zeros(D)
                if pe.state == PEState.BUSY and pe.msg is not None:
                    draw = pe.msg.cpu_cores * float(
                        self.rng.normal(1.0, noise_std)
                    )
                    if draw < 0.0:
                        draw = 0.0
                    elif draw > cores_per_worker:
                        draw = cores_per_worker
                    vec[0] = draw / cores_per_worker
                    mres = pe.msg.resources
                    if mres:
                        for j in range(1, D):
                            vec[j] = mres.get(dims[j], 0.0)
                elif pe.state == PEState.IDLE:
                    vec[0] = idle_draw / cores_per_worker
                totals = totals + vec
                samples.append((pe.image, vec))
            clipped = np.minimum(totals, 1.0)
            dim_out[w.idx] = clipped
            out[w.idx] = clipped[0]
            w.probe.sample(samples)
        self.last_dim_measure = dim_out
        return out

    def flush_probes(self) -> None:
        dims = self._dims if self._multi else None
        for w in self.workers:
            if w.state == WorkerState.ACTIVE and w.pes:
                report = w.probe.report()
                if report:
                    if dims is not None:
                        report = {
                            img: Resources(dims, vec)
                            for img, vec in report.items()
                        }
                    self.irm.ingest_report(report)


def simulate_reference(
    stream: Stream,
    config: Optional[SimConfig] = None,
    irm: Optional[IRM] = None,
    irm_config: Optional[IRMConfig] = None,
) -> SimResult:
    """Run the IRM against a workload stream with the pre-refactor sim.

    Same contract as ``sim.simulate`` — see the module docstring for why
    this frozen copy exists.
    """
    cfg = config or SimConfig()
    if irm is None:
        irm = IRM(irm_config or IRMConfig())
        # freeze the pre-refactor profiler cost model with the fresh IRM
        # (an explicitly passed IRM is left untouched — cross-run state)
        irm.profiler = _RefProfiler(irm.config.profiler)
    else:
        irm.begin_run()
    cluster = ReferenceSimCluster(cfg, irm)

    batches = sorted(stream.batches, key=lambda b: b[0])
    next_batch = 0
    total = stream.num_messages

    times: List[float] = []
    measured: List[np.ndarray] = []
    scheduled: List[np.ndarray] = []
    qlen: List[float] = []
    active: List[int] = []
    target: List[int] = []
    ideal: List[int] = []
    pe_count: List[int] = []
    last_report_t = -1e9
    makespan = 0.0
    multi = cluster._multi
    dims = cluster._dims
    D = len(dims)
    measured_res: List[np.ndarray] = []
    scheduled_res: List[np.ndarray] = []

    t = 0.0
    while t <= cfg.t_max:
        cluster.t = t
        arrivals: List[Message] = []
        while next_batch < len(batches) and batches[next_batch][0] <= t:
            arrivals.extend(batches[next_batch][1])
            next_batch += 1

        cluster.tick(arrivals)
        m = cluster.measure()
        if t - last_report_t >= cfg.report_interval:
            cluster.flush_probes()
            last_report_t = t
        irm.step(t, cluster)

        W = cfg.max_workers
        mw = np.zeros(W)
        k = min(len(m), W)
        mw[:k] = m[:W]
        sw = np.zeros(W)
        sl = cluster.worker_scheduled_loads()
        import math as _math

        if multi:
            mr = np.zeros((W, D))
            mr[:k] = cluster.last_dim_measure[:k]
            sr = np.zeros((W, D))
            for j in range(min(len(sl), W)):
                v = sl[j].values
                c = v[0]
                sw[j] = c if c < 1.0 else 1.0
                sr[j] = np.minimum(v, 1.0)
            measured_res.append(mr)
            scheduled_res.append(sr)
        else:
            sw[: min(len(sl), W)] = np.minimum(np.array(sl[:W]), 1.0)

        times.append(t)
        measured.append(mw)
        scheduled.append(sw)
        qlen.append(len(cluster.queue))
        active.append(
            sum(1 for w in cluster.workers if w.state == WorkerState.ACTIVE)
        )
        target.append(cluster.requested_target)
        est = irm.profiler
        if multi:
            # ideal bins: dominant-dimension bound on the in-system load
            busy_vec = np.zeros(D)
            for w in cluster.workers:
                if w.state == WorkerState.ACTIVE:
                    for pe in w.pes:
                        busy_vec = busy_vec + pe.estimate.values
            backlog_vec = np.zeros(D)
            for msg in cluster.queue[:64]:
                backlog_vec = backlog_vec + est.estimate(msg.image).values
            ideal.append(int(max(
                _math.ceil(busy_vec[j] + (backlog_vec[j]
                                          if backlog_vec[j] < 64.0 else 64.0))
                for j in range(D)
            )))
        else:
            # ideal bins for the *current* in-system load (backlog + busy PEs)
            busy_load = sum(
                pe.estimate
                for w in cluster.workers
                for pe in w.pes
                if w.state == WorkerState.ACTIVE
            )
            backlog_load = sum(
                est.estimate(msg.image) for msg in cluster.queue[:64]
            )
            ideal.append(int(_math.ceil(busy_load + min(backlog_load, 64.0))))
        pe_count.append(sum(len(w.pes) for w in cluster.workers))

        if cluster.completed:
            makespan = max(makespan, max(mm.done_t for mm in cluster.completed))
        done = len(cluster.completed)
        if done >= total and next_batch >= len(batches) and not cluster.queue:
            break
        t = round(t + cfg.dt, 9)

    return SimResult(
        times=np.array(times),
        measured_cpu=np.stack(measured),
        scheduled_cpu=np.stack(scheduled),
        queue_len=np.array(qlen),
        active_workers=np.array(active),
        target_workers=np.array(target),
        ideal_bins=np.array(ideal),
        pe_count=np.array(pe_count),
        completed=len(cluster.completed),
        total=total,
        makespan=makespan,
        messages=[m for _, b in stream.batches for m in b],
        resource_dims=dims,
        measured_res=np.stack(measured_res) if multi else None,
        scheduled_res=np.stack(scheduled_res) if multi else None,
    )
