"""``ClusterView`` conformance checking.

The IRM schedules any cluster that implements the ``ClusterView`` protocol
(``core.irm``).  Three backends do today — the discrete-event simulator,
the live asyncio runtime, and the serving engine's adapter — and the
protocol is structural (``typing.Protocol``), so nothing enforces it at
class-definition time.  ``verify_cluster_view`` is the executable contract:
it checks that a view object exposes every required method, that the
observational ones return sanely-typed values, and that the *optional*
``backlog_resource_demand`` — which the IRM probes with ``getattr`` — is
either absent or returns ``None`` / a ``Resources`` vector.

Used by ``tests/test_view_conformance.py`` against all three backends and
intended for any future backend to self-check in its own tests.
"""

from __future__ import annotations

from typing import List

from .resources import Resources

__all__ = [
    "verify_cluster_view",
    "REQUIRED_METHODS",
    "OPTIONAL_METHODS",
    "ACTUATOR_METHODS",
]

# Observational methods: called by the checker, return values validated.
OBSERVER_METHODS = ("queue_length", "queue_image_mix",
                    "worker_scheduled_loads")
# Actuators: presence/callability checked only (calling them mutates the
# cluster, which a conformance check must not do).
ACTUATOR_METHODS = ("try_start_pe", "scale_workers")
REQUIRED_METHODS = OBSERVER_METHODS + ACTUATOR_METHODS
# Tolerated but not required; the IRM degrades gracefully without them.
OPTIONAL_METHODS = ("backlog_resource_demand",)


def verify_cluster_view(view) -> List[str]:
    """Check ``view`` against the ``ClusterView`` contract.

    Returns a list of human-readable problems — empty means conformant.
    Only observational methods are invoked; actuators are checked for
    presence and callability.
    """
    problems: List[str] = []
    for name in REQUIRED_METHODS:
        fn = getattr(view, name, None)
        if fn is None:
            problems.append(f"missing required method {name!r}")
        elif not callable(fn):
            problems.append(f"{name!r} is not callable")
    if problems:
        return problems  # can't meaningfully probe further

    q = view.queue_length()
    if not isinstance(q, (int, float)):
        problems.append(
            f"queue_length() must return a number, got {type(q).__name__}"
        )
    elif q < 0:
        problems.append(f"queue_length() must be non-negative, got {q}")

    mix = view.queue_image_mix()
    if not hasattr(mix, "items"):
        problems.append(
            f"queue_image_mix() must return a mapping, got {type(mix).__name__}"
        )
    else:
        for img, frac in mix.items():
            if not isinstance(img, str):
                problems.append(f"queue_image_mix() key {img!r} is not a str")
            if not isinstance(frac, (int, float)) or frac < 0:
                problems.append(
                    f"queue_image_mix()[{img!r}] must be a non-negative "
                    f"number, got {frac!r}"
                )
        total = sum(mix.values()) if mix else 0.0
        if mix and abs(total - 1.0) > 1e-6:
            problems.append(
                f"queue_image_mix() fractions must sum to 1, got {total}"
            )

    loads = view.worker_scheduled_loads()
    try:
        loads = list(loads)
    except TypeError:
        problems.append(
            "worker_scheduled_loads() must return an iterable, got "
            f"{type(loads).__name__}"
        )
        loads = []
    for i, load in enumerate(loads):
        if isinstance(load, Resources):
            if any(v < 0 for v in load.values):
                problems.append(
                    f"worker_scheduled_loads()[{i}] has a negative dimension"
                )
        elif isinstance(load, (int, float)):
            if load < 0:
                problems.append(
                    f"worker_scheduled_loads()[{i}] is negative: {load}"
                )
        else:
            problems.append(
                f"worker_scheduled_loads()[{i}] must be float or Resources, "
                f"got {type(load).__name__}"
            )

    # Optional: absent is fine (the IRM getattr-probes); when present it
    # must be callable and return None or a Resources vector.
    demand_fn = getattr(view, "backlog_resource_demand", None)
    if demand_fn is not None:
        if not callable(demand_fn):
            problems.append("backlog_resource_demand is not callable")
        else:
            demand = demand_fn()
            if demand is not None and not isinstance(demand, Resources):
                problems.append(
                    "backlog_resource_demand() must return None or "
                    f"Resources, got {type(demand).__name__}"
                )

    return problems
