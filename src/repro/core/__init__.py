"""Core of the reproduction: the paper's Intelligent Resource Manager.

Online bin-packing (Section IV), the IRM components (Section V), the
discrete-event evaluation environment (Section VI), and the Spark
dynamic-allocation baseline (Section VI-B.1).
"""

from .binpack import (
    ASYMPTOTIC_RATIO,
    AnyFit,
    BestFit,
    Bin,
    DominantFit,
    FirstFit,
    FirstFitDecreasing,
    FirstFitTree,
    Harmonic,
    Item,
    NextFit,
    PackResult,
    VectorAnyFit,
    VectorBestFit,
    VectorBin,
    VectorFirstFit,
    VectorFirstFitDecreasing,
    VectorItem,
    VectorNextFit,
    WorstFit,
    is_vector_policy,
    lower_bound,
    make_packer,
    vector_equivalent,
    vector_lower_bound,
)
from .resources import ResourceLike, Resources, as_resources
from .allocator import AllocatorConfig, BinPackingManager, PackingRun, idle_buffer
from .irm import IRM, ClusterView, IRMConfig, IRMMetrics
from .load_predictor import LoadPredictor, LoadPredictorConfig, ScaleDecision
from .profiler import MasterProfiler, ProfilerConfig, WorkerProbe
from .queues import AllocationQueue, ContainerQueue, HostRequest
from .sim import SimCluster, SimConfig, SimResult, simulate
from .view_conformance import verify_cluster_view

# NOTE: core.sim_reference (the frozen pre-refactor simulator) is NOT
# re-exported here.  Rule R3 (`python -m repro.analysis`) restricts its
# import to the equivalence/parity suites; everyone else uses `simulate`.
from .spark_baseline import SparkConfig, SparkResult, simulate_spark
from .workloads import Message, Stream, synthetic_workload, usecase_workload

__all__ = [
    "ASYMPTOTIC_RATIO",
    "AnyFit",
    "BestFit",
    "Bin",
    "FirstFit",
    "FirstFitDecreasing",
    "FirstFitTree",
    "Harmonic",
    "Item",
    "NextFit",
    "PackResult",
    "DominantFit",
    "VectorAnyFit",
    "VectorBestFit",
    "VectorBin",
    "VectorFirstFit",
    "VectorFirstFitDecreasing",
    "VectorItem",
    "VectorNextFit",
    "WorstFit",
    "is_vector_policy",
    "lower_bound",
    "make_packer",
    "vector_equivalent",
    "vector_lower_bound",
    "ResourceLike",
    "Resources",
    "as_resources",
    "AllocatorConfig",
    "BinPackingManager",
    "PackingRun",
    "idle_buffer",
    "IRM",
    "ClusterView",
    "IRMConfig",
    "IRMMetrics",
    "LoadPredictor",
    "LoadPredictorConfig",
    "ScaleDecision",
    "MasterProfiler",
    "ProfilerConfig",
    "WorkerProbe",
    "AllocationQueue",
    "ContainerQueue",
    "HostRequest",
    "SimCluster",
    "verify_cluster_view",
    "SimConfig",
    "SimResult",
    "simulate",
    "SparkConfig",
    "SparkResult",
    "simulate_spark",
    "Message",
    "Stream",
    "synthetic_workload",
    "usecase_workload",
]
