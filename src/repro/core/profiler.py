"""Worker profiler (paper Section V-B.3).

Two-part design, exactly as in the paper:

  1. ``WorkerProbe`` lives on each worker VM and periodically measures the
     current CPU usage of every running PE, averages per container image, and
     reports the per-image means to the master.
  2. ``MasterProfiler`` aggregates reports from all active workers and keeps a
     moving average over the last N measurements per image (N configurable).
     The average is the *item size* used by the bin-packing manager, and
     updated averages are propagated to requests waiting in the container and
     allocation queues (see ``queues.ContainerQueue.refresh_estimates``).

This is the paper's "run-time learning process" that replaces trained models:
no training data, no fitting — just profiled observations of the running
workloads.  The same class profiles decode-step cost per request class in the
serving engine and per-source document length in the data pipeline.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

from .resources import ResourceLike, Resources, as_resources

__all__ = ["ProfilerConfig", "MasterProfiler", "WorkerProbe", "clamp_estimate"]


def clamp_estimate(est: ResourceLike, config: "ProfilerConfig") -> ResourceLike:
    """Clamp a profiled size into the packer's valid item domain.

    Scalar estimates clamp to [min_size, max_size] exactly as before; vector
    estimates clamp per dimension (CPU keeps the min_size floor so items stay
    in the paper's (0, 1] domain; auxiliary dimensions may be zero).
    """
    if isinstance(est, Resources):
        return est.clamp(config.min_size, config.max_size)
    return min(config.max_size, max(config.min_size, est))


@dataclasses.dataclass
class ProfilerConfig:
    # Number of most-recent measurements in the moving average ("N being
    # arbitrarily configurable" — paper V-B.3).
    window: int = 32
    # Initial guess for a never-before-seen workload class.  The paper notes
    # the first run performs slightly worse while this guess is corrected.
    default_size: float = 0.5
    # Clamp profiled sizes into (0, 1] so they are valid bin-packing items.
    min_size: float = 1e-3
    max_size: float = 1.0


class MasterProfiler:
    """Moving-average profile of resource usage per workload class."""

    def __init__(self, config: Optional[ProfilerConfig] = None):
        self.config = config or ProfilerConfig()
        self._samples: Dict[str, deque] = {}
        self._count: Dict[str, int] = {}
        # Memoized estimates: the moving average only changes when a new
        # measurement arrives (every report_interval), but the simulation
        # hot path queries it for every PE and backlog message every tick —
        # cache per image, invalidate on observe().
        self._est_cache: Dict[str, ResourceLike] = {}
        # None => scalar (the paper's CPU-fraction profile).  Set by a
        # multi-resource cluster so defaults for unseen images are vectors.
        self._dims: Optional[Tuple[str, ...]] = None

    # -- multi-resource mode -------------------------------------------------
    def set_resource_dims(self, dims: Sequence[str]) -> None:
        """Switch default estimates to ``Resources`` over ``dims``.

        A profiler that already holds samples keeps them: scalar samples
        become CPU-only vectors and existing vectors re-align, so a
        persistent IRM (the paper's cross-run profile) can carry its learned
        profile from a scalar cluster onto a multi-resource one without
        mixing floats and vectors inside one moving-average window.
        """
        dims = tuple(dims)
        if dims == self._dims:
            return
        self._dims = dims
        for image, dq in self._samples.items():
            self._samples[image] = deque(
                (as_resources(v, dims) for v in dq), maxlen=dq.maxlen
            )
        self._est_cache.clear()

    @property
    def resource_dims(self) -> Optional[Tuple[str, ...]]:
        return self._dims

    def _default_estimate(self) -> ResourceLike:
        """First-guess size for a never-before-seen workload class."""
        if self._dims is None:
            return self.config.default_size
        return Resources.full(self._dims, self.config.default_size)

    # -- ingest --------------------------------------------------------------
    def observe(self, image: str, value: ResourceLike) -> None:
        """Record one aggregated measurement for a workload class."""
        dq = self._samples.get(image)
        if dq is None:
            dq = deque(maxlen=self.config.window)
            self._samples[image] = dq
            self._count[image] = 0
        dq.append(value if isinstance(value, Resources) else float(value))
        self._count[image] += 1
        self._est_cache.pop(image, None)

    def observe_report(self, report: Mapping[str, ResourceLike]) -> None:
        """Ingest a worker probe report: {image: mean usage on that worker}."""
        for image, value in report.items():
            self.observe(image, value)

    # -- query ---------------------------------------------------------------
    def estimate(self, image: str) -> ResourceLike:
        """Moving-average item size for ``image`` (default guess if unseen)."""
        cached = self._est_cache.get(image)
        if cached is not None:
            return cached
        dq = self._samples.get(image)
        if not dq:
            est = self._default_estimate()
        else:
            est = sum(dq) / len(dq)
        est = clamp_estimate(est, self.config)
        self._est_cache[image] = est
        return est

    def num_observations(self, image: str) -> int:
        return self._count.get(image, 0)

    def known_images(self) -> Tuple[str, ...]:
        return tuple(self._samples)

    def snapshot(self) -> Dict[str, float]:
        return {img: self.estimate(img) for img in self._samples}


class WorkerProbe:
    """Worker-side half: per-PE CPU samples -> per-image means.

    ``sample`` is called at ``report_interval`` (the paper's experiments use
    1 second) with the instantaneous usage of every PE on this worker.
    """

    def __init__(self) -> None:
        # Running (sum, count) per image — bit-identical to accumulating a
        # list and taking sum()/len() at report time (same left-to-right
        # float addition order), without growing per-tick Python lists.
        self._sum: Dict[str, float] = {}
        self._n: Dict[str, int] = {}

    def sample(self, pe_usages: Iterable[Tuple[str, float]]) -> None:
        """Accumulate one round of (image, usage) samples."""
        acc, counts = self._sum, self._n
        for image, usage in pe_usages:
            if image in acc:
                acc[image] += float(usage)
                counts[image] += 1
            else:
                acc[image] = float(usage)
                counts[image] = 1

    def accumulators(self) -> Tuple[Dict[str, float], Dict[str, int]]:
        """The live (sum, count) dicts — the simulation's per-PE fast path.

        Callers may accumulate into these directly (same semantics as one
        ``sample()`` call per entry: add to the sum, bump the count); the
        representation is owned here so ``report()`` and the hot loop can
        never drift apart.
        """
        return self._sum, self._n

    def report(self) -> Dict[str, float]:
        """Flush: per-image mean since the last report (sent to the master)."""
        counts = self._n
        out = {image: s / counts[image] for image, s in self._sum.items()}
        self._sum = {}
        self._n = {}
        return out
