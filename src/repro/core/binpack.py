"""Online bin-packing algorithms (paper Section IV).

The paper bases its Intelligent Resource Manager on the *Any-Fit* family of
online bin-packing algorithms (Epstein et al. [18]), in particular First-Fit:

  - items ``a_i in (0, 1]`` arrive one by one (no knowledge of future items),
  - bins have capacity 1.0 (a worker VM),
  - a new bin is opened only when no active bin can fit the next item,
  - First-Fit places each item into the *lowest-index* bin that fits and has
    asymptotic performance ratio R = 1.7 with O(n log n) time / O(n) space.

This module implements the Any-Fit family (First-, Best-, Worst-, Next-Fit),
the offline First-Fit-Decreasing variant used as a quality reference, the
Harmonic(M) algorithm the paper cites (Lee & Lee [20]), and — the paper's
stated future-work direction — multi-dimensional *vector* bin-packing.

Two First-Fit implementations are provided: a straightforward O(n·m) scan
(``FirstFit``) and an O(n log m) segment-tree variant (``FirstFitTree``) that
realizes the complexity bound quoted in the paper; they are equivalence-tested
property-style in ``tests/test_binpack.py``.

The object packers are plain Python on purpose: packing is control-flow-heavy,
runs on the *host* (the master node in HarmonicIO terms), and its cost is
microseconds per item (see ``benchmarks/binpack_microbench.py``) — it never
belongs on the accelerator.  The JAX integration points (sequence packing,
KV-page allocation, expert capacity) consume the *results* of these packers.

For fleet-scale bin counts (10⁴ workers) the per-item Python scan over bin
objects dominates the IRM's decision cost, so this module also ships a
second engine, ``NumpyPacker``: the whole fleet is one ``(n_bins, n_dims)``
float64 used-capacity matrix and every placement decision is a masked
``argmax``/``argmin`` over it.  The numpy engine is *decision-equivalent* to
the object packers — same placements, bit for bit — which
``tests/test_packer_equivalence.py`` pins property-style for every policy.
``make_packer(..., engine=...)`` selects between them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Optional, Sequence

import numpy as np

__all__ = [
    "Item",
    "Bin",
    "PackResult",
    "AnyFit",
    "FirstFit",
    "FirstFitTree",
    "BestFit",
    "WorstFit",
    "NextFit",
    "FirstFitDecreasing",
    "Harmonic",
    "VectorItem",
    "VectorBin",
    "VectorAnyFit",
    "VectorFirstFit",
    "VectorBestFit",
    "VectorNextFit",
    "DominantFit",
    "VectorFirstFitDecreasing",
    "NumpyPacker",
    "NUMPY_BIN_THRESHOLD",
    "lower_bound",
    "vector_lower_bound",
    "make_packer",
    "is_vector_policy",
    "vector_equivalent",
    "ASYMPTOTIC_RATIO",
]

# Best performance ratio in the Any-Fit group (paper Sec. IV-A, [18]).
ASYMPTOTIC_RATIO = {
    "first-fit": 1.7,
    "best-fit": 1.7,
    "worst-fit": 2.0,
    "next-fit": 2.0,
}

_EPS = 1e-9


@dataclasses.dataclass
class Item:
    """A bin-packing item: ``size`` in (0, 1] plus an opaque payload tag.

    In the IRM the tag is a container host request; in the data pipeline it is
    a document id; in the serving engine it is a request id.
    """

    size: float
    tag: Any = None

    def __post_init__(self) -> None:
        if not (0.0 < self.size <= 1.0 + _EPS):
            raise ValueError(f"item size must be in (0, 1], got {self.size}")


class Bin:
    """A fixed-capacity bin (a worker VM in the paper's model)."""

    __slots__ = ("capacity", "used", "items")

    def __init__(self, capacity: float = 1.0, used: float = 0.0):
        self.capacity = float(capacity)
        self.used = float(used)
        self.items: list[Item] = []

    @property
    def free(self) -> float:
        return self.capacity - self.used

    def fits(self, size: float) -> bool:
        return size <= self.free + _EPS

    def add(self, item: Item) -> None:
        if not self.fits(item.size):
            raise ValueError(
                f"item of size {item.size} does not fit bin with free {self.free}"
            )
        self.items.append(item)
        self.used += item.size

    def remove(self, item: Item) -> None:
        self.items.remove(item)
        self.used -= item.size

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Bin(used={self.used:.3f}/{self.capacity:.3f}, n={len(self.items)})"


@dataclasses.dataclass
class PackResult:
    """Outcome of packing a sequence of items.

    ``assignments[i]`` is the bin index item ``i`` was placed in.  ``opened``
    is the number of bins newly opened by this run (the worker scale-up the
    IRM derives from a packing run).
    """

    assignments: list[int]
    bins: list["Bin"]
    opened: int

    @property
    def num_bins(self) -> int:
        return len(self.bins)


class AnyFit:
    """General Any-Fit approach (paper Algorithm 1).

    Items are packed in arrival order.  ``_choose`` returns the index of the
    active bin to place the item in, or ``None`` — in which case (and only in
    which case) a new bin is opened.  Subclasses implement the search
    criterion; the base class owns the shared packing loop.
    """

    name = "any-fit"

    def __init__(self, capacity: float = 1.0, bins: Optional[list[Bin]] = None):
        self.capacity = float(capacity)
        self.bins: list[Bin] = list(bins) if bins is not None else []

    # -- search criterion ---------------------------------------------------
    def _choose(self, size: float) -> Optional[int]:
        raise NotImplementedError

    # -- shared loop (Algorithm 1) ------------------------------------------
    def pack_one(self, item: Item) -> int:
        """Pack a single item online; returns the bin index used."""
        if item.size > self.capacity + _EPS:
            raise ValueError(
                f"item size {item.size} exceeds bin capacity {self.capacity}"
            )
        idx = self._choose(item.size)
        if idx is None:
            idx = self._open_bin()
        self.bins[idx].add(item)
        self._on_update(idx)
        return idx

    def pack(self, items: Iterable[Item]) -> PackResult:
        before = len(self.bins)
        assignments = [self.pack_one(it) for it in items]
        return PackResult(
            assignments=assignments,
            bins=self.bins,
            opened=len(self.bins) - before,
        )

    # -- hooks ---------------------------------------------------------------
    def _open_bin(self) -> int:
        self.bins.append(Bin(self.capacity))
        return len(self.bins) - 1

    def _on_update(self, idx: int) -> None:  # pragma: no cover - hook
        pass

    def reset(self) -> None:
        self.bins = []


class FirstFit(AnyFit):
    """First-Fit: lowest-index active bin that fits (R = 1.7)."""

    name = "first-fit"

    def _choose(self, size: float) -> Optional[int]:
        for i, b in enumerate(self.bins):
            if b.fits(size):
                return i
        return None


class FirstFitTree(AnyFit):
    """First-Fit with an O(log m) per-item search via a max segment tree.

    The tree stores the maximum free capacity over ranges of bin indices;
    descending left-first finds the lowest-index bin whose free capacity is
    >= the item size.  This realizes the O(n log n) total complexity the
    paper quotes for First-Fit.  Behaviour is exactly equivalent to
    ``FirstFit`` (property-tested).
    """

    name = "first-fit-tree"

    def __init__(self, capacity: float = 1.0, bins: Optional[list[Bin]] = None):
        super().__init__(capacity, bins)
        self._cap = 1
        while self._cap < max(1, len(self.bins)):
            self._cap *= 2
        self._tree = [0.0] * (2 * self._cap)
        for i, b in enumerate(self.bins):
            self._tree[self._cap + i] = b.free
        for i in range(self._cap - 1, 0, -1):
            self._tree[i] = max(self._tree[2 * i], self._tree[2 * i + 1])

    def _grow(self) -> None:
        old_cap, old_tree = self._cap, self._tree
        self._cap *= 2
        self._tree = [0.0] * (2 * self._cap)
        self._tree[self._cap : self._cap + old_cap] = old_tree[old_cap : 2 * old_cap]
        for i in range(self._cap - 1, 0, -1):
            self._tree[i] = max(self._tree[2 * i], self._tree[2 * i + 1])

    def _update(self, idx: int, free: float) -> None:
        i = self._cap + idx
        self._tree[i] = free
        i //= 2
        while i >= 1:
            self._tree[i] = max(self._tree[2 * i], self._tree[2 * i + 1])
            i //= 2

    def _choose(self, size: float) -> Optional[int]:
        if self._tree[1] + _EPS < size:
            return None
        i = 1
        while i < self._cap:
            if self._tree[2 * i] + _EPS >= size:
                i = 2 * i
            else:
                i = 2 * i + 1
        idx = i - self._cap
        return idx if idx < len(self.bins) else None

    def _open_bin(self) -> int:
        idx = super()._open_bin()
        if idx >= self._cap:
            self._grow()
        self._update(idx, self.bins[idx].free)
        return idx

    def _on_update(self, idx: int) -> None:
        self._update(idx, self.bins[idx].free)

    def reset(self) -> None:
        super().reset()
        self._cap = 1
        self._tree = [0.0, 0.0]


class BestFit(AnyFit):
    """Best-Fit: the fitting bin with *minimum* residual free capacity."""

    name = "best-fit"

    def _choose(self, size: float) -> Optional[int]:
        best, best_free = None, math.inf
        for i, b in enumerate(self.bins):
            if b.fits(size) and b.free < best_free:
                best, best_free = i, b.free
        return best


class WorstFit(AnyFit):
    """Worst-Fit: the fitting bin with *maximum* free capacity."""

    name = "worst-fit"

    def _choose(self, size: float) -> Optional[int]:
        best, best_free = None, -math.inf
        for i, b in enumerate(self.bins):
            if b.fits(size) and b.free > best_free:
                best, best_free = i, b.free
        return best


class NextFit(AnyFit):
    """Next-Fit: only the most recently opened bin is considered (R = 2)."""

    name = "next-fit"

    def _choose(self, size: float) -> Optional[int]:
        if self.bins and self.bins[-1].fits(size):
            return len(self.bins) - 1
        return None


class FirstFitDecreasing:
    """Offline First-Fit-Decreasing — the quality reference (R = 11/9).

    Not online (sorts the whole sequence), used in benchmarks to quantify the
    optimality gap of the online packers, and by the training-data packer in
    *batch* mode where a whole shard of documents is visible at once.
    """

    name = "first-fit-decreasing"

    def __init__(self, capacity: float = 1.0):
        self.capacity = capacity

    def pack(self, items: Sequence[Item]) -> PackResult:
        order = sorted(range(len(items)), key=lambda i: -items[i].size)
        ff = FirstFitTree(self.capacity)
        assignments = [0] * len(items)
        for i in order:
            assignments[i] = ff.pack_one(items[i])
        return PackResult(assignments=assignments, bins=ff.bins, opened=len(ff.bins))


class Harmonic(AnyFit):
    """Harmonic(M) (Lee & Lee [20], cited by the paper).

    Items are classified into harmonic intervals (1/(k+1), 1/k]; each class k
    packs into its own bins, k items per bin.  R_inf ≈ 1.691 as M → ∞.
    Included for the algorithm-comparison benchmark; the IRM default stays
    First-Fit as in the paper.
    """

    name = "harmonic"

    def __init__(self, capacity: float = 1.0, m: int = 12):
        super().__init__(capacity)
        self.m = m
        # class k in [1, m]; open bin index + count for each class
        self._open: dict[int, int] = {}

    def _class_of(self, size: float) -> int:
        frac = size / self.capacity
        k = min(self.m, int(math.floor(1.0 / max(frac, 1e-12))))
        return max(1, k)

    def _choose(self, size: float) -> Optional[int]:
        k = self._class_of(size)
        idx = self._open.get(k)
        if idx is not None and self.bins[idx].fits(size) and (
            len(self.bins[idx].items) < k
        ):
            return idx
        return None

    def pack_one(self, item: Item) -> int:
        k = self._class_of(item.size)
        idx = self._choose(item.size)
        if idx is None:
            idx = self._open_bin()
            self._open[k] = idx
        self.bins[idx].add(item)
        return idx

    def reset(self) -> None:
        # the class->open-bin map indexes into self.bins; dropping the bins
        # without clearing it leaves stale indices that the next pack()
        # dereferences (IndexError)
        super().reset()
        self._open = {}


# ---------------------------------------------------------------------------
# Multi-dimensional (vector) bin-packing — the paper's future-work Sec. VII.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class VectorItem:
    """An item with one size per resource dimension (e.g. CPU, RAM, net)."""

    sizes: tuple[float, ...]
    tag: Any = None

    def __post_init__(self) -> None:
        if not self.sizes:
            raise ValueError("vector item needs at least one dimension")
        for s in self.sizes:
            if not (0.0 <= s <= 1.0 + _EPS):
                raise ValueError(f"vector item sizes must be in [0, 1], got {s}")
        if max(self.sizes) <= 0.0:
            raise ValueError("vector item must be non-zero in some dimension")


class VectorBin:
    __slots__ = ("capacity", "used", "items")

    def __init__(
        self,
        capacity: tuple[float, ...],
        used: Optional[Sequence[float]] = None,
    ):
        self.capacity = tuple(float(c) for c in capacity)
        if used is None:
            self.used = tuple(0.0 for _ in capacity)
        else:
            if len(tuple(used)) != len(self.capacity):
                raise ValueError("used vector must match capacity dimensions")
            self.used = tuple(float(u) for u in used)
        self.items: list[VectorItem] = []

    @property
    def free(self) -> tuple[float, ...]:
        return tuple(c - u for c, u in zip(self.capacity, self.used, strict=True))

    def fits(self, sizes: Sequence[float]) -> bool:
        return all(s <= f + _EPS for s, f in zip(sizes, self.free, strict=True))

    def add(self, item: VectorItem) -> None:
        if not self.fits(item.sizes):
            raise ValueError("vector item does not fit")
        self.items.append(item)
        self.used = tuple(u + s for u, s in zip(self.used, item.sizes, strict=True))


def _normalize_capacity(capacity) -> tuple[float, ...]:
    """Accept a float (all-dims capacity 1-vector), tuple, or Resources."""
    if isinstance(capacity, (int, float)):
        return (float(capacity),)
    as_tuple = getattr(capacity, "as_tuple", None)
    if as_tuple is not None:  # core.resources.Resources (duck-typed: no import
        return as_tuple()     # cycle — binpack stays below resources)
    return tuple(float(c) for c in capacity)


class VectorAnyFit:
    """Shared loop for online vector packers (mirrors ``AnyFit``).

    Like the scalar Any-Fit group, supports pre-filled open bins (active
    workers in the IRM) and opens a new bin only when ``_choose`` finds no
    feasible active bin.
    """

    name = "vector-any-fit"

    def __init__(
        self,
        capacity=(1.0,),
        bins: Optional[list[VectorBin]] = None,
    ):
        self.capacity = _normalize_capacity(capacity)
        self.bins: list[VectorBin] = list(bins) if bins is not None else []

    # -- search criterion ---------------------------------------------------
    def _choose(self, item: VectorItem) -> Optional[int]:
        raise NotImplementedError

    # -- shared loop --------------------------------------------------------
    def pack_one(self, item: VectorItem) -> int:
        if any(s > c + _EPS for s, c in zip(item.sizes, self.capacity, strict=True)):
            raise ValueError(
                f"item sizes {item.sizes} exceed bin capacity {self.capacity}"
            )
        idx = self._choose(item)
        if idx is None:
            self.bins.append(VectorBin(self.capacity))
            idx = len(self.bins) - 1
        self.bins[idx].add(item)
        return idx

    def pack(self, items: Iterable[VectorItem]) -> PackResult:
        before = len(self.bins)
        assignments = [self.pack_one(it) for it in items]
        return PackResult(
            assignments=assignments,
            bins=self.bins,  # type: ignore[arg-type]
            opened=len(self.bins) - before,
        )

    def reset(self) -> None:
        self.bins = []


class VectorFirstFit(VectorAnyFit):
    """First-Fit for vector bin-packing with pluggable tie-break heuristics.

    ``heuristic``:
      - ``"first"``: lowest index feasible bin (pure First-Fit semantics);
      - ``"dot"``:   feasible bin maximizing <used, item> alignment (packs
                     complementary workloads together — Panigrahy et al.);
      - ``"l2"``:    feasible bin minimizing the L2 norm of the residual free
                     vector after placement.
    """

    name = "vector-first-fit"

    def __init__(
        self,
        capacity=(1.0,),
        heuristic: str = "first",
        bins: Optional[list[VectorBin]] = None,
    ):
        if heuristic not in ("first", "dot", "l2"):
            raise ValueError(f"unknown heuristic {heuristic!r}")
        super().__init__(capacity, bins)
        self.heuristic = heuristic

    def _score(self, b: VectorBin, item: VectorItem) -> float:
        if self.heuristic == "dot":
            return sum(u * s for u, s in zip(b.used, item.sizes, strict=True))
        # l2: negative residual norm (maximize => minimize residual)
        resid = [f - s for f, s in zip(b.free, item.sizes, strict=True)]
        return -math.sqrt(sum(r * r for r in resid))

    def _choose(self, item: VectorItem) -> Optional[int]:
        feasible = [i for i, b in enumerate(self.bins) if b.fits(item.sizes)]
        if not feasible:
            return None
        if self.heuristic == "first":
            return feasible[0]
        return max(feasible, key=lambda i: self._score(self.bins[i], item))


class VectorBestFit(VectorAnyFit):
    """Best-Fit generalized: minimize total residual free fraction.

    Among feasible bins, picks the one whose summed post-placement residual
    ``sum_d (free_d - s_d) / cap_d`` is smallest (ties: lowest index) — the
    tightest bin across all dimensions at once.
    """

    name = "vector-best-fit"

    def _choose(self, item: VectorItem) -> Optional[int]:
        best, best_resid = None, math.inf
        for i, b in enumerate(self.bins):
            if not b.fits(item.sizes):
                continue
            resid = sum(
                (f - s) / c
                for f, s, c in zip(b.free, item.sizes, b.capacity, strict=True)
            )
            if resid < best_resid:
                best, best_resid = i, resid
        return best


class VectorNextFit(VectorAnyFit):
    """Next-Fit generalized: only the most recently opened bin is considered."""

    name = "vector-next-fit"

    def _choose(self, item: VectorItem) -> Optional[int]:
        if self.bins and self.bins[-1].fits(item.sizes):
            return len(self.bins) - 1
        return None


class DominantFit(VectorAnyFit):
    """Dominant-resource heuristic.

    Classifies the item by its *dominant* dimension (largest ``s_d / cap_d``
    utilization — dominant-resource fairness's notion of an item's share)
    and places it in the feasible bin with the most free capacity in that
    dimension (ties: lowest index).  Spreads bottleneck demand the way
    Worst-Fit spreads scalar load, but per resource, so CPU-heavy and
    memory-heavy items naturally interleave onto complementary bins.
    """

    name = "dominant-fit"

    def _choose(self, item: VectorItem) -> Optional[int]:
        d = max(
            range(len(item.sizes)),
            key=lambda j: item.sizes[j] / max(self.capacity[j], 1e-12),
        )
        best, best_free = None, -math.inf
        for i, b in enumerate(self.bins):
            if b.fits(item.sizes) and b.free[d] > best_free:
                best, best_free = i, b.free[d]
        return best


class VectorFirstFitDecreasing:
    """Offline FFD for vectors: sort by dominant utilization, then First-Fit.

    The quality reference for the vector packers (as scalar FFD is for the
    Any-Fit group).  In the IRM it acts per packing run: the drained request
    batch is reordered largest-dominant-share-first before placement, which
    is legal because a packing run sees its whole batch at once.
    """

    name = "vector-first-fit-decreasing"

    def __init__(
        self,
        capacity=(1.0,),
        bins: Optional[list[VectorBin]] = None,
    ):
        self.capacity = _normalize_capacity(capacity)
        self.bins: list[VectorBin] = list(bins) if bins is not None else []

    def pack(self, items: Sequence[VectorItem]) -> PackResult:
        items = list(items)
        caps = [max(c, 1e-12) for c in self.capacity]

        def dominant(it: VectorItem) -> float:
            return max(s / c for s, c in zip(it.sizes, caps, strict=True))

        order = sorted(range(len(items)), key=lambda i: -dominant(items[i]))
        before = len(self.bins)
        vff = VectorFirstFit(self.capacity, bins=self.bins)
        assignments = [0] * len(items)
        for i in order:
            assignments[i] = vff.pack_one(items[i])
        self.bins = vff.bins
        return PackResult(
            assignments=assignments,
            bins=self.bins,  # type: ignore[arg-type]
            opened=len(self.bins) - before,
        )

    def reset(self) -> None:
        self.bins = []


# ---------------------------------------------------------------------------
# Numpy engine: the whole fleet as one (n_bins, n_dims) float64 matrix
# ---------------------------------------------------------------------------

# Policies the numpy engine implements.  ``harmonic`` and the scalar FFD are
# microbenchmark-only and stay object-based.
_NUMPY_SCALAR = ("first-fit", "first-fit-tree", "best-fit", "worst-fit",
                 "next-fit")
_NUMPY_VECTOR = ("vector-first-fit", "vector-best-fit", "vector-next-fit",
                 "dominant-fit", "vector-ffd")

# ``make_packer(engine="auto")`` switches to the numpy engine once a packing
# run's pre-filled bin count reaches this threshold.  Below it the object
# packers win (no array setup cost); above it the O(bins) Python scan per
# item dominates and the vectorized argmax/argmin decision takes over.
NUMPY_BIN_THRESHOLD = 64


class NumpyPacker:
    """Array-backed packing engine, decision-equivalent to the object packers.

    State is a single ``(n_bins, n_dims)`` float64 *used*-capacity matrix
    (scalar policies are the ``n_dims == 1`` case) plus the capacity vector;
    every placement decision is a feasibility mask and one masked
    ``argmax``/``argmin`` over the fleet, so a decision costs one vectorized
    pass instead of a Python loop over bin objects.

    Equivalence to the object packers is bit-for-bit on placements, pinned
    by ``tests/test_packer_equivalence.py``.  The invariants that make it
    hold:

    - free capacity is recomputed fresh per decision as ``cap - used`` (never
      decremented incrementally — ``(a - b) - c != a - (b + c)`` in floats),
      exactly like ``Bin.free``/``VectorBin.free``;
    - the used matrix grows by sequential ``used[idx] += sizes`` adds, the
      same additions in the same order as ``Bin.add``/``VectorBin.add``;
    - ``np.argmax``/``np.argmin`` return the *first* occurrence of the
      extremum, matching the object packers' strict ``<``/``>`` scans and
      ``max(feasible, key=...)`` tie-breaks (lowest index wins);
    - per-bin scores sum along ``axis=1`` sequentially for ``n_dims < 8``
      (numpy's pairwise-summation base case), matching Python's ``sum()``.
      Beyond 7 resource dimensions score ties could in principle break
      differently; the IRM's clusters use 2–4 dimensions.

    Supports pre-filled open bins via ``bins=`` (a list of ``Bin`` /
    ``VectorBin``, the object-packer protocol) or ``used=`` (an ``(n,)`` or
    ``(n, D)`` array — the fast path the allocator uses).  ``pack_one`` /
    ``pack`` mirror the object API including oversize validation;
    ``place``/``place_batch`` are the raw-array fast paths with no Item
    wrappers.  The ``bins`` property *materializes* object bins on demand
    (compat/introspection only — it is O(n) per access and the returned
    bins' ``items`` lists are empty).
    """

    def __init__(
        self,
        policy: str,
        capacity: Any = 1.0,
        bins: Optional[list] = None,
        used: Optional[Any] = None,
        heuristic: str = "first",
    ):
        if policy not in _NUMPY_SCALAR and policy not in _NUMPY_VECTOR:
            raise ValueError(
                f"policy {policy!r} has no numpy engine; "
                f"scalar options: {sorted(_NUMPY_SCALAR)}; "
                f"vector options: {sorted(_NUMPY_VECTOR)}"
            )
        if heuristic not in ("first", "dot", "l2"):
            raise ValueError(f"unknown heuristic {heuristic!r}")
        self.policy = policy
        self.name = policy
        self.is_vector = policy in _NUMPY_VECTOR
        # vector-ffd's object twin always places with the default First-Fit
        # criterion after sorting; a non-default heuristic would silently
        # diverge from it.
        self.heuristic = heuristic if policy == "vector-first-fit" else "first"
        caps = _normalize_capacity(capacity)
        if not self.is_vector and len(caps) != 1:
            raise ValueError(
                f"scalar policy {policy!r} takes a scalar capacity, got {caps}"
            )
        self.capacity = caps if self.is_vector else caps[0]
        self._cap_vec = np.asarray(caps, dtype=np.float64)
        self.ndims = len(caps)

        if bins is not None and used is not None:
            raise ValueError("pass pre-filled state as bins= or used=, not both")
        prefill = None
        if bins is not None:
            prefill = np.array(
                [np.atleast_1d(np.asarray(b.used, dtype=np.float64))
                 for b in bins],
                dtype=np.float64,
            ).reshape(len(bins), self.ndims)
        elif used is not None:
            prefill = np.array(used, dtype=np.float64)
            if prefill.ndim == 1:
                prefill = prefill[:, None]
            if prefill.ndim != 2 or prefill.shape[1] != self.ndims:
                raise ValueError(
                    f"used matrix shape {prefill.shape} does not match "
                    f"{self.ndims} capacity dimensions"
                )
        n = 0 if prefill is None else len(prefill)
        alloc = 16
        while alloc < n:
            alloc *= 2
        self._used = np.zeros((alloc, self.ndims), dtype=np.float64)
        if n:
            self._used[:n] = prefill
        self._n = n

    # -- state ---------------------------------------------------------------
    @property
    def n_bins(self) -> int:
        return self._n

    def used_matrix(self) -> "np.ndarray":
        """The live ``(n_bins, n_dims)`` used matrix (a view — copy to keep)."""
        return self._used[: self._n]

    @property
    def bins(self) -> list:
        """Materialize object bins (compat only; O(n), empty ``items``)."""
        if self.is_vector:
            return [VectorBin(self.capacity, used=tuple(row))
                    for row in self._used[: self._n]]
        return [Bin(self.capacity, used=float(row[0]))
                for row in self._used[: self._n]]

    def reset(self) -> None:
        self._n = 0

    def _grow(self) -> None:
        grown = np.zeros((self._used.shape[0] * 2, self.ndims), dtype=np.float64)
        grown[: self._n] = self._used[: self._n]
        self._used = grown

    def _open_bin(self) -> int:
        if self._n == self._used.shape[0]:
            self._grow()
        idx = self._n
        self._used[idx] = 0.0
        self._n += 1
        return idx

    # -- decision ------------------------------------------------------------
    def _choose(self, s: "np.ndarray") -> Optional[int]:
        """Active-bin index for item ``s`` (a (D,) array), or None to open."""
        n = self._n
        if n == 0:
            return None
        p = self.policy
        if p in ("next-fit", "vector-next-fit"):
            free_last = self._cap_vec - self._used[n - 1]
            return n - 1 if bool((s <= free_last + _EPS).all()) else None
        used = self._used[:n]
        free = self._cap_vec - used
        feas = (s <= free + _EPS).all(axis=1)
        if not feas.any():
            return None
        if p in ("first-fit", "first-fit-tree"):
            return int(np.argmax(feas))
        if p == "best-fit":
            return int(np.argmin(np.where(feas, free[:, 0], np.inf)))
        if p == "worst-fit":
            return int(np.argmax(np.where(feas, free[:, 0], -np.inf)))
        if p in ("vector-first-fit", "vector-ffd"):
            if self.heuristic == "first":
                return int(np.argmax(feas))
            if self.heuristic == "dot":
                score = (used * s).sum(axis=1)
            else:  # l2: negative residual norm (maximize => minimize residual)
                resid = free - s
                score = -np.sqrt((resid * resid).sum(axis=1))
            return int(np.argmax(np.where(feas, score, -np.inf)))
        if p == "vector-best-fit":
            resid = ((free - s) / self._cap_vec).sum(axis=1)
            return int(np.argmin(np.where(feas, resid, np.inf)))
        # dominant-fit: most free capacity in the item's dominant dimension
        d = int(np.argmax(s / np.maximum(self._cap_vec, 1e-12)))
        return int(np.argmax(np.where(feas, free[:, d], -np.inf)))

    # -- raw-array fast path (what the allocator drives) ---------------------
    def place(self, sizes: Any) -> int:
        """Place one item given as a length-D array; returns the bin index."""
        s = np.asarray(sizes, dtype=np.float64).reshape(self.ndims)
        idx = self._choose(s)
        if idx is None:
            idx = self._open_bin()
        self._used[idx] += s
        return idx

    def place_batch(self, sizes: Any) -> "np.ndarray":
        """Place ``(m, D)`` (or ``(m,)`` scalar) sizes; returns assignments.

        ``vector-ffd`` reorders the batch largest-dominant-share-first with
        a stable sort (same keys, same order as the object FFD's
        ``sorted(..., key=-dominant)``) and reports assignments in the
        original item order; every other policy packs in arrival order.
        """
        sizes = np.asarray(sizes, dtype=np.float64)
        if sizes.ndim == 1:
            sizes = sizes[:, None]
        m = len(sizes)
        out = np.empty(m, dtype=np.int64)
        if self.policy == "vector-ffd" and m > 1:
            shares = (sizes / np.maximum(self._cap_vec, 1e-12)).max(axis=1)
            order = np.argsort(-shares, kind="stable")
        else:
            order = range(m)
        for i in order:
            out[i] = self.place(sizes[i])
        return out

    # -- object-API compat ----------------------------------------------------
    def pack_one(self, item: Any) -> int:
        """Pack one ``Item``/``VectorItem`` with object-packer validation."""
        if self.policy == "vector-ffd":
            raise TypeError(
                "vector-ffd is an offline packer; use pack() or place_batch()"
            )
        if self.is_vector:
            s = np.asarray(item.sizes, dtype=np.float64)
            if (s > self._cap_vec + _EPS).any():
                raise ValueError(
                    f"item sizes {item.sizes} exceed bin capacity "
                    f"{self.capacity}"
                )
        else:
            if item.size > self.capacity + _EPS:
                raise ValueError(
                    f"item size {item.size} exceeds bin capacity "
                    f"{self.capacity}"
                )
            s = np.asarray([item.size], dtype=np.float64)
        return self.place(s)

    def pack(self, items: Iterable[Any]) -> PackResult:
        items = list(items)
        before = self._n
        if self.policy == "vector-ffd":
            for it in items:
                if any(x > c + _EPS for x, c in zip(it.sizes, self.capacity, strict=True)):
                    raise ValueError(
                        f"item sizes {it.sizes} exceed bin capacity "
                        f"{self.capacity}"
                    )
            sizes = np.array([it.sizes for it in items], dtype=np.float64)
            sizes = sizes.reshape(len(items), self.ndims)
            assignments = [int(i) for i in self.place_batch(sizes)]
        else:
            assignments = [self.pack_one(it) for it in items]
        return PackResult(
            assignments=assignments,
            bins=self.bins,
            opened=self._n - before,
        )


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------


def lower_bound(sizes: Iterable[float], capacity: float = 1.0) -> int:
    """L1 lower bound on the optimal bin count: ceil(sum(sizes)/capacity).

    This is the "ideal number of bins" line in the paper's Fig. 10.  Empty
    input needs 0 bins; any strictly positive total needs at least 1 (the
    ``- _EPS`` slack must not round a tiny-but-real load down to "no bins");
    a single item larger than the capacity raises the bound past 1 exactly
    as the L1 sum dictates.
    """
    if capacity <= 0:
        raise ValueError(f"bin capacity must be positive, got {capacity}")
    total = sum(sizes)
    if total <= 0:
        return 0
    return max(1, int(math.ceil(total / capacity - _EPS)))


def vector_lower_bound(
    size_vectors: Iterable[Sequence[float]],
    capacity: Sequence[float] = (1.0,),
) -> int:
    """Dominant-dimension L1 lower bound on the optimal vector bin count.

    Each dimension gives an independent L1 bound ``ceil(sum_d / cap_d)``;
    the optimum can do no better than the worst (dominant) dimension.
    Items must not carry more dimensions than the capacity vector (extra
    demand would silently vanish from the bound otherwise).
    """
    caps = _normalize_capacity(capacity)
    for cap in caps:
        if cap <= 0:
            raise ValueError(f"bin capacity must be positive, got {caps}")
    totals = [0.0] * len(caps)
    for sizes in size_vectors:
        if len(sizes) > len(caps):
            raise ValueError(
                f"size vector {tuple(sizes)} has more dimensions than "
                f"capacity {caps}"
            )
        for d, s in enumerate(sizes):
            totals[d] += s
    best = 0
    for total, cap in zip(totals, caps, strict=True):
        if total > 0:
            best = max(best, max(1, int(math.ceil(total / cap - _EPS))))
    return best


_PACKERS: dict[str, Callable[..., AnyFit]] = {
    "first-fit": FirstFit,
    "first-fit-tree": FirstFitTree,
    "best-fit": BestFit,
    "worst-fit": WorstFit,
    "next-fit": NextFit,
    "harmonic": Harmonic,
}

_VECTOR_PACKERS: dict[str, Callable[..., Any]] = {
    "vector-first-fit": VectorFirstFit,
    "vector-best-fit": VectorBestFit,
    "vector-next-fit": VectorNextFit,
    "dominant-fit": DominantFit,
    "vector-ffd": VectorFirstFitDecreasing,
}

# Scalar policy -> its vector generalization.  Used by the allocator to
# auto-vectorize when a scalar-configured IRM is pointed at a
# multi-resource cluster (worker loads arrive as Resources vectors).
_VECTOR_EQUIVALENT = {
    "first-fit": "vector-first-fit",
    "first-fit-tree": "vector-first-fit",
    "best-fit": "vector-best-fit",
    "next-fit": "vector-next-fit",
    "worst-fit": "dominant-fit",
}


def is_vector_policy(name: str) -> bool:
    """True if ``name`` is a registered multi-dimensional packer."""
    return name in _VECTOR_PACKERS


def vector_equivalent(name: str) -> str:
    """The vector packer to use for a (possibly scalar) policy name."""
    if name in _VECTOR_PACKERS:
        return name
    try:
        return _VECTOR_EQUIVALENT[name]
    except KeyError:
        raise ValueError(
            f"packing algorithm {name!r} has no vector equivalent; "
            f"vector options: {sorted(_VECTOR_PACKERS)}"
        ) from None


def _prefill_count(kw: dict) -> int:
    """Pre-filled bin count implied by a make_packer bins=/used= kwarg."""
    state = kw.get("bins")
    if state is None:
        state = kw.get("used")
    return len(state) if state is not None else 0


def make_packer(
    name: str,
    capacity: Any = 1.0,
    engine: Optional[str] = None,
    **kw: Any,
) -> Any:
    """Factory used by the IRM config (``irm.packing_algorithm``).

    Resolves both the scalar Any-Fit family and the vector packers; vector
    names accept a float capacity (normalized to a 1-vector), a tuple, or a
    ``Resources``.

    ``engine`` selects the implementation:

    - ``None`` / ``"object"``: the per-bin object packers (default);
    - ``"numpy"``: the array-backed ``NumpyPacker`` (raises for policies
      without a numpy implementation, e.g. ``harmonic``);
    - ``"auto"``: the numpy engine when the policy has one *and* the
      pre-filled bin count (``bins=``/``used=``) reaches
      ``NUMPY_BIN_THRESHOLD``, else the object packer.  Both engines make
      identical placement decisions, so "auto" changes latency only.
    """
    if engine not in (None, "object", "numpy", "auto"):
        raise ValueError(
            f"unknown packing engine {engine!r}; "
            "expected 'object', 'numpy', or 'auto'"
        )
    has_numpy = name in _NUMPY_SCALAR or name in _NUMPY_VECTOR
    if engine == "numpy":
        return NumpyPacker(name, capacity=capacity, **kw)
    if engine == "auto" and has_numpy and _prefill_count(kw) >= NUMPY_BIN_THRESHOLD:
        return NumpyPacker(name, capacity=capacity, **kw)
    cls = _PACKERS.get(name) or _VECTOR_PACKERS.get(name)
    if cls is None:
        raise ValueError(
            f"unknown packing algorithm {name!r}; "
            f"scalar options: {sorted(_PACKERS)}; "
            f"vector options: {sorted(_VECTOR_PACKERS)}"
        )
    used = kw.pop("used", None)
    if used is not None:
        # object packers take pre-filled state as bins; materialize them so
        # an engine="auto" caller below the threshold loses nothing
        if "bins" in kw:
            raise ValueError("pass pre-filled state as bins= or used=, not both")
        if name in _VECTOR_PACKERS:
            caps = _normalize_capacity(capacity)
            kw["bins"] = [
                VectorBin(caps, used=tuple(np.atleast_1d(row)))
                for row in np.asarray(used, dtype=np.float64)
            ]
        else:
            kw["bins"] = [Bin(float(capacity), used=float(u)) for u in used]
    return cls(capacity=capacity, **kw)
