"""Apache Spark Streaming dynamic-allocation baseline (paper Section VI-B.1).

The paper compares HIO+IRM against a Spark Streaming application processing
the same CellProfiler workload, configured — after their initial attempts
with ``spark.streaming.dynamicAllocation`` failed to scale within the first
batch — with the older core dynamic allocation:

  - micro-batching with a 5 s batch interval,
  - ``spark.dynamicAllocation.executorIdleTimeout = 20 s``,
  - ``spark.streaming.concurrentJobs = 3`` so other cores can start the next
    batch while waiting for the 10–20 s "tail" tasks of the previous job,
  - exponential executor ramp-up (1, 2, 4, ... per backlog round), the
    standard Spark dynamic-allocation policy.

This module reproduces that behaviour in the same fixed-timestep style as
``core/sim.py`` so Fig. 7 (executor cores vs. actual CPU, scale-down events)
and the ~2x end-to-end wall-time gap vs. HIO can be regenerated.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .workloads import Message, Stream

__all__ = ["SparkConfig", "SparkResult", "simulate_spark"]


@dataclasses.dataclass
class SparkConfig:
    dt: float = 0.5
    batch_interval: float = 5.0        # Spark Streaming micro-batch interval
    concurrent_jobs: int = 3           # spark.streaming.concurrentJobs
    executor_idle_timeout: float = 20.0  # spark.dynamicAllocation.executorIdleTimeout
    backlog_timeout: float = 1.0       # schedulerBacklogTimeout (ramp cadence)
    executor_cores: int = 8            # one executor per SSC.xlarge worker
    max_executors: int = 5             # 5 workers => 40 cores total
    executor_start_delay: float = 3.0
    # client-side arrival rate of image files into the streaming source dir
    arrival_rate: float = 10.0         # images / second
    # serial per-image job overhead (driver-side file listing + NFS reads):
    # the paper observes "idle gaps in between" batches and hypothesizes
    # "the time could have been spent reading the images from disk".
    # Per-image NFS read time (images are "order MB" over a shared NFS
    # mount from an SSC.small VM — ~5-10 MB at 10-20 MB/s).  Calibrated so
    # the simulated run reproduces Fig. 7's observed inter-batch gaps and
    # the ~2x end-to-end wall-time vs. HIO reported in Section VI-B.
    job_setup_per_task: float = 0.7    # seconds per image, serial NFS chain
    # the paper: "For unknown reasons, the system sat idle with 2 executors
    # for some time" — an observed driver stall at the start of the run.
    initial_stall: float = 75.0
    # per-task I/O inflation (NFS image reads; the paper's hypothesis for
    # the idle gaps: "time could have been spent reading the images from
    # disk").
    task_io_overhead: float = 0.18
    cpu_noise_std: float = 0.02
    t_max: float = 3600.0
    seed: int = 0


@dataclasses.dataclass
class SparkResult:
    times: np.ndarray
    executor_cores: np.ndarray   # total registered executor cores (REST API view)
    used_cores: np.ndarray       # measured busy cores (the `top` poll)
    pending_tasks: np.ndarray
    scale_downs: List[float]     # times when executors were removed (red circles)
    completed: int
    total: int
    makespan: float


class _Executor:
    __slots__ = ("cores", "tasks", "idle_since", "ready_t")

    def __init__(self, t: float, cores: int, start_delay: float):
        self.cores = cores
        self.tasks: List[Message] = []  # running tasks (1 core each)
        self.idle_since = t
        self.ready_t = t + start_delay


class _Job:
    """One micro-batch job: a set of single-core tasks (CellProfiler procs)."""

    __slots__ = ("tasks", "remaining", "submitted", "ready_t")

    def __init__(self, tasks: List[Message], t: float):
        self.tasks = list(tasks)
        self.remaining = len(tasks)
        self.submitted = t
        self.ready_t = t  # set at admission: serial setup/IO before tasks run

    def done(self) -> bool:
        return self.remaining <= 0


def simulate_spark(
    stream: Stream, config: Optional[SparkConfig] = None
) -> SparkResult:
    cfg = config or SparkConfig()
    rng = np.random.default_rng(cfg.seed)

    # flatten the stream into client-side arrivals at cfg.arrival_rate
    all_msgs: List[Message] = [m for _, batch in stream.batches for m in batch]
    arrival_times = np.arange(len(all_msgs)) / cfg.arrival_rate
    total = len(all_msgs)

    executors: List[_Executor] = [_Executor(0.0, cfg.executor_cores, 0.0)]
    jobs_waiting: List[_Job] = []
    jobs_running: List[_Job] = []
    in_flight: List[Tuple[Message, _Executor, _Job]] = []
    source_buffer: List[Message] = []
    completed = 0
    makespan = 0.0
    next_arrival = 0
    last_batch_t = 0.0
    ramp = 1  # exponential ramp counter
    last_ramp_t = -1e9
    io_busy_until = 0.0  # NFS share: one job reads images at a time

    times: List[float] = []
    cores_ts: List[float] = []
    used_ts: List[float] = []
    pending_ts: List[int] = []
    scale_downs: List[float] = []

    t = 0.0
    while t <= cfg.t_max:
        # 1. new files land in the source directory
        while next_arrival < total and arrival_times[next_arrival] <= t:
            source_buffer.append(all_msgs[next_arrival])
            next_arrival += 1

        # 2. batch boundary: everything in the buffer becomes one job
        if t - last_batch_t >= cfg.batch_interval:
            last_batch_t = t
            if source_buffer:
                jobs_waiting.append(_Job(source_buffer, t))
                source_buffer = []

        # 3. admit jobs up to the concurrency limit; admission starts the
        #    setup/IO phase.  The NFS share is a single contended resource,
        #    so I/O phases serialize across concurrent jobs — the source of
        #    the inter-batch idle gaps the paper observes in Fig. 7.
        while jobs_waiting and len(jobs_running) < cfg.concurrent_jobs:
            job = jobs_waiting.pop(0)
            io_start = max(t, io_busy_until)
            job.ready_t = io_start + cfg.job_setup_per_task * len(job.tasks)
            io_busy_until = job.ready_t
            jobs_running.append(job)

        # 4. finish tasks
        still: List[Tuple[Message, _Executor, _Job]] = []
        for msg, ex, job in in_flight:
            if t >= msg.done_t:
                ex.tasks.remove(msg)
                job.remaining -= 1
                completed += 1
                makespan = max(makespan, msg.done_t)
                if not ex.tasks:
                    ex.idle_since = t
            else:
                still.append((msg, ex, job))
        in_flight = still
        jobs_running = [j for j in jobs_running if not j.done()]

        # 5. schedule pending tasks of jobs past their setup phase
        stalled = t < cfg.initial_stall
        pending = [
            (task, j)
            for j in jobs_running
            if t >= j.ready_t
            for task in j.tasks
            if task.start_t < 0
        ]
        if not stalled:
            for ex in executors:
                if t < ex.ready_t:
                    continue
                free = ex.cores - len(ex.tasks)
                while free > 0 and pending:
                    task, job = pending.pop(0)
                    task.start_t = t
                    task.done_t = t + task.duration * (1.0 + cfg.task_io_overhead)
                    ex.tasks.append(task)
                    in_flight.append((task, ex, job))
                    free -= 1

        # 6. dynamic allocation: exponential ramp while tasks are backlogged
        #    (held at 2 executors during the observed initial stall)
        n_pending = len(pending)
        if stalled:
            while len(executors) < 2:
                executors.append(
                    _Executor(t, cfg.executor_cores, cfg.executor_start_delay)
                )
        elif n_pending > 0 and (t - last_ramp_t) >= cfg.backlog_timeout:
            want = min(cfg.max_executors, len(executors) + ramp)
            while len(executors) < want:
                executors.append(
                    _Executor(t, cfg.executor_cores, cfg.executor_start_delay)
                )
            ramp *= 2
            last_ramp_t = t
        elif n_pending == 0:
            ramp = 1

        # 7. idle-timeout scale-down (the paper's red circles)
        kept: List[_Executor] = []
        for ex in executors:
            if (
                not ex.tasks
                and t >= ex.ready_t
                and (t - ex.idle_since) >= cfg.executor_idle_timeout
                and len(executors) > 1
                and len(kept) + (len(executors) - len(kept) - 1) >= 1
            ):
                scale_downs.append(t)
                executors_removed = True  # noqa: F841  (debug marker)
                continue
            kept.append(ex)
        executors = kept

        # 8. record
        reg_cores = sum(ex.cores for ex in executors if t >= ex.ready_t)
        busy = sum(len(ex.tasks) for ex in executors)
        noise = rng.normal(0.0, cfg.cpu_noise_std * max(busy, 1))
        times.append(t)
        cores_ts.append(float(reg_cores))
        used_ts.append(float(max(0.0, busy + noise)))
        pending_ts.append(n_pending)

        if (
            completed >= total
            and next_arrival >= total
            and not jobs_waiting
            and not jobs_running
            and not source_buffer
        ):
            break
        t = round(t + cfg.dt, 9)

    return SparkResult(
        times=np.array(times),
        executor_cores=np.array(cores_ts),
        used_cores=np.array(used_ts),
        pending_tasks=np.array(pending_ts),
        scale_downs=scale_downs,
        completed=completed,
        total=total,
        makespan=makespan,
    )
