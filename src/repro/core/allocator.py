"""Container allocator / bin-packing manager (paper Section V-B.2).

Models the scheduling problem exactly as the paper does:

  - a worker VM is a *bin* with capacity 1.0 (an active VM is an open bin,
    pre-filled with the profiled usage of the PEs it already hosts),
  - a container hosting request is an *item* with size in (0, 1] — the
    profiled CPU usage of that PE's image,
  - a packing run (at a configurable rate) maps queued requests to workers
    and determines how many workers are needed.

On top of the raw bin count, a small buffer of idle workers is kept ready to
accept stream requests; the buffer is logarithmically proportional to the
number of currently active workers (paper Section V-A), providing more
headroom for fluctuations when the workload is not as high.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Union

import numpy as np

from .binpack import (
    _EPS,
    NUMPY_BIN_THRESHOLD,
    Bin,
    Item,
    NumpyPacker,
    VectorBin,
    VectorItem,
    is_vector_policy,
    lower_bound,
    make_packer,
    vector_equivalent,
    vector_lower_bound,
)
from .queues import HostRequest
from .resources import ResourceLike, Resources, as_resources

__all__ = ["AllocatorConfig", "PackingRun", "BinPackingManager", "idle_buffer"]


def idle_buffer(active_workers: int) -> int:
    """Idle-worker headroom: ceil(log2(active + 1)) (log-proportional)."""
    return int(math.ceil(math.log2(active_workers + 1))) if active_workers > 0 else 1


@dataclasses.dataclass
class AllocatorConfig:
    # Packing algorithm for the packing run; First-Fit in the paper.  Any
    # ``make_packer`` name — scalar Any-Fit or a vector packer.  A scalar
    # name on a multi-resource cluster is auto-promoted to its vector
    # generalization (``binpack.vector_equivalent``).
    algorithm: str = "first-fit-tree"
    # Bin capacity: 1.0 == 100% of a worker's CPU.  On a multi-resource
    # cluster this may be a ``Resources`` vector (a float means every
    # dimension has that capacity).
    capacity: Union[float, Resources] = 1.0
    # Rate of packing runs, seconds (paper: "at a configurable rate").
    pack_interval: float = 2.0
    # Keep a log-proportional idle-worker buffer (paper Section V-A).
    keep_idle_buffer: bool = True
    # Optional per-run cap on consumed requests (back-pressure guard).
    max_requests_per_run: Optional[int] = None
    # Optional per-worker headroom so measurement noise does not congest a
    # worker scheduled at exactly 100% (0.0 == faithful paper behaviour).
    headroom: float = 0.0
    # Packing engine: "object" (per-bin Python packers), "numpy" (the
    # array-backed ``NumpyPacker`` — required for ndarray worker loads), or
    # "auto" (numpy once the fleet reaches ``numpy_bin_threshold`` bins or
    # the loads arrive as an ndarray).  Both engines make identical
    # placement decisions (``tests/test_packer_equivalence.py``).
    engine: str = "auto"
    numpy_bin_threshold: int = NUMPY_BIN_THRESHOLD
    # Incremental repacking (numpy engine only): keep the pre-fill matrix
    # from the previous run and refresh only *dirty* rows — workers whose
    # reported load changed since the last decision, rows beyond the old
    # fleet size, and the previous run's placement frontier.  Decisions are
    # provably equal to a full repack (the pre-fill of a bin depends only on
    # its own load); when the dirty fraction exceeds ``dirty_fallback`` the
    # whole matrix is rebuilt instead.
    incremental: bool = True
    dirty_fallback: float = 0.25


@dataclasses.dataclass
class PackingRun:
    """Result of one periodic bin-packing run.

    ``scheduled_load`` entries are floats on the scalar path and
    ``Resources`` vectors on the multi-resource path — except when the run
    was fed an ndarray of worker loads (the fleet-scale fast path), in
    which case it is the raw ``(n_bins, n_dims)`` used matrix.
    ``ideal_bins`` is the L1 lower bound (dominant-dimension L1 for
    vectors).
    """

    t: float
    placements: List[HostRequest]  # requests with ``target_worker`` attached
    num_bins: int                  # bins used by this packing solution
    target_workers: int            # num_bins + idle buffer
    ideal_bins: int                # L1 lower bound for the packed load
    scheduled_load: List[ResourceLike]  # per-bin scheduled usage after the run
    # decision-audit capture (observability plane; ``None`` unless the
    # manager's ``audit`` flag is set): policy, dims, capacity, per-bin
    # free vector *before* the run, per-item sizes/assignments/ids —
    # everything ``repro.obs.audit`` needs to replay rejection reasons
    audit: Optional[dict] = None


class BinPackingManager:
    """Periodic First-Fit packing of queued PEs onto workers."""

    def __init__(self, config: Optional[AllocatorConfig] = None):
        self.config = config or AllocatorConfig()
        self._last_run_t: Optional[float] = None
        self.runs: List[PackingRun] = []
        # observability: capture the decision-audit snapshot per run
        # (pure reads — decisions are identical with the flag on or off)
        self.audit = False
        # incremental-repack cache (numpy engine): loads snapshot, the
        # derived pre-fill matrix min(load, cap), the capacity vector it was
        # built against, and the previous run's placement frontier
        self._inc_loads: Optional[np.ndarray] = None
        self._inc_prefill: Optional[np.ndarray] = None
        self._inc_cap: Optional[np.ndarray] = None
        self._inc_frontier: np.ndarray = np.empty(0, dtype=np.int64)
        self.full_repacks = 0        # numpy runs that rebuilt the matrix
        self.incremental_runs = 0    # numpy runs that refreshed dirty rows

    def should_run(self, t: float) -> bool:
        return (
            self._last_run_t is None
            or (t - self._last_run_t) >= self.config.pack_interval
        )

    def run(
        self,
        t: float,
        requests: Sequence[HostRequest],
        worker_loads: Sequence[ResourceLike],
    ) -> PackingRun:
        """One packing run.

        ``worker_loads[i]`` is the *scheduled* (profiled) usage of active
        worker ``i`` — the sum of size estimates of the PEs it currently
        hosts.  Active workers are open bins pre-filled to that level; queued
        requests are packed in FIFO order; bins opened beyond the active
        workers represent the scale-up the IRM will request.

        The run is *vector* when anything multi-dimensional reaches it: a
        ``Resources`` capacity, a vector packing policy, or ``Resources``
        loads/size estimates.  A scalar run is bit-for-bit the paper's
        behaviour.

        ``worker_loads`` may also be an ndarray — ``(n,)`` scalar or
        ``(n, D)`` vector — which skips every per-worker Python scan and is
        packed by the numpy engine regardless of ``config.engine`` (the
        object packers have no array path).  With ``engine="auto"`` (the
        default) list inputs switch to the numpy engine once the fleet
        reaches ``config.numpy_bin_threshold`` bins; placements are
        identical either way.
        """
        cfg = self.config
        is_arr = isinstance(worker_loads, np.ndarray)
        use_numpy = cfg.engine == "numpy" or is_arr or (
            cfg.engine == "auto"
            and len(worker_loads) >= cfg.numpy_bin_threshold
        )
        if use_numpy:
            return self._run_numpy(t, requests, worker_loads)
        if (
            isinstance(cfg.capacity, Resources)
            or is_vector_policy(cfg.algorithm)
            or any(isinstance(load, Resources) for load in worker_loads)
            or any(isinstance(r.size_estimate, Resources) for r in requests)
        ):
            return self._run_vector(t, requests, worker_loads)
        self._last_run_t = t
        cap = cfg.capacity - cfg.headroom
        bins = [Bin(cfg.capacity, used=min(load, cfg.capacity)) for load in worker_loads]
        try:
            # algorithms that support pre-filled open bins (the Any-Fit group)
            packer = make_packer(cfg.algorithm, capacity=cfg.capacity, bins=bins)
        except TypeError:
            raise ValueError(
                f"algorithm {cfg.algorithm!r} does not support pre-filled bins; "
                "use an Any-Fit algorithm for the IRM allocator"
            ) from None

        # audit snapshot before pack_one mutates the bins
        free_before = (
            [[float(cfg.capacity - b.used)] for b in bins]
            if self.audit else None
        )
        placements: List[HostRequest] = []
        audit_sizes: List[List[float]] = []
        audit_assignments: List[int] = []
        for req in requests:
            size = min(max(req.size_estimate, 1e-3), cap)
            idx = packer.pack_one(Item(size=size, tag=req.req_id))
            req.target_worker = idx
            placements.append(req)
            if self.audit:
                audit_sizes.append([float(size)])
                audit_assignments.append(int(idx))

        used_bins = sum(1 for b in packer.bins if b.used > 1e-9)
        total_load = sum(b.used for b in packer.bins)
        ideal = lower_bound([total_load], cfg.capacity) if total_load > 0 else 0
        target = used_bins + (idle_buffer(used_bins) if cfg.keep_idle_buffer else 0)

        run = PackingRun(
            t=t,
            placements=placements,
            num_bins=used_bins,
            target_workers=target,
            ideal_bins=ideal,
            scheduled_load=[b.used for b in packer.bins],
            audit=self._audit_record(
                cfg.algorithm, ("cpu",), [float(cfg.capacity)],
                free_before, audit_sizes, audit_assignments, requests,
            ) if self.audit else None,
        )
        self.runs.append(run)
        return run

    def _audit_record(
        self,
        policy: str,
        dims,
        capacity: List[float],
        free_before,
        sizes,
        assignments,
        requests: Sequence[HostRequest],
    ) -> dict:
        """The decision-audit snapshot ``repro.obs.audit`` replays."""
        return {
            "policy": policy,
            "dims": list(dims),
            "capacity": capacity,
            "free_before": free_before,
            "sizes": sizes,
            "assignments": assignments,
            "req_ids": [r.req_id for r in requests],
            "images": [r.image for r in requests],
        }

    # -- multi-resource packing run (paper Sec. VII future work) -------------
    def _resolve_dims(
        self,
        requests: Sequence[HostRequest],
        worker_loads: Sequence[ResourceLike],
    ) -> tuple:
        """Dimension names for this run: config capacity wins, else the
        first ``Resources`` seen among loads / request estimates."""
        if isinstance(self.config.capacity, Resources):
            return self.config.capacity.dims
        for load in worker_loads:
            if isinstance(load, Resources):
                return load.dims
        for r in requests:
            if isinstance(r.size_estimate, Resources):
                return r.size_estimate.dims
        return ("cpu",)

    def _run_vector(
        self,
        t: float,
        requests: Sequence[HostRequest],
        worker_loads: Sequence[ResourceLike],
    ) -> PackingRun:
        """Vector bin-packing run: pre-filled *vector* bins, per-dimension
        headroom, dominant-dimension lower bound."""
        cfg = self.config
        self._last_run_t = t
        dims = self._resolve_dims(requests, worker_loads)
        D = len(dims)
        cap = as_resources(cfg.capacity, dims).values if isinstance(
            cfg.capacity, Resources
        ) else np.full(D, float(cfg.capacity))
        # per-dimension item ceiling: capacity minus headroom (the scalar
        # semantics — bins keep full capacity, items are clamped)
        item_hi = cap - cfg.headroom

        bins = [
            VectorBin(
                tuple(cap),
                used=np.minimum(as_resources(load, dims).values, cap),
            )
            for load in worker_loads
        ]
        algorithm = vector_equivalent(cfg.algorithm)
        packer = make_packer(algorithm, capacity=tuple(cap), bins=bins)
        # audit snapshot before pack() mutates the bins
        free_before = (
            [(cap - np.asarray(b.used)).tolist() for b in bins]
            if self.audit else None
        )

        items: List[VectorItem] = []
        for req in requests:
            size = as_resources(req.size_estimate, dims).values
            size = np.minimum(size, item_hi)
            size = np.maximum(size, 0.0)
            size[0] = max(size[0], min(1e-3, item_hi[0]))
            items.append(VectorItem(tuple(float(s) for s in size), tag=req.req_id))
        result = packer.pack(items)
        placements: List[HostRequest] = []
        for req, idx in zip(requests, result.assignments, strict=True):
            req.target_worker = idx
            placements.append(req)

        used_bins = sum(
            1 for b in packer.bins if any(u > 1e-9 for u in b.used)
        )
        ideal = vector_lower_bound([b.used for b in packer.bins], tuple(cap))
        target = used_bins + (idle_buffer(used_bins) if cfg.keep_idle_buffer else 0)

        run = PackingRun(
            t=t,
            placements=placements,
            num_bins=used_bins,
            target_workers=target,
            ideal_bins=ideal,
            scheduled_load=[Resources(dims, b.used) for b in packer.bins],
            audit=self._audit_record(
                algorithm, dims, [float(c) for c in cap], free_before,
                [list(it.sizes) for it in items],
                [int(a) for a in result.assignments], requests,
            ) if self.audit else None,
        )
        self.runs.append(run)
        return run

    # -- numpy engine: matrix pre-fill, incremental refresh, batch place -----
    def _numpy_prefill(
        self, loads_mat: np.ndarray, cap_vec: np.ndarray
    ) -> np.ndarray:
        """The ``(n, D)`` pre-fill matrix ``min(load, cap)`` for this run.

        With ``config.incremental`` the previous run's matrix is reused and
        only dirty rows are recomputed: rows whose load changed since the
        last run (exact float compare — a bitwise-equal load yields a
        bitwise-equal pre-fill, so clean rows need no work), rows beyond the
        previous fleet size, and the previous placement frontier (bins the
        last run placed requests into — redundant given the load compare,
        but kept as belt-and-suspenders for views whose loads lag their
        placements).  Because every row depends only on its own load, the
        result is always element-for-element identical to a full rebuild;
        when the dirty fraction exceeds ``config.dirty_fallback`` the full
        rebuild is cheaper and is used instead.
        """
        cfg = self.config
        n, D = loads_mat.shape
        cached = (
            cfg.incremental
            and self._inc_prefill is not None
            and self._inc_prefill.shape[1] == D
            and self._inc_cap is not None
            and np.array_equal(self._inc_cap, cap_vec)
        )
        if cached:
            prev_n = len(self._inc_loads)
            common = min(n, prev_n)
            dirty = np.zeros(n, dtype=bool)
            if common:
                dirty[:common] = (
                    loads_mat[:common] != self._inc_loads[:common]
                ).any(axis=1)
            dirty[common:] = True
            fr = self._inc_frontier
            if fr.size:
                dirty[fr[fr < n]] = True
            if n == 0 or (int(dirty.sum()) / n) <= cfg.dirty_fallback:
                if prev_n == n:
                    prefill = self._inc_prefill
                else:
                    prefill = np.empty((n, D), dtype=np.float64)
                    prefill[:common] = self._inc_prefill[:common]
                prefill[dirty] = np.minimum(loads_mat[dirty], cap_vec)
                self.incremental_runs += 1
            else:
                prefill = np.minimum(loads_mat, cap_vec)
                self.full_repacks += 1
        else:
            prefill = np.minimum(loads_mat, cap_vec)
            self.full_repacks += 1
        self._inc_loads = loads_mat.copy()
        self._inc_prefill = prefill
        self._inc_cap = cap_vec.copy()
        return prefill

    def _run_numpy(
        self,
        t: float,
        requests: Sequence[HostRequest],
        worker_loads,
    ) -> PackingRun:
        """One packing run on the numpy engine.

        Mirrors the scalar/vector object runs decision-for-decision (same
        clamps, same pre-fill, same packer semantics — pinned by
        ``tests/test_packer_equivalence.py``); the differences are
        representational: the fleet is one ``(n, D)`` matrix, and when the
        loads arrive as an ndarray the returned ``scheduled_load`` is the
        raw used matrix instead of a list of floats/``Resources`` (building
        10⁴ objects per decision would defeat the point).
        """
        cfg = self.config
        self._last_run_t = t
        is_arr = isinstance(worker_loads, np.ndarray)
        loads_D = (
            worker_loads.shape[1]
            if is_arr and worker_loads.ndim == 2
            else None
        )
        vector_mode = (
            isinstance(cfg.capacity, Resources)
            or is_vector_policy(cfg.algorithm)
            or (loads_D is not None and loads_D > 1)
            or any(isinstance(r.size_estimate, Resources) for r in requests)
        )
        if not vector_mode and not is_arr:
            vector_mode = any(
                isinstance(load, Resources) for load in worker_loads
            )

        # -- capacity vector + dimension names
        if vector_mode:
            dims = self._resolve_dims(
                requests, () if is_arr else worker_loads
            )
            if loads_D is not None and len(dims) < loads_D:
                dims = tuple(dims) + tuple(
                    f"res{i}" for i in range(len(dims), loads_D)
                )
            D = len(dims)
            cap_vec = (
                as_resources(cfg.capacity, dims).values.astype(np.float64)
                if isinstance(cfg.capacity, Resources)
                else np.full(D, float(cfg.capacity))
            )
        else:
            dims = ("cpu",)
            D = 1
            cap_vec = np.full(1, float(cfg.capacity))

        # -- worker loads as an (n, D) matrix
        if is_arr:
            loads_mat = np.asarray(worker_loads, dtype=np.float64)
            if loads_mat.ndim == 1:
                loads_mat = loads_mat[:, None]
            if loads_mat.shape[1] < D:  # scalar loads on a vector run
                padded = np.zeros((len(loads_mat), D), dtype=np.float64)
                padded[:, : loads_mat.shape[1]] = loads_mat
                loads_mat = padded
            elif loads_mat.shape[1] > D:
                raise ValueError(
                    f"worker load matrix has {loads_mat.shape[1]} dimensions "
                    f"but the run resolves to {D} ({dims})"
                )
        elif vector_mode:
            loads_mat = np.array(
                [as_resources(load, dims).values for load in worker_loads],
                dtype=np.float64,
            ).reshape(len(worker_loads), D)
        else:
            loads_mat = np.array(
                [float(load) for load in worker_loads], dtype=np.float64
            )[:, None]

        # -- item sizes, clamped exactly like the object paths
        item_hi = cap_vec - cfg.headroom
        m = len(requests)
        sizes = np.empty((m, D), dtype=np.float64)
        if vector_mode:
            for i, req in enumerate(requests):
                size = as_resources(req.size_estimate, dims).values
                size = np.minimum(size, item_hi)
                size = np.maximum(size, 0.0)
                size[0] = max(size[0], min(1e-3, item_hi[0]))
                sizes[i] = size
        else:
            hi = float(item_hi[0])
            for i, req in enumerate(requests):
                sizes[i, 0] = min(max(req.size_estimate, 1e-3), hi)

        algorithm = (
            vector_equivalent(cfg.algorithm) if vector_mode else cfg.algorithm
        )
        prefill = self._numpy_prefill(loads_mat, cap_vec)
        # audit snapshot: the packer adopts ``prefill`` as its live used
        # matrix and mutates it, so the free view must be copied now
        free_before = (
            (cap_vec - prefill).tolist() if self.audit else None
        )
        packer = NumpyPacker(
            algorithm,
            capacity=tuple(cap_vec) if vector_mode else float(cap_vec[0]),
            used=prefill,
        )
        assignments = packer.place_batch(sizes)
        self._inc_frontier = np.unique(assignments)

        placements: List[HostRequest] = []
        for req, idx in zip(requests, assignments, strict=True):
            req.target_worker = int(idx)
            placements.append(req)

        used = packer.used_matrix()
        used_bins = int((used > 1e-9).any(axis=1).sum())
        ideal = 0
        for total, c in zip(used.sum(axis=0).tolist(), cap_vec.tolist(), strict=True):
            if total > 0:
                ideal = max(ideal, max(1, int(math.ceil(total / c - _EPS))))
        target = used_bins + (
            idle_buffer(used_bins) if cfg.keep_idle_buffer else 0
        )

        if is_arr:
            scheduled: List = used.copy()  # the raw (n, D) matrix
        elif vector_mode:
            scheduled = [Resources(dims, row) for row in used]
        else:
            scheduled = [float(u) for u in used[:, 0]]

        run = PackingRun(
            t=t,
            placements=placements,
            num_bins=used_bins,
            target_workers=target,
            ideal_bins=ideal,
            scheduled_load=scheduled,
            audit=self._audit_record(
                algorithm, dims, cap_vec.tolist(), free_before,
                sizes.tolist(), [int(a) for a in assignments], requests,
            ) if self.audit else None,
        )
        self.runs.append(run)
        return run
