"""Container allocator / bin-packing manager (paper Section V-B.2).

Models the scheduling problem exactly as the paper does:

  - a worker VM is a *bin* with capacity 1.0 (an active VM is an open bin,
    pre-filled with the profiled usage of the PEs it already hosts),
  - a container hosting request is an *item* with size in (0, 1] — the
    profiled CPU usage of that PE's image,
  - a packing run (at a configurable rate) maps queued requests to workers
    and determines how many workers are needed.

On top of the raw bin count, a small buffer of idle workers is kept ready to
accept stream requests; the buffer is logarithmically proportional to the
number of currently active workers (paper Section V-A), providing more
headroom for fluctuations when the workload is not as high.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Union

import numpy as np

from .binpack import (
    Bin,
    Item,
    VectorBin,
    VectorItem,
    is_vector_policy,
    lower_bound,
    make_packer,
    vector_equivalent,
    vector_lower_bound,
)
from .queues import HostRequest
from .resources import ResourceLike, Resources, as_resources

__all__ = ["AllocatorConfig", "PackingRun", "BinPackingManager", "idle_buffer"]


def idle_buffer(active_workers: int) -> int:
    """Idle-worker headroom: ceil(log2(active + 1)) (log-proportional)."""
    return int(math.ceil(math.log2(active_workers + 1))) if active_workers > 0 else 1


@dataclasses.dataclass
class AllocatorConfig:
    # Packing algorithm for the packing run; First-Fit in the paper.  Any
    # ``make_packer`` name — scalar Any-Fit or a vector packer.  A scalar
    # name on a multi-resource cluster is auto-promoted to its vector
    # generalization (``binpack.vector_equivalent``).
    algorithm: str = "first-fit-tree"
    # Bin capacity: 1.0 == 100% of a worker's CPU.  On a multi-resource
    # cluster this may be a ``Resources`` vector (a float means every
    # dimension has that capacity).
    capacity: Union[float, Resources] = 1.0
    # Rate of packing runs, seconds (paper: "at a configurable rate").
    pack_interval: float = 2.0
    # Keep a log-proportional idle-worker buffer (paper Section V-A).
    keep_idle_buffer: bool = True
    # Optional per-run cap on consumed requests (back-pressure guard).
    max_requests_per_run: Optional[int] = None
    # Optional per-worker headroom so measurement noise does not congest a
    # worker scheduled at exactly 100% (0.0 == faithful paper behaviour).
    headroom: float = 0.0


@dataclasses.dataclass
class PackingRun:
    """Result of one periodic bin-packing run.

    ``scheduled_load`` entries are floats on the scalar path and
    ``Resources`` vectors on the multi-resource path; ``ideal_bins`` is the
    L1 lower bound (dominant-dimension L1 for vectors).
    """

    t: float
    placements: List[HostRequest]  # requests with ``target_worker`` attached
    num_bins: int                  # bins used by this packing solution
    target_workers: int            # num_bins + idle buffer
    ideal_bins: int                # L1 lower bound for the packed load
    scheduled_load: List[ResourceLike]  # per-bin scheduled usage after the run


class BinPackingManager:
    """Periodic First-Fit packing of queued PEs onto workers."""

    def __init__(self, config: Optional[AllocatorConfig] = None):
        self.config = config or AllocatorConfig()
        self._last_run_t: Optional[float] = None
        self.runs: List[PackingRun] = []

    def should_run(self, t: float) -> bool:
        return (
            self._last_run_t is None
            or (t - self._last_run_t) >= self.config.pack_interval
        )

    def run(
        self,
        t: float,
        requests: Sequence[HostRequest],
        worker_loads: Sequence[ResourceLike],
    ) -> PackingRun:
        """One packing run.

        ``worker_loads[i]`` is the *scheduled* (profiled) usage of active
        worker ``i`` — the sum of size estimates of the PEs it currently
        hosts.  Active workers are open bins pre-filled to that level; queued
        requests are packed in FIFO order; bins opened beyond the active
        workers represent the scale-up the IRM will request.

        The run is *vector* when anything multi-dimensional reaches it: a
        ``Resources`` capacity, a vector packing policy, or ``Resources``
        loads/size estimates.  A scalar run is bit-for-bit the paper's
        behaviour.
        """
        cfg = self.config
        if (
            isinstance(cfg.capacity, Resources)
            or is_vector_policy(cfg.algorithm)
            or any(isinstance(load, Resources) for load in worker_loads)
            or any(isinstance(r.size_estimate, Resources) for r in requests)
        ):
            return self._run_vector(t, requests, worker_loads)
        self._last_run_t = t
        cap = cfg.capacity - cfg.headroom
        bins = [Bin(cfg.capacity, used=min(load, cfg.capacity)) for load in worker_loads]
        try:
            # algorithms that support pre-filled open bins (the Any-Fit group)
            packer = make_packer(cfg.algorithm, capacity=cfg.capacity, bins=bins)
        except TypeError:
            raise ValueError(
                f"algorithm {cfg.algorithm!r} does not support pre-filled bins; "
                "use an Any-Fit algorithm for the IRM allocator"
            ) from None

        placements: List[HostRequest] = []
        for req in requests:
            size = min(max(req.size_estimate, 1e-3), cap)
            idx = packer.pack_one(Item(size=size, tag=req.req_id))
            req.target_worker = idx
            placements.append(req)

        used_bins = sum(1 for b in packer.bins if b.used > 1e-9)
        total_load = sum(b.used for b in packer.bins)
        ideal = lower_bound([total_load], cfg.capacity) if total_load > 0 else 0
        target = used_bins + (idle_buffer(used_bins) if cfg.keep_idle_buffer else 0)

        run = PackingRun(
            t=t,
            placements=placements,
            num_bins=used_bins,
            target_workers=target,
            ideal_bins=ideal,
            scheduled_load=[b.used for b in packer.bins],
        )
        self.runs.append(run)
        return run

    # -- multi-resource packing run (paper Sec. VII future work) -------------
    def _resolve_dims(
        self,
        requests: Sequence[HostRequest],
        worker_loads: Sequence[ResourceLike],
    ) -> tuple:
        """Dimension names for this run: config capacity wins, else the
        first ``Resources`` seen among loads / request estimates."""
        if isinstance(self.config.capacity, Resources):
            return self.config.capacity.dims
        for load in worker_loads:
            if isinstance(load, Resources):
                return load.dims
        for r in requests:
            if isinstance(r.size_estimate, Resources):
                return r.size_estimate.dims
        return ("cpu",)

    def _run_vector(
        self,
        t: float,
        requests: Sequence[HostRequest],
        worker_loads: Sequence[ResourceLike],
    ) -> PackingRun:
        """Vector bin-packing run: pre-filled *vector* bins, per-dimension
        headroom, dominant-dimension lower bound."""
        cfg = self.config
        self._last_run_t = t
        dims = self._resolve_dims(requests, worker_loads)
        D = len(dims)
        cap = as_resources(cfg.capacity, dims).values if isinstance(
            cfg.capacity, Resources
        ) else np.full(D, float(cfg.capacity))
        # per-dimension item ceiling: capacity minus headroom (the scalar
        # semantics — bins keep full capacity, items are clamped)
        item_hi = cap - cfg.headroom

        bins = [
            VectorBin(
                tuple(cap),
                used=np.minimum(as_resources(load, dims).values, cap),
            )
            for load in worker_loads
        ]
        algorithm = vector_equivalent(cfg.algorithm)
        packer = make_packer(algorithm, capacity=tuple(cap), bins=bins)

        items: List[VectorItem] = []
        for req in requests:
            size = as_resources(req.size_estimate, dims).values
            size = np.minimum(size, item_hi)
            size = np.maximum(size, 0.0)
            size[0] = max(size[0], min(1e-3, item_hi[0]))
            items.append(VectorItem(tuple(float(s) for s in size), tag=req.req_id))
        result = packer.pack(items)
        placements: List[HostRequest] = []
        for req, idx in zip(requests, result.assignments):
            req.target_worker = idx
            placements.append(req)

        used_bins = sum(
            1 for b in packer.bins if any(u > 1e-9 for u in b.used)
        )
        ideal = vector_lower_bound([b.used for b in packer.bins], tuple(cap))
        target = used_bins + (idle_buffer(used_bins) if cfg.keep_idle_buffer else 0)

        run = PackingRun(
            t=t,
            placements=placements,
            num_bins=used_bins,
            target_workers=target,
            ideal_bins=ideal,
            scheduled_load=[Resources(dims, b.used) for b in packer.bins],
        )
        self.runs.append(run)
        return run
