"""Backward-compatibility shim — the generators moved to ``repro.scenarios``.

The paper's two workloads (Section VI-A synthetic batches, Section VI-B
microscopy use case) now live in ``repro.scenarios.streams`` next to the
extended traffic shapes (bursty, diurnal, heavy-tailed, multi-tenant), and
are registered in the scenario catalogue (``repro.scenarios.registry``).

Import from ``repro.scenarios`` in new code; this module keeps the historic
``repro.core.workloads`` import path working for the sim, the Spark
baseline, and existing tests.
"""

from __future__ import annotations

from ..scenarios.streams import (  # noqa: F401
    Message,
    Stream,
    synthetic_workload,
    usecase_workload,
)

__all__ = ["Message", "Stream", "synthetic_workload", "usecase_workload"]
