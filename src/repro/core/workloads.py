"""Workload generators for the two evaluation scenarios in the paper.

Section VI-A (synthetic): four workload classes, all targeting 100% CPU (of
one core) for various durations, "streamed in regular small batches of jobs
and two peaks of large batches to introduce different levels of intensity in
pressure to the IRM".

Section VI-B (use case): 767 microscopy images processed by a CellProfiler
pipeline, each invocation taking 10–20 seconds, streamed as a single large
batch with randomized order (10 runs; the profiler persists across runs).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["Message", "Stream", "synthetic_workload", "usecase_workload"]

_msg_ids = itertools.count()


@dataclasses.dataclass
class Message:
    """One stream message: data to process + the container image to run.

    ``cpu_cores`` is the CPU draw while processing, in cores; ``duration`` is
    the processing time in seconds.
    """

    image: str
    duration: float
    cpu_cores: float = 1.0
    arrival: float = 0.0
    msg_id: int = dataclasses.field(default_factory=lambda: next(_msg_ids))
    # bookkeeping filled in by the sim
    start_t: float = -1.0
    done_t: float = -1.0


@dataclasses.dataclass
class Stream:
    """A time-ordered schedule of message batches."""

    batches: List[Tuple[float, List[Message]]]

    @property
    def num_messages(self) -> int:
        return sum(len(msgs) for _, msgs in self.batches)

    @property
    def images(self) -> List[str]:
        seen: Dict[str, None] = {}
        for _, msgs in self.batches:
            for m in msgs:
                seen.setdefault(m.image, None)
        return list(seen)

    def horizon(self) -> float:
        return max(t for t, _ in self.batches) if self.batches else 0.0


def synthetic_workload(
    seed: int = 0,
    *,
    t_end: float = 480.0,
    batch_interval: float = 12.0,
    batch_size: Tuple[int, int] = (3, 7),
    peak_times: Tuple[float, ...] = (120.0, 330.0),
    peak_size: int = 48,
) -> Stream:
    """Paper Section VI-A: periodic small batches plus two large peaks.

    Four synthetic classes all busy one core at ~100%, with durations
    5 / 10 / 20 / 40 s ("various amounts of time").
    """
    rng = np.random.default_rng(seed)
    classes = [
        ("synthetic/cpu100-d5", 5.0),
        ("synthetic/cpu100-d10", 10.0),
        ("synthetic/cpu100-d20", 20.0),
        ("synthetic/cpu100-d40", 40.0),
    ]

    def make_msgs(n: int, t: float) -> List[Message]:
        idx = rng.integers(0, len(classes), size=n)
        out = []
        for i in idx:
            image, dur = classes[int(i)]
            jitter = float(rng.uniform(0.9, 1.1))
            out.append(
                Message(image=image, duration=dur * jitter, cpu_cores=1.0, arrival=t)
            )
        return out

    batches: List[Tuple[float, List[Message]]] = []
    t = 0.0
    while t < t_end:
        n = int(rng.integers(batch_size[0], batch_size[1] + 1))
        batches.append((t, make_msgs(n, t)))
        t += batch_interval
    for pt in peak_times:
        batches.append((pt, make_msgs(peak_size, pt)))
    batches.sort(key=lambda b: b[0])
    return Stream(batches=batches)


def usecase_workload(
    seed: int = 0,
    *,
    n_images: int = 767,
    duration_range: Tuple[float, float] = (10.0, 20.0),
    image: str = "haste/cellprofiler:3.1.9",
) -> Stream:
    """Paper Section VI-B: the CellProfiler microscopy batch.

    The entire collection is streamed as a single batch; per-image analysis
    takes 10–20 s ("Due to variations in the images they take varying
    amounts of time to process").  The streaming order is randomized per run
    (the ``seed``).
    """
    rng = np.random.default_rng(seed)
    durations = rng.uniform(duration_range[0], duration_range[1], size=n_images)
    rng.shuffle(durations)  # randomized streaming order
    msgs = [
        Message(image=image, duration=float(d), cpu_cores=1.0, arrival=0.0)
        for d in durations
    ]
    return Stream(batches=[(0.0, msgs)])
