"""Load predictor (paper Section V-B.4).

Tracks the pressure of streaming requests by watching the master message
queue length and its rate of change (ROC).  Four threshold cases decide
between a *large* and a *small* increase in PEs:

    case 1: ROC >= roc_high   OR queue >= queue_high   -> large increase
    case 2: ROC >= roc_low    AND queue >= queue_low   -> large increase
    case 3: ROC >= roc_low    (queue moderate)         -> small increase
    case 4: queue >= queue_low (ROC moderate)          -> small increase

i.e. "if the ROC is very large or the queue is very long, this indicates that
data streams are not processed fast enough" (paper).  Queue metrics are read
periodically, and after scheduling more PEs there is a cooldown timeout before
the predictor reads them again — scheduling PEs ahead of need "gives HIO time
to set up additional workers and reduces the congestion".
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["LoadPredictorConfig", "LoadPredictor", "ScaleDecision"]


@dataclasses.dataclass
class LoadPredictorConfig:
    # queue-length thresholds (messages)
    queue_low: float = 8.0
    queue_high: float = 64.0
    # rate-of-change thresholds (messages / second)
    roc_low: float = 1.0
    roc_high: float = 8.0
    # scale-up magnitudes (number of PEs queued)
    small_increase: int = 2
    large_increase: int = 8
    # how often queue metrics are read (seconds)
    read_interval: float = 1.0
    # timeout after a scale-up before metrics are read again (seconds)
    cooldown: float = 5.0


@dataclasses.dataclass
class ScaleDecision:
    num_pes: int
    case: int  # 0 = no action, 1..4 as documented above
    roc: float
    queue_len: float


class LoadPredictor:
    """Queue-pressure-driven PE scale-up decisions."""

    def __init__(self, config: Optional[LoadPredictorConfig] = None):
        self.config = config or LoadPredictorConfig()
        self._last_read_t: Optional[float] = None
        self._last_len: Optional[float] = None
        self._cooldown_until: float = -1.0

    def reset(self) -> None:
        self._last_read_t = None
        self._last_len = None
        self._cooldown_until = -1.0

    def update(self, t: float, queue_len: float) -> ScaleDecision:
        """Periodic read of queue metrics; returns the scale-up decision.

        ``t`` is the current (simulated or wall) time in seconds.  Returns a
        decision with ``num_pes == 0`` while within the read interval or the
        post-scale-up cooldown.
        """
        cfg = self.config
        noop = ScaleDecision(0, 0, 0.0, queue_len)

        if t < self._cooldown_until:
            return noop
        if self._last_read_t is not None and (t - self._last_read_t) < cfg.read_interval:
            return noop

        roc = 0.0
        if self._last_read_t is not None and t > self._last_read_t:
            roc = (queue_len - self._last_len) / (t - self._last_read_t)
        self._last_read_t = t
        self._last_len = queue_len

        case, num = 0, 0
        if roc >= cfg.roc_high or queue_len >= cfg.queue_high:
            case, num = 1, cfg.large_increase
        elif roc >= cfg.roc_low and queue_len >= cfg.queue_low:
            case, num = 2, cfg.large_increase
        elif roc >= cfg.roc_low:
            case, num = 3, cfg.small_increase
        elif queue_len >= cfg.queue_low:
            case, num = 4, cfg.small_increase

        if num > 0:
            self._cooldown_until = t + cfg.cooldown
        return ScaleDecision(num_pes=num, case=case, roc=roc, queue_len=queue_len)
