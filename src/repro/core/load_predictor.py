"""Load predictor (paper Section V-B.4).

Tracks the pressure of streaming requests by watching the master message
queue length and its rate of change (ROC).  Four threshold cases decide
between a *large* and a *small* increase in PEs:

    case 1: ROC >= roc_high   OR queue >= queue_high   -> large increase
    case 2: ROC >= roc_low    AND queue >= queue_low   -> large increase
    case 3: ROC >= roc_low    (queue moderate)         -> small increase
    case 4: queue >= queue_low (ROC moderate)          -> small increase

i.e. "if the ROC is very large or the queue is very long, this indicates that
data streams are not processed fast enough" (paper).  Queue metrics are read
periodically, and after scheduling more PEs there is a cooldown timeout before
the predictor reads them again — scheduling PEs ahead of need "gives HIO time
to set up additional workers and reduces the congestion".

Multi-resource mode: when the cluster reports the backlog's aggregate
resource demand (a ``Resources`` vector), the predictor scales the queue
pressure on the *bottleneck dimension*.  A backlog whose dominant demand is
memory (or accelerator) represents proportionally more worker-opening
pressure than its message count alone suggests, so the effective queue
length is ``queue_len * (dominant utilization / cpu utilization)`` and the
ROC is tracked on that effective pressure.  With no demand vector (the
scalar paper path) the math is bit-for-bit unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .resources import Resources

__all__ = ["LoadPredictorConfig", "LoadPredictor", "ScaleDecision"]


@dataclasses.dataclass
class LoadPredictorConfig:
    # queue-length thresholds (messages)
    queue_low: float = 8.0
    queue_high: float = 64.0
    # rate-of-change thresholds (messages / second)
    roc_low: float = 1.0
    roc_high: float = 8.0
    # scale-up magnitudes (number of PEs queued)
    small_increase: int = 2
    large_increase: int = 8
    # how often queue metrics are read (seconds)
    read_interval: float = 1.0
    # timeout after a scale-up before metrics are read again (seconds)
    cooldown: float = 5.0


@dataclasses.dataclass
class ScaleDecision:
    num_pes: int
    case: int  # 0 = no action, 1..4 as documented above
    roc: float
    queue_len: float
    # effective pressure the thresholds saw (== queue_len on the scalar path)
    pressure: float = 0.0
    # the backlog's dominant resource dimension ("cpu" when scalar)
    bottleneck: str = "cpu"


class LoadPredictor:
    """Queue-pressure-driven PE scale-up decisions."""

    def __init__(self, config: Optional[LoadPredictorConfig] = None):
        self.config = config or LoadPredictorConfig()
        self._last_read_t: Optional[float] = None
        self._last_len: Optional[float] = None
        self._cooldown_until: float = -1.0

    def reset(self) -> None:
        self._last_read_t = None
        self._last_len = None
        self._cooldown_until = -1.0

    @staticmethod
    def effective_pressure(
        queue_len: float,
        demand: Optional[Resources],
        capacity: Optional[Resources] = None,
    ) -> Tuple[float, str]:
        """(effective queue pressure, bottleneck dimension).

        ``demand`` is the backlog's aggregate resource demand in
        worker-capacity fractions.  When its dominant dimension is not CPU,
        the message count understates how many workers the backlog will
        open, so pressure is scaled by ``util_dominant / util_cpu``.
        Returns ``queue_len`` unchanged on the scalar path (``demand`` is
        None or 1-D).
        """
        if demand is None or len(demand.dims) <= 1:
            return queue_len, "cpu"
        if capacity is not None:
            caps = capacity.align(demand.dims).values
        else:
            caps = np.ones(len(demand.dims))
        util = demand.values / np.maximum(caps, 1e-12)
        i = int(util.argmax())
        bottleneck = demand.dims[i]
        ref = float(util[0])
        if i == 0 or ref <= 1e-12 or float(util[i]) <= ref:
            return queue_len, bottleneck
        return queue_len * float(util[i]) / ref, bottleneck

    def update(
        self,
        t: float,
        queue_len: float,
        demand=None,
        capacity: Optional[Resources] = None,
    ) -> ScaleDecision:
        """Periodic read of queue metrics; returns the scale-up decision.

        ``t`` is the current (simulated or wall) time in seconds.  Returns a
        decision with ``num_pes == 0`` while within the read interval or the
        post-scale-up cooldown.  ``demand``/``capacity`` enable the
        bottleneck-dimension scaling documented in ``effective_pressure``;
        ``demand`` may be a ``Resources``, ``None``, or a zero-arg callable
        returning either — a callable is only evaluated on ticks that pass
        the read-interval/cooldown gates, so the (possibly expensive)
        backlog scan never runs on gated ticks.  Gated noop decisions
        therefore report ``pressure == queue_len``.
        """
        cfg = self.config

        if t < self._cooldown_until or (
            self._last_read_t is not None
            and (t - self._last_read_t) < cfg.read_interval
        ):
            return ScaleDecision(0, 0, 0.0, queue_len, pressure=queue_len)

        if callable(demand):
            demand = demand()
        pressure, bottleneck = self.effective_pressure(queue_len, demand, capacity)

        roc = 0.0
        if self._last_read_t is not None and t > self._last_read_t:
            roc = (pressure - self._last_len) / (t - self._last_read_t)
        self._last_read_t = t
        self._last_len = pressure

        case, num = 0, 0
        if roc >= cfg.roc_high or pressure >= cfg.queue_high:
            case, num = 1, cfg.large_increase
        elif roc >= cfg.roc_low and pressure >= cfg.queue_low:
            case, num = 2, cfg.large_increase
        elif roc >= cfg.roc_low:
            case, num = 3, cfg.small_increase
        elif pressure >= cfg.queue_low:
            case, num = 4, cfg.small_increase

        if num > 0:
            self._cooldown_until = t + cfg.cooldown
        return ScaleDecision(num_pes=num, case=case, roc=roc,
                             queue_len=queue_len, pressure=pressure,
                             bottleneck=bottleneck)
