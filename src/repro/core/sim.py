"""Discrete-event cluster simulation — the paper's SNIC testbed in software.

The paper evaluates the IRM on a real OpenStack cloud (SNIC).  This module
reproduces that environment as a deterministic, seeded, fixed-timestep
simulation so the *same IRM code* can be evaluated quantitatively:

  - workers are VMs with ``cores`` CPU cores and a boot delay,
  - PEs are containers with a start delay, an idle self-termination timeout,
    and a measured CPU draw (target + noise) while processing a message,
  - messages queue at the master and are pulled P2P by idle PEs of the
    matching image (backlog processed with priority, i.e. FIFO),
  - worker probes report per-image mean usage to the master profiler at
    ``report_interval`` (1 s in the paper's experiments).

Everything the paper plots is recorded per tick: measured CPU per worker
(Fig. 3/4/8), scheduled-vs-measured error (Fig. 5/9), queue length, and
active/target/ideal worker counts (Fig. 10).

The simulation deliberately reproduces the paper's noise sources: the delay
between scheduling a PE and it actually drawing CPU (start transient), rapid
start/stop churn, and measurement noise.

Implementation note — the indexed hot path.  This is the throughput-tuned
rewrite of the original per-tick full-scan simulation (kept verbatim in
``sim_reference.py`` and equivalence-tested in
``tests/test_sim_equivalence.py``).  Results are tick-for-tick, bit-for-bit
identical; only the data structures changed:

  - the master queue is a set of **per-image FIFO deques** keyed by a global
    arrival sequence number, so a P2P pull is ``deque.popleft()`` instead of
    an O(queue) scan + ``list.pop(i)`` — the global-FIFO match order is
    preserved exactly because each deque stays sorted by sequence number
    (front re-inserts use decreasing negative sequence numbers);
  - PE state transitions are driven by **event indices**: a min-heap of
    STARTING PEs keyed by ready time, a min-heap of BUSY PEs keyed by
    message completion time, and a dict of IDLE PEs keyed by
    ``(worker idx, PE creation id)`` — so a tick touches only the PEs that
    change state plus the currently-idle set, not every PE on every worker;
  - ``simulate`` records into **preallocated numpy buffers** sliced once at
    the end instead of growing Python lists and stacking;
  - per-tick allocations (including a per-tick ``import math``) are hoisted
    out of the loop, and the master profiler memoizes its moving-average
    estimates between probe reports (``MasterProfiler.estimate``).
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import math
from bisect import insort
from collections import deque
from itertools import islice
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..obs.audit import emit_packing_audit
from .irm import IRM, IRMConfig
from .profiler import WorkerProbe
from .queues import HostRequest
from .resources import Resources
from .workloads import Message, Stream

__all__ = ["SimConfig", "SimResult", "SimCluster", "simulate",
           "worker_fits_message"]


class PEState(enum.Enum):
    STARTING = "starting"
    IDLE = "idle"
    BUSY = "busy"
    STOPPED = "stopped"


class WorkerState(enum.Enum):
    BOOTING = "booting"
    ACTIVE = "active"
    OFF = "off"


@dataclasses.dataclass
class SimConfig:
    dt: float = 0.5                 # simulation tick, seconds
    cores_per_worker: int = 8       # SSC.xlarge has 8 vCPUs
    max_workers: int = 5            # the paper restricts both frameworks to 5
    worker_boot_delay: float = 15.0
    pe_start_delay: float = 2.5     # container start latency
    container_idle_timeout: float = 1.0  # paper: 1 s in the use-case runs
    report_interval: float = 1.0    # paper: 1 s in the use-case runs
    cpu_noise_std: float = 0.02     # measurement noise (fraction of a worker)
    idle_pe_cpu_cores: float = 0.02
    t_max: float = 3600.0
    seed: int = 0
    # if True, a worker failure is injected (fault-tolerance tests)
    fail_worker_at: Optional[Tuple[int, float]] = None  # (worker idx, time)
    # Resource dimensions of a worker.  ("cpu",) is the paper's scalar model
    # (bit-for-bit unchanged).  More dimensions (dim 0 must stay "cpu")
    # switch the cluster to vector mode: messages carry per-dimension draws
    # (``Message.resources``), the profiler learns per-dimension estimates,
    # the allocator packs vector bins, and non-CPU dimensions are *rigid*
    # (a worker never overcommits them — the congestion gate below).
    resource_dims: Tuple[str, ...] = ("cpu",)


def worker_fits_message(pes, msg: "Message", dims: Tuple[str, ...],
                        t: float) -> bool:
    """Non-CPU congestion gate: can this worker take ``msg`` right now?

    CPU stays fungible (the paper lets measured CPU overcommit and clip);
    auxiliary dimensions (memory, accelerator) are rigid, so an idle PE may
    only pull a message while every non-CPU dimension stays within worker
    capacity.  A dimension's committed usage counts messages that are still
    *running* at ``t`` (``done_t > t``): both simulation implementations
    agree on that set regardless of the order they process completions in,
    which keeps the indexed and reference paths bit-for-bit identical.

    Shared by ``sim`` and ``sim_reference`` so the two can never drift.
    """
    mres = msg.resources
    for d in dims[1:]:
        need = mres.get(d, 0.0) if mres else 0.0
        committed = 0.0
        for pe in pes:
            pmsg = pe.msg
            if pmsg is not None and pmsg.done_t > t and pmsg.resources:
                committed += pmsg.resources.get(d, 0.0)
        if committed + need > 1.0 + 1e-9:
            return False
    return True


class SimPE:
    __slots__ = ("image", "state", "ready_t", "msg", "idle_since", "estimate",
                 "uid")

    def __init__(self, image: str, t: float, start_delay: float,
                 estimate: float, uid: int = 0):
        self.image = image
        self.state = PEState.STARTING
        self.ready_t = t + start_delay
        self.msg: Optional[Message] = None
        self.idle_since = -1.0
        self.estimate = estimate  # size estimate at placement time (scheduled)
        self.uid = uid  # creation order; (worker idx, uid) is the pass order


class SimWorker:
    __slots__ = ("idx", "state", "ready_t", "pes", "probe")

    def __init__(self, idx: int, t: float, boot_delay: float):
        self.idx = idx
        self.state = WorkerState.BOOTING if boot_delay > 0 else WorkerState.ACTIVE
        self.ready_t = t + boot_delay
        self.pes: List[SimPE] = []
        self.probe = WorkerProbe()


@dataclasses.dataclass
class SimResult:
    times: np.ndarray               # (T,)
    measured_cpu: np.ndarray        # (T, max_workers) fraction of worker
    scheduled_cpu: np.ndarray       # (T, max_workers) bin-packing view
    queue_len: np.ndarray           # (T,)
    active_workers: np.ndarray      # (T,)
    target_workers: np.ndarray      # (T,)
    ideal_bins: np.ndarray          # (T,)
    pe_count: np.ndarray            # (T,)
    completed: int
    total: int
    makespan: float                 # time when the last message finished
    messages: List[Message]
    # -- multi-resource extension (None / ("cpu",) on the scalar path) -------
    resource_dims: Tuple[str, ...] = ("cpu",)
    measured_res: Optional[np.ndarray] = None   # (T, max_workers, D)
    scheduled_res: Optional[np.ndarray] = None  # (T, max_workers, D)
    # in-flight messages returned to the queue head by worker failures
    # (``fail_worker_at``) — the at-least-once accounting both backends
    # expose so the fault-parity suite can compare them directly
    requeued: int = 0

    @property
    def error(self) -> np.ndarray:
        """Scheduled minus measured CPU, percentage points (Figs. 5/9)."""
        return (self.scheduled_cpu - self.measured_cpu) * 100.0

    def mean_busy_utilization(self) -> float:
        """Mean measured utilization over (worker, tick) cells that are on."""
        on = self.scheduled_cpu > 1e-6
        if not on.any():
            return 0.0
        return float(self.measured_cpu[on].mean())


class SimCluster:
    """ClusterView implementation backed by the simulation state.

    The master queue and the PE population are indexed (see the module
    docstring) so a tick costs O(changed PEs + idle PEs), not
    O(workers x PEs x queue).
    """

    def __init__(self, config: SimConfig, irm: IRM, bus=None):
        self.cfg = config
        self.irm = irm
        # optional observability event bus (``bus.now`` stays None on the
        # sim backend: events are stamped with the nominal tick).  Every
        # emission is a guarded list append — no RNG, no float math — so
        # the tick-for-tick trace is bit-identical with or without it.
        self.bus = bus
        self.t = 0.0
        self.rng = np.random.default_rng(config.seed)
        self.workers: List[SimWorker] = []
        self.completed: List[Message] = []
        self.requested_target = 0
        self.max_done_t = 0.0  # running max over completed messages
        self._failed: set = set()
        self.requeued = 0  # messages bounced back to the head by failures
        # ---- multi-resource mode ------------------------------------------
        self._dims = tuple(config.resource_dims)
        self._multi = len(self._dims) > 1
        if self._multi:
            if self._dims[0] != "cpu":
                raise ValueError(
                    f"resource_dims[0] must be 'cpu', got {self._dims}"
                )
            # unseen-image defaults become Resources vectors
            irm.profiler.set_resource_dims(self._dims)
        # per-dimension measured usage (n_workers, D), filled by measure()
        self.last_dim_measure: Optional[np.ndarray] = None
        # ---- master queue: per-image FIFO deques of (seq, message) --------
        # Each deque is sorted ascending by the global arrival sequence
        # number, so its head is the first message of that image in global
        # FIFO order.  Normal arrivals take increasing positive sequence
        # numbers; front re-inserts (failure requeues) take decreasing
        # negative ones — exactly ``list.insert(0, m)`` semantics.
        self._img_queues: Dict[str, Deque[Tuple[int, Message]]] = {}
        self._qlen = 0
        self._seq_back = 0
        self._seq_front = 0
        # ---- PE indices ---------------------------------------------------
        self._pe_uid = 0
        self._starting: List[Tuple[float, int, int, SimPE]] = []  # ready_t heap
        self._busy: List[Tuple[float, int, int, SimPE, Message]] = []  # done_t
        self._idle: Dict[Tuple[int, int], SimPE] = {}
        self._dirty_workers: set = set()  # workers with STOPPED PEs to compact
        # ---- worker indices (fleet-scale lifecycle) -----------------------
        # The probe/measure/recording paths and the lifecycle transitions
        # iterate these instead of scanning the whole pool, so a tick costs
        # O(active workers + transitions) rather than O(pool slots):
        #   _active_idx — ACTIVE worker indices, kept sorted ascending so
        #       every iteration order (and hence RNG draw order and float
        #       summation order) matches the reference's full scan;
        #   _boot_heap  — (ready_t, idx) min-heap of BOOTING workers with
        #       lazy invalidation (an entry is live iff the worker is still
        #       BOOTING with that exact ready_t);
        #   _off_heap   — min-heap of OFF slot indices; its top is the
        #       lowest OFF slot, mirroring the reference's first-OFF scan
        #       (a *failed* top blocks reuse and forces appends, exactly
        #       like the reference finding the failed slot first);
        #   _n_alive    — count of non-OFF workers.
        self._active_idx: List[int] = []
        self._boot_heap: List[Tuple[float, int]] = []
        self._off_heap: List[int] = []
        self._n_alive = 0

    # ---- master queue ---------------------------------------------------------
    def _push_back(self, m: Message) -> None:
        self._seq_back += 1
        dq = self._img_queues.get(m.image)
        if dq is None:
            dq = self._img_queues[m.image] = deque()
        dq.append((self._seq_back, m))
        self._qlen += 1
        if self.bus is not None:
            self.bus.emit("msg.enqueued", msg_id=m.msg_id, image=m.image,
                          arrival=m.arrival)

    def _push_front(self, m: Message) -> None:
        self._seq_front -= 1
        dq = self._img_queues.get(m.image)
        if dq is None:
            dq = self._img_queues[m.image] = deque()
        dq.appendleft((self._seq_front, m))
        self._qlen += 1

    def backlog_head(self, k: int) -> List[Message]:
        """The first ``k`` queued messages in global FIFO order."""
        if self._qlen == 0 or k <= 0:
            return []
        live = [iter(dq) for dq in self._img_queues.values() if dq]
        if len(live) == 1:
            return [m for _, m in islice(live[0], k)]
        return [m for _, m in islice(heapq.merge(*live), k)]

    @property
    def queue(self) -> List[Message]:
        """The backlog in global FIFO order (debugging / inspection only)."""
        return self.backlog_head(self._qlen)

    # ---- ClusterView protocol -------------------------------------------------
    def queue_length(self) -> float:
        return float(self._qlen)

    def queue_image_mix(self) -> Dict[str, float]:
        # Insertion order of the result must follow each image's first
        # occurrence in global FIFO order (= its deque head's sequence
        # number): the IRM's largest-remainder apportionment breaks ties by
        # this order.
        if self._qlen == 0:
            return {}
        heads = sorted(
            (dq[0][0], img, len(dq))
            for img, dq in self._img_queues.items()
            if dq
        )
        n = float(self._qlen)
        return {img: cnt / n for _, img, cnt in heads}

    def worker_scheduled_loads(self) -> List:
        # Bins are pre-filled with the *current* profiled usage of the PEs
        # they host — the paper propagates updated moving averages to all
        # scheduling state, not placement-time snapshots (Section V-B.3).
        # Estimates are looked up once per image per call; the accumulation
        # stays in PE-list order so the float sum matches the reference.
        # Only ACTIVE workers can host PEs (BOOTING pools are empty, OFF
        # slots report zero), so the PE accumulation visits the active index
        # instead of scanning the whole pool — values are identical to the
        # reference's full scan.
        est = self.irm.profiler.estimate
        cache: Dict[str, float] = {}
        stopped = PEState.STOPPED
        workers = self.workers
        if self._multi:
            # vector mode: per-dimension float64 accumulation, same order
            D = len(self._dims)
            dims = self._dims
            vout: List[Resources] = [
                Resources(dims, np.zeros(D)) for _ in range(len(workers))
            ]
            for idx in self._active_idx:
                load = np.zeros(D)
                for pe in workers[idx].pes:
                    if pe.state is stopped:
                        continue
                    img = pe.image
                    v = cache.get(img)
                    if v is None:
                        v = cache[img] = est(img).values
                    load = load + v
                vout[idx] = Resources(dims, load)
            return vout
        out = [0.0] * len(workers)
        for idx in self._active_idx:
            load = 0.0
            for pe in workers[idx].pes:
                if pe.state is stopped:
                    continue
                img = pe.image
                v = cache.get(img)
                if v is None:
                    v = cache[img] = est(img)
                load += v
            out[idx] = load
        return out

    def backlog_resource_demand(self) -> Optional[Resources]:
        """Aggregate estimated demand of the backlog head (vector mode)."""
        if not self._multi:
            return None
        est = self.irm.profiler.estimate
        total: Optional[Resources] = None
        for msg in self.backlog_head(64):
            v = est(msg.image)
            total = v if total is None else total + v
        return total

    def try_start_pe(self, req: HostRequest) -> bool:
        idx = req.target_worker
        if idx is None or idx >= len(self.workers):
            return False
        w = self.workers[idx]
        if w.state != WorkerState.ACTIVE:
            return False  # e.g. "a new VM still initializing" (paper V-B.2)
        self._pe_uid += 1
        pe = SimPE(req.image, self.t, self.cfg.pe_start_delay,
                   req.size_estimate, uid=self._pe_uid)
        w.pes.append(pe)
        heapq.heappush(self._starting, (pe.ready_t, idx, pe.uid, pe))
        if self.bus is not None:
            self.bus.emit("pe.spawn", worker=idx, pe=pe.uid,
                          image=req.image)
        return True

    def _lowest_off_slot(self) -> Optional[SimWorker]:
        """The lowest-index OFF worker (the reference's first-OFF scan).

        May return a *failed* worker: the reference's scan stops at the
        first OFF slot and, seeing it failed, appends a fresh worker — a
        failed lowest slot must block reuse here too, so it is peeked but
        never popped.
        """
        h = self._off_heap
        while h:
            w = self.workers[h[0]]
            if w.state is not WorkerState.OFF:
                heapq.heappop(h)  # stale entry (slot was reused)
                continue
            return w
        return None

    def scale_workers(self, target: int) -> None:
        self.requested_target = target
        capped = min(target, self.cfg.max_workers)
        n_alive = self._n_alive
        # boot additional workers
        while n_alive < capped:
            # reuse the lowest OFF slot if any, else append
            slot = self._lowest_off_slot()
            if slot is not None and slot.idx not in self._failed:
                heapq.heappop(self._off_heap)
                slot.state = WorkerState.BOOTING
                slot.ready_t = self.t + self.cfg.worker_boot_delay
                heapq.heappush(self._boot_heap, (slot.ready_t, slot.idx))
                if self.bus is not None:
                    self.bus.emit("worker.boot", worker=slot.idx,
                                  ready_t=slot.ready_t)
            else:
                w = SimWorker(
                    len(self.workers), self.t, self.cfg.worker_boot_delay
                )
                self.workers.append(w)
                if w.state is WorkerState.BOOTING:
                    heapq.heappush(self._boot_heap, (w.ready_t, w.idx))
                else:  # zero boot delay: born ACTIVE
                    insort(self._active_idx, w.idx)
                if self.bus is not None:
                    self.bus.emit("worker.boot", worker=w.idx,
                                  ready_t=w.ready_t)
            n_alive += 1
        # deactivate empty workers above the target (highest index first)
        if n_alive > capped:
            for idx in reversed(list(self._active_idx)):
                if n_alive <= capped:
                    break
                w = self.workers[idx]
                if not w.pes:
                    w.state = WorkerState.OFF
                    self._active_idx.remove(idx)
                    heapq.heappush(self._off_heap, idx)
                    n_alive -= 1
                    if self.bus is not None:
                        self.bus.emit("worker.deactivate", worker=idx)
        self._n_alive = n_alive

    # ---- simulation dynamics ---------------------------------------------------
    def _inject_failure(self) -> None:
        if self.cfg.fail_worker_at is None:
            return
        idx, when = self.cfg.fail_worker_at
        if self.t >= when and idx < len(self.workers) and idx not in self._failed:
            w = self.workers[idx]
            n_pes = len(w.pes)
            n_req = 0
            # in-flight messages are lost back to the master queue
            # (at-least-once); front-inserted one by one, so the last PE's
            # message ends up globally first — list.insert(0, m) semantics.
            for pe in w.pes:
                if pe.msg is not None:
                    pe.msg.start_t = -1.0
                    self._push_front(pe.msg)
                    self.requeued += 1
                    n_req += 1
                    if self.bus is not None:
                        self.bus.emit("msg.requeued", msg_id=pe.msg.msg_id,
                                      image=pe.msg.image)
                # purge from the indices: heap entries are skipped lazily
                # once the state no longer matches.
                self._idle.pop((w.idx, pe.uid), None)
                pe.state = PEState.STOPPED
                pe.msg = None
            w.pes = []
            if self.bus is not None:
                self.bus.emit("worker.kill", worker=idx, pes=n_pes,
                              requeued=n_req)
            if w.state is not WorkerState.OFF:
                if w.state is WorkerState.ACTIVE:
                    self._active_idx.remove(idx)
                # a BOOTING victim leaves a stale _boot_heap entry behind;
                # the promotion pass skips it (state no longer matches)
                self._n_alive -= 1
                heapq.heappush(self._off_heap, idx)
            w.state = WorkerState.OFF
            self._failed.add(idx)

    def tick(self, arrivals: List[Message]) -> None:
        cfg = self.cfg
        for m in arrivals:
            self._push_back(m)
        self._inject_failure()
        t = self.t

        # worker lifecycle: promote ready BOOTING workers off the min-heap
        # (the transition depends only on t, so heap order == scan order
        # up to the irrelevant promotion sequence; the sorted active index
        # preserves every downstream iteration order)
        bh_boot = self._boot_heap
        while bh_boot and bh_boot[0][0] <= t:
            rt, widx = heapq.heappop(bh_boot)
            w = self.workers[widx]
            if w.state is WorkerState.BOOTING and w.ready_t == rt:
                w.state = WorkerState.ACTIVE
                insort(self._active_idx, widx)
                if self.bus is not None:
                    self.bus.emit("worker.active", worker=widx)

        # STARTING -> IDLE.  Transition conditions depend only on t, so
        # draining the ready heap is order-equivalent to the reference
        # simulation's in-pass checks.
        sh = self._starting
        while sh and sh[0][0] <= t:
            _, widx, uid, pe = heapq.heappop(sh)
            if pe.state is PEState.STARTING:
                pe.state = PEState.IDLE
                pe.idle_since = t
                self._idle[(widx, uid)] = pe

        # BUSY -> IDLE (message completions)
        bh = self._busy
        done_now: List[Tuple[int, int, SimPE]] = []
        while bh and bh[0][0] <= t:
            _, widx, uid, pe, msg = heapq.heappop(bh)
            if pe.state is PEState.BUSY and pe.msg is msg:
                done_now.append((widx, uid, pe))
        # completed in the reference pass order: (worker idx, PE order)
        done_now.sort()
        for widx, uid, pe in done_now:
            self.completed.append(pe.msg)
            if pe.msg.done_t > self.max_done_t:
                self.max_done_t = pe.msg.done_t
            if self.bus is not None:
                dm = pe.msg
                self.bus.emit("msg.completed", msg_id=dm.msg_id,
                              image=dm.image, worker=widx, pe=uid,
                              start_t=dm.start_t, done_t=dm.done_t,
                              arrival=dm.arrival)
            pe.msg = None
            pe.state = PEState.IDLE
            pe.idle_since = t
            self._idle[(widx, uid)] = pe

        # IDLE: P2P pulls then the idle timeout, in the reference pass order.
        # A pull is deque.popleft() on this image's FIFO — the head is the
        # first matching message in *global* FIFO order by construction.
        if self._idle:
            timeout = cfg.container_idle_timeout
            img_queues = self._img_queues
            multi = self._multi
            for key in sorted(self._idle):
                pe = self._idle[key]
                dq = img_queues.get(pe.image)
                # vector mode: rigid non-CPU dimensions gate the P2P pull
                # (head-blocking FIFO: a blocked head is not skipped)
                if dq and multi and not worker_fits_message(
                    self.workers[key[0]].pes, dq[0][1], self._dims, t
                ):
                    dq = None
                if dq:
                    _, m = dq.popleft()
                    self._qlen -= 1
                    m.start_t = t
                    m.done_t = t + m.duration
                    pe.msg = m
                    pe.state = PEState.BUSY
                    del self._idle[key]
                    heapq.heappush(bh, (m.done_t, key[0], key[1], pe, m))
                    if self.bus is not None:
                        self.bus.emit("msg.pulled", msg_id=m.msg_id,
                                      image=m.image, worker=key[0],
                                      pe=key[1])
                        self.bus.emit("msg.started", msg_id=m.msg_id,
                                      image=m.image, worker=key[0],
                                      pe=key[1])
                elif t - pe.idle_since >= timeout:
                    pe.state = PEState.STOPPED  # graceful self-termination
                    del self._idle[key]
                    self._dirty_workers.add(key[0])
                    if self.bus is not None:
                        self.bus.emit("pe.exit", worker=key[0], pe=key[1],
                                      image=pe.image)

        # compact only the workers that lost a PE this tick
        if self._dirty_workers:
            for widx in self._dirty_workers:
                w = self.workers[widx]
                w.pes = [pe for pe in w.pes if pe.state is not PEState.STOPPED]
            self._dirty_workers.clear()

    def _measure_multi(self) -> np.ndarray:
        """Vector-mode measurement: per-dimension usage per worker.

        CPU (dimension 0) keeps the scalar path's noisy draw — same RNG
        sequence — while auxiliary dimensions are measured exactly (memory
        and accelerator reservations are deterministic).  Fills
        ``last_dim_measure`` (n_workers, D) and returns the CPU column.
        """
        cfg = self.cfg
        dims = self._dims
        D = len(dims)
        cores_per_worker = float(cfg.cores_per_worker)
        noise_std = cfg.cpu_noise_std * cfg.cores_per_worker
        idle_draw = min(max(cfg.idle_pe_cpu_cores, 0.0), cores_per_worker)
        rng_normal = self.rng.normal
        busy, idle = PEState.BUSY, PEState.IDLE
        n = max(len(self.workers), 1)
        out = np.zeros(n)
        dim_out = np.zeros((n, D))
        # ascending active indices == the reference's full scan filtered to
        # ACTIVE workers: same RNG draw order, same probe accumulation order
        for idx in self._active_idx:
            w = self.workers[idx]
            totals = np.zeros(D)
            acc, counts = w.probe.accumulators()
            for pe in w.pes:
                vec = np.zeros(D)
                if pe.state is busy and pe.msg is not None:
                    draw = pe.msg.cpu_cores * float(rng_normal(1.0, noise_std))
                    if draw < 0.0:
                        draw = 0.0
                    elif draw > cores_per_worker:
                        draw = cores_per_worker
                    vec[0] = draw / cores_per_worker
                    mres = pe.msg.resources
                    if mres:
                        for j in range(1, D):
                            vec[j] = mres.get(dims[j], 0.0)
                elif pe.state is idle:
                    vec[0] = idle_draw / cores_per_worker
                totals = totals + vec
                img = pe.image
                if img in acc:
                    acc[img] = acc[img] + vec
                    counts[img] += 1
                else:
                    acc[img] = vec
                    counts[img] = 1
            clipped = np.minimum(totals, 1.0)
            dim_out[w.idx] = clipped
            out[w.idx] = clipped[0]
        self.last_dim_measure = dim_out
        return out

    def measure(self) -> np.ndarray:
        """Instantaneous measured CPU per worker (fraction of the worker)."""
        if self._multi:
            return self._measure_multi()
        cfg = self.cfg
        cores_per_worker = float(cfg.cores_per_worker)
        noise_std = cfg.cpu_noise_std * cfg.cores_per_worker
        # idle draw pre-clipped to [0, cores_per_worker] once per call
        idle_draw = min(max(cfg.idle_pe_cpu_cores, 0.0), cores_per_worker)
        rng_normal = self.rng.normal
        busy, idle = PEState.BUSY, PEState.IDLE
        out = np.zeros(max(len(self.workers), 1))
        # ascending active indices == the reference's full scan filtered to
        # ACTIVE workers: same RNG draw order, same probe accumulation order
        for idx in self._active_idx:
            w = self.workers[idx]
            cores = 0.0
            # accumulate straight into the probe's per-image running means
            # (same order and float addition as WorkerProbe.sample)
            acc, counts = w.probe.accumulators()
            for pe in w.pes:
                if pe.state is busy and pe.msg is not None:
                    draw = pe.msg.cpu_cores * float(rng_normal(1.0, noise_std))
                    # clip to [0, cores_per_worker] (bit-equal to np.clip)
                    if draw < 0.0:
                        draw = 0.0
                    elif draw > cores_per_worker:
                        draw = cores_per_worker
                elif pe.state is idle:
                    draw = idle_draw
                else:  # STARTING draws ~nothing: the paper's transient error
                    draw = 0.0
                cores += draw
                img = pe.image
                if img in acc:
                    acc[img] += draw / cores_per_worker
                    counts[img] += 1
                else:
                    acc[img] = draw / cores_per_worker
                    counts[img] = 1
            u = cores / cores_per_worker
            out[w.idx] = u if u < 1.0 else 1.0
        return out

    def flush_probes(self) -> None:
        dims = self._dims if self._multi else None
        for idx in self._active_idx:
            w = self.workers[idx]
            if w.pes:
                report = w.probe.report()
                if report:
                    if dims is not None:
                        # vector mode accumulates ndarrays; name them
                        report = {
                            img: Resources(dims, vec)
                            for img, vec in report.items()
                        }
                    self.irm.ingest_report(report)


def simulate(
    stream: Stream,
    config: Optional[SimConfig] = None,
    irm: Optional[IRM] = None,
    irm_config: Optional[IRMConfig] = None,
    bus=None,
) -> SimResult:
    """Run the IRM against a workload stream; returns recorded time series.

    Passing an existing ``irm`` keeps its profiler state across runs — the
    paper's 10-run experiment where "HIO was started fresh for the first run
    and remained running for all subsequent runs".

    ``bus``, when given, receives the observability event stream (message
    spans, worker/PE lifecycle, IRM decision audit) with the same schema
    as the live backends; events are stamped in nominal tick time.  The
    frozen reference simulation has no such hook, and the equivalence
    suite runs with ``bus=None``, so the bit-for-bit contract is intact.
    """
    cfg = config or SimConfig()
    if irm is None:
        irm = IRM(irm_config or IRMConfig())
    else:
        irm.begin_run()
    cluster = SimCluster(cfg, irm, bus=bus)
    if bus is not None:
        irm.packing_manager.audit = bus.audit

    batches = sorted(stream.batches, key=lambda b: b[0])
    n_batches = len(batches)
    next_batch = 0
    total = stream.num_messages

    # preallocated recording buffers, sliced to the tick count at the end
    cap = int(cfg.t_max / cfg.dt) + 2
    times = np.empty(cap, np.float64)
    measured = np.zeros((cap, cfg.max_workers), np.float64)
    scheduled = np.zeros((cap, cfg.max_workers), np.float64)
    qlen = np.empty(cap, np.int64)
    active = np.empty(cap, np.int64)
    target = np.empty(cap, np.int64)
    ideal = np.empty(cap, np.int64)
    pe_count = np.empty(cap, np.int64)
    dims = cluster._dims
    multi = cluster._multi
    D = len(dims)
    measured_res = np.zeros((cap, cfg.max_workers, D)) if multi else None
    scheduled_res = np.zeros((cap, cfg.max_workers, D)) if multi else None

    W = cfg.max_workers
    workers = cluster.workers
    estimate = irm.profiler.estimate
    last_report_t = -1e9
    n = 0

    t = 0.0
    while t <= cfg.t_max:
        cluster.t = t
        if bus is not None:
            bus.tick = t
        arrivals: List[Message] = []
        while next_batch < n_batches and batches[next_batch][0] <= t:
            arrivals.extend(batches[next_batch][1])
            next_batch += 1

        cluster.tick(arrivals)
        m = cluster.measure()
        if t - last_report_t >= cfg.report_interval:
            cluster.flush_probes()
            last_report_t = t
        step_metrics = irm.step(t, cluster)
        if bus is not None:
            emit_packing_audit(bus, irm.config.allocator.algorithm,
                               step_metrics.packing)

        if n >= cap:  # t_max/dt bounds the tick count; guard regardless
            times = np.concatenate([times, np.empty(cap, np.float64)])
            measured = np.vstack([measured, np.zeros((cap, W), np.float64)])
            scheduled = np.vstack([scheduled, np.zeros((cap, W), np.float64)])
            qlen = np.concatenate([qlen, np.empty(cap, np.int64)])
            active = np.concatenate([active, np.empty(cap, np.int64)])
            target = np.concatenate([target, np.empty(cap, np.int64)])
            ideal = np.concatenate([ideal, np.empty(cap, np.int64)])
            pe_count = np.concatenate([pe_count, np.empty(cap, np.int64)])
            if multi:
                measured_res = np.concatenate(
                    [measured_res, np.zeros((cap, W, D))])
                scheduled_res = np.concatenate(
                    [scheduled_res, np.zeros((cap, W, D))])
            cap *= 2

        times[n] = t
        k = min(len(m), W)
        measured[n, :k] = m[:k]
        sl = cluster.worker_scheduled_loads()
        srow = scheduled[n]
        if multi:
            dm = cluster.last_dim_measure
            measured_res[n, :k] = dm[:k]
            for j in range(min(len(sl), W)):
                v = sl[j].values
                c = v[0]
                srow[j] = c if c < 1.0 else 1.0
                scheduled_res[n, j] = np.minimum(v, 1.0)
        else:
            for j in range(min(len(sl), W)):
                v = sl[j]
                srow[j] = v if v < 1.0 else 1.0

        qlen[n] = cluster._qlen
        # PEs only live on ACTIVE workers (BOOTING pools are empty; OFF
        # transitions clear or forbid PEs), so counting over the sorted
        # active index reproduces the reference's full-pool scan, including
        # the float order of the busy-load accumulation.
        if multi:
            n_active = len(cluster._active_idx)
            n_pes = 0
            busy_vec = np.zeros(D)
            for widx in cluster._active_idx:
                pes = workers[widx].pes
                n_pes += len(pes)
                for pe in pes:
                    busy_vec = busy_vec + pe.estimate.values
            active[n] = n_active
            target[n] = cluster.requested_target
            pe_count[n] = n_pes
            # ideal bins: dominant-dimension bound on the in-system load
            backlog_vec = np.zeros(D)
            for msg in cluster.backlog_head(64):
                backlog_vec = backlog_vec + estimate(msg.image).values
            ideal[n] = int(max(
                math.ceil(busy_vec[j] + (backlog_vec[j]
                                         if backlog_vec[j] < 64.0 else 64.0))
                for j in range(D)
            ))
            n += 1
        else:
            n_active = len(cluster._active_idx)
            n_pes = 0
            busy_load = 0.0
            for widx in cluster._active_idx:
                pes = workers[widx].pes
                n_pes += len(pes)
                for pe in pes:
                    busy_load += pe.estimate
            active[n] = n_active
            target[n] = cluster.requested_target
            pe_count[n] = n_pes
            # ideal bins for the *current* in-system load (backlog + busy PEs)
            backlog_load = 0.0
            for msg in cluster.backlog_head(64):
                backlog_load += estimate(msg.image)
            ideal[n] = int(math.ceil(
                busy_load + (backlog_load if backlog_load < 64.0 else 64.0)
            ))
            n += 1

        done = len(cluster.completed)
        if done >= total and next_batch >= n_batches and cluster._qlen == 0:
            break
        t = round(t + cfg.dt, 9)

    return SimResult(
        times=times[:n].copy(),
        measured_cpu=measured[:n].copy(),
        scheduled_cpu=scheduled[:n].copy(),
        queue_len=qlen[:n].copy(),
        active_workers=active[:n].copy(),
        target_workers=target[:n].copy(),
        ideal_bins=ideal[:n].copy(),
        pe_count=pe_count[:n].copy(),
        completed=len(cluster.completed),
        total=total,
        makespan=cluster.max_done_t,
        messages=[m for _, b in stream.batches for m in b],
        resource_dims=dims,
        measured_res=measured_res[:n].copy() if multi else None,
        scheduled_res=scheduled_res[:n].copy() if multi else None,
        requeued=cluster.requeued,
    )
