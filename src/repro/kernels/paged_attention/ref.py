"""Pure-jnp oracle for paged decode attention.

Gathers each sequence's pages into a dense KV view and runs masked decode
attention — the semantics the Pallas kernel must reproduce exactly.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["paged_attention_ref", "gather_pages"]


def gather_pages(
    pool: jax.Array,        # (num_pages, page_size, KVH, D)
    page_table: jax.Array,  # (B, max_pages) int32, -1 = unused
) -> jax.Array:
    """Dense (B, max_pages * page_size, KVH, D) view of the paged cache.

    Unused table slots (-1) gather page 0; the caller masks by seq_lens, so
    the garbage never contributes.
    """
    idx = jnp.maximum(page_table, 0)                       # (B, P)
    gathered = pool[idx]                                   # (B, P, ps, KVH, D)
    B, P, ps, KVH, D = gathered.shape
    return gathered.reshape(B, P * ps, KVH, D)


def paged_attention_ref(
    q: jax.Array,           # (B, H, D) one query token per sequence
    k_pool: jax.Array,      # (num_pages, page_size, KVH, D)
    v_pool: jax.Array,      # (num_pages, page_size, KVH, D)
    page_table: jax.Array,  # (B, max_pages) int32, -1 = unused
    seq_lens: jax.Array,    # (B,) valid tokens per sequence
) -> jax.Array:
    B, H, D = q.shape
    KVH = k_pool.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)

    k = gather_pages(k_pool, page_table).astype(jnp.float32)  # (B, S, KVH, D)
    v = gather_pages(v_pool, page_table).astype(jnp.float32)
    S = k.shape[1]

    qf = q.reshape(B, KVH, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k) * scale           # (B, KVH, G, S)
    valid = jnp.arange(S)[None, :] < seq_lens[:, None]          # (B, S)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v)
    return out.reshape(B, H, D).astype(q.dtype)
