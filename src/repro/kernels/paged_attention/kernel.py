"""Pallas TPU kernel: decode attention over a First-Fit paged KV cache.

The serving-side compute hot-spot of the paper's technique: the page
allocator (``serving/kv_cache.py``) packs sequences into fixed-size HBM
pages (bins); this kernel attends one query token per sequence against its
scattered pages without ever materializing a dense cache.

TPU-native structure:
  - the *page table* and *sequence lengths* are scalar-prefetched
    (``PrefetchScalarGridSpec``) so the BlockSpec index maps can chase the
    page indirection: the K/V block for grid step (b, h, i) is DMA'd from
    HBM page ``page_table[b, i]`` while the previous block computes —
    the TPU version of vLLM's gather;
  - grid = (B, KVH, max_pages); the page loop is the minor (sequential)
    dimension, so the online-softmax state (m, l, acc) for the G = H/KVH
    grouped query heads lives in VMEM scratch across the sweep;
  - GQA is exploited, not repeated: all G query heads of one KV head are
    processed together as a (G, D) x (D, page_size) MXU matmul;
  - pages past ``ceil(seq_len / page_size)`` are skipped entirely
    (``pl.when``): compute is proportional to the *occupied* bins, exactly
    like the IRM's workers.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_decode_attention"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _paged_attn_kernel(
    page_table_ref,  # scalar-prefetch (B, max_pages) int32
    seq_lens_ref,    # scalar-prefetch (B,) int32
    q_ref,           # (1, 1, G, D)
    k_ref,           # (1, page_size, 1, D)  page pt[b, i]
    v_ref,           # (1, page_size, 1, D)
    o_ref,           # (1, 1, G, D)
    m_ref,           # VMEM (G,) f32
    l_ref,           # VMEM (G,) f32
    acc_ref,         # VMEM (G, D) f32
    *,
    page_size: int,
    n_pages: int,
    scale: float,
):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = seq_lens_ref[b]
    # occupied-bin skip: pages at or past ceil(seq_len / page_size) hold no
    # valid tokens for this sequence
    in_use = (i * page_size) < seq_len

    @pl.when(in_use)
    def _compute():
        q = q_ref[0, 0]        # (G, D)
        k = k_ref[0, :, 0]     # (page_size, D)
        v = v_ref[0, :, 0]     # (page_size, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale              # (G, page_size)

        token_pos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1
        )
        mask = token_pos < seq_len  # (1, page_size)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q: jax.Array,           # (B, H, D) one query token per sequence
    k_pool: jax.Array,      # (num_pages, page_size, KVH, D)
    v_pool: jax.Array,      # (num_pages, page_size, KVH, D)
    page_table: jax.Array,  # (B, max_pages) int32, -1 = unused slot
    seq_lens: jax.Array,    # (B,) int32
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    num_pages, page_size, KVH, _ = k_pool.shape
    G = H // KVH
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(D)

    # unused slots (-1) index page 0; masked out via seq_lens
    table = jnp.maximum(page_table, 0).astype(jnp.int32)
    q_g = q.reshape(B, KVH, G, D)

    kernel = functools.partial(
        _paged_attn_kernel,
        page_size=page_size,
        n_pages=max_pages,
        scale=scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, i, pt, sl: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, page_size, 1, D),
                lambda b, h, i, pt, sl: (pt[b, i], 0, h, 0),
            ),
            pl.BlockSpec(
                (1, page_size, 1, D),
                lambda b, h, i, pt, sl: (pt[b, i], 0, h, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, D), lambda b, h, i, pt, sl: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(table, seq_lens.astype(jnp.int32), q_g, k_pool, v_pool)
    return out.reshape(B, H, D)
