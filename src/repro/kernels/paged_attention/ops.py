"""Jit'd public wrapper for paged decode attention.

Bridges the host-side ``PageAllocator`` (First-Fit page tables as numpy) and
the device kernel, and dispatches kernel vs interpret vs jnp-reference.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp

from ...serving.kv_cache import PageAllocator
from .kernel import paged_decode_attention
from .ref import paged_attention_ref

__all__ = ["paged_attention", "page_table_from_allocator"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def page_table_from_allocator(
    allocator: PageAllocator, seq_ids: List[int]
) -> tuple:
    """(page_table, seq_lens) device arrays for the active sequences."""
    table = jnp.asarray(allocator.page_table(seq_ids), jnp.int32)
    lens = jnp.asarray(
        [allocator.seq_len(s) for s in seq_ids], jnp.int32
    )
    return table, lens


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_attention(
    q: jax.Array,           # (B, H, D)
    k_pool: jax.Array,      # (num_pages, page_size, KVH, D)
    v_pool: jax.Array,      # (num_pages, page_size, KVH, D)
    page_table: jax.Array,  # (B, max_pages) int32, -1 = unused
    seq_lens: jax.Array,    # (B,)
    *,
    use_kernel: bool = True,
    interpret: bool = False,
) -> jax.Array:
    if use_kernel:
        return paged_decode_attention(
            q, k_pool, v_pool, page_table, seq_lens,
            interpret=interpret or not _on_tpu(),
        )
    return paged_attention_ref(q, k_pool, v_pool, page_table, seq_lens)
