"""Jit'd public wrapper for the grouped matmul: dispatches kernel (TPU),
interpret (CPU validation), or jnp reference, and provides the fused SwiGLU
expert-FFN built from three grouped GEMMs."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import grouped_matmul
from .ref import grouped_matmul_ref

__all__ = ["gmm", "expert_ffn_swiglu"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def gmm(
    x: jax.Array,            # (E, C, d)
    w: jax.Array,            # (E, d, f)
    group_sizes: jax.Array,  # (E,)
    *,
    use_kernel: bool = True,
    interpret: bool = False,
) -> jax.Array:
    if use_kernel:
        return grouped_matmul(
            x, w, group_sizes, interpret=interpret or not _on_tpu()
        )
    return grouped_matmul_ref(x, w, group_sizes)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def expert_ffn_swiglu(
    x: jax.Array,            # (E, C, d) capacity-packed tokens
    w_gate: jax.Array,       # (E, d, f)
    w_up: jax.Array,         # (E, d, f)
    w_down: jax.Array,       # (E, f, d)
    group_sizes: jax.Array,  # (E,)
    *,
    use_kernel: bool = True,
    interpret: bool = False,
) -> jax.Array:
    kw = dict(use_kernel=use_kernel, interpret=interpret)
    h = jax.nn.silu(gmm(x, w_gate, group_sizes, **kw)) * gmm(
        x, w_up, group_sizes, **kw
    )
    return gmm(h, w_down, group_sizes, **kw)
