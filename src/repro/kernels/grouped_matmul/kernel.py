"""Pallas TPU kernel: expert-blocked grouped matmul over capacity bins.

The MoE dispatch (``models/moe.py``) packs routed tokens into per-expert
capacity bins — the paper's bin-packing applied to experts.  The expert FFN
is then E independent GEMMs ``(C, d) @ (d, f)`` whose *occupied* row count
varies per expert (``group_sizes``).  This kernel:

  - tiles each expert GEMM into MXU-aligned (block_c x block_d x block_f)
    VMEM blocks; the contraction (d) loop is the minor grid dimension so the
    fp32 accumulator tile lives in VMEM scratch across it;
  - scalar-prefetches ``group_sizes`` and *skips every block* whose row
    range lies past the expert's occupancy (``pl.when``) — compute scales
    with the bins' fill level, not their capacity, exactly like the IRM's
    workers (an empty capacity slot costs nothing);
  - zeroes skipped output tiles so padding rows stay exactly 0 (matching
    the dispatch scatter's zeros and the ref oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_matmul"]


def _gmm_kernel(
    group_sizes_ref,  # scalar-prefetch (E,) int32
    x_ref,            # (1, block_c, block_d)
    w_ref,            # (1, block_d, block_f)
    o_ref,            # (1, block_c, block_f)
    acc_ref,          # VMEM (block_c, block_f) f32
    *,
    block_c: int,
    n_d: int,
):
    e = pl.program_id(0)
    ic = pl.program_id(1)
    kd = pl.program_id(3)

    occupied = (ic * block_c) < group_sizes_ref[e]

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occupied)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(kd == n_d - 1)
    def _finalize():
        # zero rows past the expert's occupancy (partial last block)
        rows = ic * block_c + jax.lax.broadcasted_iota(
            jnp.int32, (block_c, 1), 0
        )
        valid = rows < group_sizes_ref[e]
        o_ref[0] = jnp.where(valid, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_d", "block_f", "interpret"),
)
def grouped_matmul(
    x: jax.Array,            # (E, C, d)
    w: jax.Array,            # (E, d, f)
    group_sizes: jax.Array,  # (E,) int32
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    E, C, d = x.shape
    f = w.shape[2]
    block_c = min(block_c, C)
    block_d = min(block_d, d)
    block_f = min(block_f, f)
    if C % block_c or d % block_d or f % block_f:
        raise ValueError(
            f"(C={C}, d={d}, f={f}) must be divisible by blocks "
            f"({block_c}, {block_d}, {block_f})"
        )
    n_c, n_d, n_f = C // block_c, d // block_d, f // block_f

    kernel = functools.partial(_gmm_kernel, block_c=block_c, n_d=n_d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # contraction (d) minor so the accumulator survives across it
        grid=(E, n_c, n_f, n_d),
        in_specs=[
            pl.BlockSpec(
                (1, block_c, block_d), lambda e, ic, jf, kd, gs: (e, ic, kd)
            ),
            pl.BlockSpec(
                (1, block_d, block_f), lambda e, ic, jf, kd, gs: (e, kd, jf)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_c, block_f), lambda e, ic, jf, kd, gs: (e, ic, jf)
        ),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, w)
