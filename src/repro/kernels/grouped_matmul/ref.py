"""Pure-jnp oracle for the expert-blocked grouped matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["grouped_matmul_ref"]


def grouped_matmul_ref(
    x: jax.Array,            # (E, C, d) capacity-packed expert inputs
    w: jax.Array,            # (E, d, f) per-expert weights
    group_sizes: jax.Array,  # (E,) valid rows per expert bin
) -> jax.Array:
    """Per-expert GEMM over the occupied prefix of each capacity bin.

    Rows at or past ``group_sizes[e]`` are padding (zeros from the dispatch
    scatter); the oracle zeroes them explicitly so the kernel's block-skip
    behaviour is pinned down exactly.
    """
    E, C, d = x.shape
    out = jnp.einsum(
        "ecd,edf->ecf",
        x.astype(jnp.float32),
        w.astype(jnp.float32),
    )
    valid = jnp.arange(C)[None, :] < group_sizes[:, None]  # (E, C)
    return jnp.where(valid[..., None], out, 0.0).astype(x.dtype)
