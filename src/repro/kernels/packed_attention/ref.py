"""Pure-jnp oracle for the packed flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["packed_attention_ref"]


def packed_attention_ref(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, H, Skv, D)
    v: jax.Array,            # (B, H, Skv, D)
    segment_ids_q: jax.Array,   # (B, Sq)
    segment_ids_kv: jax.Array,  # (B, Skv)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32),
        k.astype(jnp.float32),
    ) * scale
    q_ids = jnp.arange(Sq)[:, None]
    kv_ids = jnp.arange(Skv)[None, :]
    mask = (segment_ids_q[:, :, None] == segment_ids_kv[:, None, :]) & (
        segment_ids_kv[:, None, :] != 0
    )
    if causal:
        mask &= (q_ids >= kv_ids)[None]
    if window > 0:
        mask &= (q_ids - kv_ids < window)[None]
    s = jnp.where(mask[:, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> zero output
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
