"""Pallas TPU kernel: flash attention with segment-ID masking.

The compute hot-spot of First-Fit-packed training batches: causal attention
that must not cross the segment boundaries the packer created.  Standard
flash-attention structure adapted to the TPU memory hierarchy:

  - grid (B, H, n_q, n_kv); the minor (last) grid dim executes sequentially
    on a TensorCore, so the online-softmax state (m, l, acc) lives in VMEM
    scratch and survives across the kv sweep;
  - Q/K/V tiles are (block_q x head_dim) / (block_kv x head_dim) VMEM blocks
    with head_dim the 128-lane minor dimension (MXU-aligned);
  - logits/softmax accumulate in fp32 on the MXU (bf16 operands);
  - *block skipping*: a (q, kv) tile pair is skipped entirely when causality
    excludes it (kv block strictly above the diagonal).  Segment masking is
    applied within surviving tiles; fully-masked tiles contribute zero
    through the mask (exp(-inf) = 0) without corrupting the running max.

The packing-aware mask is what ties this kernel to the paper: bins = rows,
items = documents, and the kernel is what makes a packed row compute at the
same cost as a dense row (98%+ of tokens are real — see
benchmarks/packing_throughput.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["packed_flash_attention"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _attn_kernel(
    seg_q_ref,   # (1, block_q) int32
    seg_kv_ref,  # (1, block_kv) int32
    q_ref,       # (1, 1, block_q, D)
    k_ref,       # (1, 1, block_kv, D)
    v_ref,       # (1, 1, block_kv, D)
    o_ref,       # (1, 1, block_q, D)
    m_ref,       # VMEM (block_q,) f32
    l_ref,       # VMEM (block_q,) f32
    acc_ref,     # VMEM (block_q, D) f32
    *,
    causal: bool,
    window: int,
    block_q: int,
    block_kv: int,
    n_kv: int,
    scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv

    # block-level skip: strictly-above-diagonal kv blocks never contribute
    run = True
    if causal:
        run = kv_start <= q_start + block_q - 1
    if window > 0:
        run = jnp.logical_and(run, q_start - (kv_start + block_kv - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]  # (bq, D)
        k = k_ref[0, 0]  # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)

        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        seg_q = seg_q_ref[0][:, None]   # (bq, 1)
        seg_kv = seg_kv_ref[0][None, :]  # (1, bk)
        mask = jnp.logical_and(seg_q == seg_kv, seg_kv != 0)
        if causal:
            mask = jnp.logical_and(mask, q_ids >= kv_ids)
        if window > 0:
            mask = jnp.logical_and(mask, q_ids - kv_ids < window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        # fully-masked rows: s == m_new == NEG_INF would give p = 1
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def packed_flash_attention(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, H, Skv, D)  (KV heads pre-repeated)
    v: jax.Array,            # (B, H, Skv, D)
    segment_ids_q: jax.Array,   # (B, Sq) int32, 0 = padding
    segment_ids_kv: jax.Array,  # (B, Skv)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    if Sq % block_q or Skv % block_kv:
        raise ValueError("sequence lengths must be multiples of the block sizes")
    n_q = Sq // block_q
    n_kv = Skv // block_kv
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        n_kv=n_kv,
        scale=scale,
    )
    grid = (B, H, n_q, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_kv), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(segment_ids_q, segment_ids_kv, q, k, v)
