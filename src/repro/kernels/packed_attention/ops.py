"""Jit'd public wrapper for packed attention.

Accepts model-layout tensors (B, S, H, D) with separate KV heads, handles
GQA repetition and layout transposes, and dispatches to the Pallas kernel on
TPU or to its interpret-mode execution elsewhere (CPU tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import packed_flash_attention
from .ref import packed_attention_ref

__all__ = ["packed_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "use_kernel", "interpret")
)
def packed_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, KVH, D)
    v: jax.Array,            # (B, Skv, KVH, D)
    segment_ids_q: jax.Array,
    segment_ids_kv: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    use_kernel: bool = True,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    kf = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vf = jnp.repeat(v, rep, axis=2) if rep > 1 else v

    # pad sequences to block multiples; segment id 0 masks the padding
    block = 256
    pq = (-Sq) % min(block, Sq) if Sq >= block else (-Sq) % 128
    pkv_len = kf.shape[1]
    pkv = (-pkv_len) % min(block, pkv_len) if pkv_len >= block else (-pkv_len) % 128

    def pad_seq(x, p):
        if p == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[1] = (0, p)
        return jnp.pad(x, widths)

    qp, sp_q = pad_seq(q, pq), pad_seq(segment_ids_q, pq)
    kp, vp, sp_kv = pad_seq(kf, pkv), pad_seq(vf, pkv), pad_seq(segment_ids_kv, pkv)

    qt = qp.transpose(0, 2, 1, 3)
    kt = kp.transpose(0, 2, 1, 3)
    vt = vp.transpose(0, 2, 1, 3)
    if use_kernel:
        out = packed_flash_attention(
            qt, kt, vt, sp_q, sp_kv,
            causal=causal, window=window,
            interpret=interpret or not _on_tpu(),
        )
    else:
        out = packed_attention_ref(
            qt, kt, vt, sp_q, sp_kv, causal=causal, window=window,
        )
    return out.transpose(0, 2, 1, 3)[:, :Sq]
