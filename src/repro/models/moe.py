"""Mixture-of-Experts layer with capacity-binned dispatch.

The dispatch is the paper's technique applied at the token level: experts are
*bins* with a fixed capacity (``capacity_factor * tokens * top_k / E`` slots,
rounded up to an MXU-aligned multiple of 128), and routed tokens are *items*
packed into them.  Tokens that overflow an expert's bin are dropped
(GShard-style), exactly like a worker that cannot fit another PE.

Mechanically the dispatch is sort-based (Megablocks-style): flatten (token,
expert) assignments, sort by expert, compute each token's position within its
expert's bin by cumulative count, scatter into an (E, C, d) buffer, run the
expert FFNs as a batched einsum (or the ``kernels/grouped_matmul`` Pallas
kernel on TPU), and combine back with router weights.  Under pjit the (E, C,
d) buffer is sharded on the expert axis (EP) when E divides the model axis,
otherwise on d_ff (expert-internal TP) — see ``distributed/sharding.py``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import constrain
from .params import Spec

__all__ = ["moe_specs", "moe_layer", "expert_capacity"]


def expert_capacity(
    num_tokens: int, num_experts: int, top_k: int, factor: float,
    align: int = 128,
) -> int:
    """Capacity per expert bin, rounded up to an ``align`` multiple.

    The Pallas grouped-matmul path needs 128-aligned bins (MXU tiles); the
    SPMD einsum path only needs sublane alignment (8), which cuts the
    capacity padding — and with it the wasted expert FLOPs — by up to 17%
    at the assigned configs (EXPERIMENTS.md §Perf).
    """
    raw = int(math.ceil(num_tokens * top_k * factor / num_experts))
    return max(align, ((raw + align - 1) // align) * align)


def _top_k_iterative(probs: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Partition-friendly top-k over the last dim.

    ``jax.lax.top_k`` lowers to a TopK custom-call that the SPMD
    partitioner cannot partition — it all-gathers the full router
    probabilities to every device (measured: 2 x 26 GB/device/step on
    qwen3-moe train_4k).  K passes of argmax+mask partition cleanly and
    cost K*E flops per token — noise next to the expert GEMMs.
    """
    E = probs.shape[-1]
    masked = probs
    vals, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(masked, axis=-1)
        one_hot = jax.nn.one_hot(i, E, dtype=jnp.bool_)
        v = jnp.sum(jnp.where(one_hot, masked, 0.0), axis=-1)
        vals.append(v)
        idxs.append(i.astype(jnp.int32))
        masked = jnp.where(one_hot, -jnp.inf, masked)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_specs(cfg: Any) -> Dict[str, Spec]:
    assert cfg.moe is not None
    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.expert_d_ff
    specs = {
        "router": Spec((d, e), ("embed", None), init="scaled"),
        "w_up": Spec((e, d, f), ("experts", "embed", "mlp"), init="scaled"),
        "w_down": Spec((e, f, d), ("experts", "mlp", "embed"), init="scaled"),
    }
    if cfg.act == "swiglu":
        specs["w_gate"] = Spec(
            (e, d, f), ("experts", "embed", "mlp"), init="scaled"
        )
    return specs


def moe_layer(
    p: Dict[str, jax.Array],
    cfg: Any,
    x: jax.Array,  # (B, S, d)
    *,
    use_gmm_kernel: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Top-k routed MoE with capacity bins.  Returns (out, aux_losses).

    Dispatch is *group-local*: tokens are reshaped into G groups along the
    batch dim, where G is exactly the number of batch shards of the active
    mesh layout (``batch_shard_count``; G=1 on a single device).  All
    dispatch state — router sort, bin positions, the capacity-bin scatter
    and the combine scatter-add — then lives entirely within one shard, so
    the SPMD partitioner emits ZERO collectives for it.  Capacity is
    enforced per group (exactly what a real distributed EP system does:
    each host drops its own overflow).  Measured on qwen3-moe train_4k at
    16x16: global dispatch moved 47 TB/device/step; group-local moves
    none (EXPERIMENTS.md §Perf).
    """
    from ..distributed.context import batch_shard_count

    mcfg = cfg.moe
    B, S, d = x.shape
    E, K = mcfg.num_experts, mcfg.top_k
    G = batch_shard_count(B)
    Tg = (B // G) * S
    kernel_path = (use_gmm_kernel and cfg.act == "swiglu" and G == 1
                   and jax.default_backend() == "tpu")
    # 128-aligned bins only for the Pallas grouped-matmul; the SPMD einsum
    # path packs tighter (8-aligned), cutting capacity-padding flops
    C = expert_capacity(Tg, E, K, mcfg.capacity_factor,
                        align=128 if kernel_path else 8)

    xg = constrain(x.reshape(G, Tg, d), ("batch", None, None))

    def dispatch(xt: jax.Array):
        """One group's routing + capacity-bin packing.  xt: (Tg, d)."""
        logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = _top_k_iterative(probs, K)  # (Tg, K)
        # renormalize the selected gates (Mixtral/Qwen convention)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9
        )

        flat_expert = expert_idx.reshape(-1)          # (Tg*K,)
        order = jnp.argsort(flat_expert)              # sort by destination
        sorted_expert = flat_expert[order]
        sorted_token = (order // K).astype(jnp.int32)

        one_pos = jnp.arange(Tg * K, dtype=jnp.int32)
        counts = jnp.bincount(sorted_expert, length=E)            # (E,)
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(counts)[:-1].astype(jnp.int32)]
        )
        pos_in_expert = one_pos - starts[sorted_expert]
        keep = pos_in_expert < C                      # bin overflow -> drop

        dest = jnp.where(keep, sorted_expert * C + pos_in_expert, E * C)
        buf = jnp.zeros((E * C + 1, d), x.dtype)
        buf = buf.at[dest].set(xt[sorted_token])
        buf = buf[: E * C].reshape(E, C, d)

        gates_sorted = gate_vals.reshape(-1)[order]
        aux = (counts, probs.mean(axis=0),
               jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))))
        return buf, dest, keep, sorted_token, gates_sorted, aux

    (buf, dest, keep, sorted_token, gates_sorted,
     (counts, mean_prob, z_loss_g)) = jax.vmap(dispatch)(xg)
    # buf: (G, E, C, d) — group over the batch axes, experts over model (EP)
    buf = constrain(buf, ("batch", "experts", None, None))

    # ---- expert FFN (batched over the expert axis; EP-shardable) ----------
    # On single-device TPU execution the grouped-GEMM Pallas kernel
    # (kernels/grouped_matmul) skips unoccupied capacity blocks — compute
    # scales with bin fill, not capacity.  Under pjit/SPMD (and on CPU) the
    # einsum form lets XLA partition over the expert axis.
    if kernel_path:
        from ..kernels.grouped_matmul.ops import expert_ffn_swiglu

        out_buf = expert_ffn_swiglu(
            buf[0], p["w_gate"], p["w_up"], p["w_down"],
            jnp.minimum(counts[0], C).astype(jnp.int32),
        )[None]
    else:
        if cfg.act == "swiglu":
            h = jax.nn.silu(
                jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
            ) * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
        else:
            h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, p["w_up"]))
        h = constrain(h, ("batch", "experts", None, "mlp"))
        out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])  # (G,E,C,d)
    out_buf = constrain(out_buf, ("batch", "experts", None, None))

    # ---- combine: gather expert outputs back to tokens (group-local) -------
    def combine(out_buf_g, dest_g, keep_g, sorted_token_g, gates_g):
        out_flat = out_buf_g.reshape(E * C, d)
        gathered = jnp.where(
            keep_g[:, None], out_flat[jnp.where(keep_g, dest_g, 0)], 0.0
        )  # (Tg*K, d)
        contrib = gathered * gates_g[:, None].astype(gathered.dtype)
        return jnp.zeros((Tg, d), x.dtype).at[sorted_token_g].add(contrib)

    out = jax.vmap(combine)(out_buf, dest, keep, sorted_token, gates_sorted)
    out = constrain(out, ("batch", None, None))

    # ---- aux losses ---------------------------------------------------------
    # Switch-style load balance: E * sum_e (fraction_e * prob_e), averaged
    # over groups (== the global statistic when groups are equal-sized)
    frac = counts.astype(jnp.float32) / jnp.maximum(1, Tg * K)  # (G, E)
    lb_loss = E * jnp.mean(jnp.sum(frac * mean_prob, axis=-1))
    z_loss = jnp.mean(z_loss_g)
    dropped = jnp.sum(~keep) / jnp.maximum(1, G * Tg * K)
    aux = {
        "moe_load_balance": lb_loss * mcfg.load_balance_loss,
        "moe_z_loss": z_loss * mcfg.router_z_loss,
        "moe_drop_fraction": dropped,
    }
    return out.reshape(B, S, d), aux
