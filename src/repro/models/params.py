"""Minimal functional parameter system.

Models declare parameters as ``Spec`` trees (shape + dtype + *logical axis
names* + initializer).  From one spec tree we derive:

  - ``init_params``      — materialized arrays (smoke tests, real training),
  - ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins for the
                           multi-pod dry-run (never allocates),
  - ``logical_axes``     — pytree of axis-name tuples consumed by
                           ``distributed/sharding.py`` to build
                           ``NamedSharding``s from the mesh rules.

No flax/haiku dependency: params are plain nested dicts of arrays, models are
pure functions — the natural fit for ``jax.jit`` + ``lax.scan`` over stacked
layers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Spec", "init_params", "abstract_params", "logical_axes", "tree_bytes"]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"spec shape {self.shape} and axes {self.axes} rank mismatch"
            )


def _init_one(key: jax.Array, spec: Spec, dtype: Any) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(dtype)
    if spec.init == "scaled":  # fan-in scaled (truncated-normal-ish)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(1, fan_in))
        return (std * jax.random.normal(key, spec.shape)).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _is_spec(x: Any) -> bool:
    return isinstance(x, Spec)


def init_params(
    specs: Pytree, key: jax.Array, dtype: Optional[Any] = None
) -> Pytree:
    """Materialize a spec tree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    arrays = [
        _init_one(k, s, dtype or s.dtype) for k, s in zip(keys, leaves, strict=True)
    ]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(specs: Pytree, dtype: Optional[Any] = None) -> Pytree:
    """ShapeDtypeStruct stand-ins — the dry-run path, zero allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        specs,
        is_leaf=_is_spec,
    )


def logical_axes(specs: Pytree) -> Pytree:
    """Pytree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def tree_bytes(tree: Pytree) -> int:
    """Total bytes of a tree of arrays or ShapeDtypeStructs."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
