"""Memory-bounded sequential scans for recurrent layers (Mamba / xLSTM).

``chunked_scan`` runs ``lax.scan`` over time in chunks, with each chunk body
wrapped in ``jax.checkpoint``: the forward only keeps chunk-boundary carries,
and the backward recomputes within-chunk states.  This bounds training-time
memory at O(L/chunk * carry + chunk * step_residuals) instead of
O(L * step_residuals) — the standard way to make sequence-recurrent layers
trainable at 4k+ context without a fused kernel.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["chunked_scan"]


def chunked_scan(
    step: Callable[[Any, Any], Tuple[Any, Any]],
    init: Any,
    xs: Any,
    *,
    chunk_size: int = 128,
) -> Tuple[Any, Any]:
    """Equivalent to ``lax.scan(step, init, xs)`` with chunked remat.

    ``xs`` leaves must share the leading (time) dimension.  The time axis is
    padded to a chunk multiple; padded steps still run but their outputs are
    trimmed (recurrences here are safe to run on zero inputs — gates of zero
    inputs keep the carry finite).
    """
    leaves = jax.tree.leaves(xs)
    if not leaves:
        raise ValueError("chunked_scan needs at least one xs leaf")
    L = leaves[0].shape[0]
    c = min(chunk_size, L)
    pad = (-L) % c
    n_chunks = (L + pad) // c

    def pad_reshape(x: jax.Array) -> jax.Array:
        if pad:
            widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
            x = jnp.pad(x, widths)
        return x.reshape((n_chunks, c) + x.shape[1:])

    xs_c = jax.tree.map(pad_reshape, xs)

    @jax.checkpoint
    def chunk_body(carry: Any, xc: Any) -> Tuple[Any, Any]:
        return lax.scan(step, carry, xc)

    carry, ys = lax.scan(chunk_body, init, xs_c)

    def unshape(y: jax.Array) -> jax.Array:
        y = y.reshape((n_chunks * c,) + y.shape[2:])
        return y[:L]

    return carry, jax.tree.map(unshape, ys)
