"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Follows the xLSTM paper's exponential-gating formulation with the max-state
stabilizer.  Both are sequence-recurrent and run through the chunked,
remat-bounded scan (``scan_utils.chunked_scan``); the mLSTM's per-head state
is a (dh x dh) matrix (linear-attention form), the sLSTM's a per-unit scalar
triple.  Decode carries the states — O(1) per token, which makes the xlstm
arch eligible for long_500k.

Block structure (xLSTM paper Fig. 9/10, simplified):
  mLSTM block: LN -> up-proj (2x) -> [path: causal conv -> silu -> q,k;  v]
               -> mLSTM -> headwise RMS norm -> (* silu(gate)) -> down-proj
  sLSTM block: LN -> sLSTM (recurrent gates with per-head hidden feedback)
               -> headwise RMS norm -> gated FFN (factor 4/3)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.context import constrain
from .layers import rms_norm
from .params import Spec
from .scan_utils import chunked_scan
from .ssm import _causal_depthwise_conv

__all__ = [
    "mlstm_specs",
    "mlstm_forward",
    "mlstm_decode_step",
    "mlstm_init_state",
    "slstm_specs",
    "slstm_forward",
    "slstm_decode_step",
    "slstm_init_state",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: Any) -> Tuple[int, int, int]:
    du = int(cfg.xlstm.m_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dh = du // H
    return du, H, dh


def mlstm_specs(cfg: Any) -> Dict[str, Spec]:
    d = cfg.d_model
    du, H, dh = _mlstm_dims(cfg)
    k = cfg.xlstm.conv_kernel
    return {
        "up": Spec((d, 2 * du), ("embed", "mlp"), init="scaled"),
        "conv_w": Spec((k, du), (None, "mlp"), init="scaled"),
        "conv_b": Spec((du,), ("mlp",), init="zeros"),
        "wq": Spec((du, H, dh), ("mlp", "heads", "head_dim"), init="scaled"),
        "wk": Spec((du, H, dh), ("mlp", "heads", "head_dim"), init="scaled"),
        "wv": Spec((du, H, dh), ("mlp", "heads", "head_dim"), init="scaled"),
        "wi": Spec((du, H), ("mlp", "heads"), init="scaled"),
        "wf": Spec((du, H), ("mlp", "heads"), init="scaled"),
        "bi": Spec((H,), ("heads",), init="zeros"),
        "bf": Spec((H,), ("heads",), init="ones"),  # bias toward remembering
        "out_norm": Spec((dh,), ("head_dim",), init="zeros"),
        "down": Spec((du, d), ("mlp", "embed"), init="scaled"),
    }


def _mlstm_scan(
    q: jax.Array,  # (B, S, H, dh)
    k: jax.Array,
    v: jax.Array,
    ig: jax.Array,  # (B, S, H) raw input-gate logits
    fg: jax.Array,  # (B, S, H) raw forget-gate logits
    state: Dict[str, jax.Array],
    chunk_size: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, H, dh = q.shape
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        q_t, k_t, v_t, i_t, f_t = xs
        logf = -jax.nn.softplus(-f_t)  # log sigmoid
        m_new = jnp.maximum(logf + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(logf + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :] * scale
        )
        n = f_p[..., None] * n + i_p[..., None] * k_t * scale
        num = jnp.einsum("bhij,bhj->bhi", C, q_t)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhj,bhj->bh", n, q_t)), jnp.exp(-m_new)
        )
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(
        jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (q, k, v, ig, fg)
    )
    carry, hs = chunked_scan(
        step, (state["C"], state["n"], state["m"]), xs, chunk_size=chunk_size
    )
    C, n, m = carry
    return jnp.moveaxis(hs, 0, 1), {"C": C, "n": n, "m": m}  # (B, S, H, dh)


def mlstm_forward(
    p: Dict[str, jax.Array],
    cfg: Any,
    x: jax.Array,
    *,
    state: Dict[str, jax.Array] = None,
    chunk_size: int = 128,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, _ = x.shape
    du, H, dh = _mlstm_dims(cfg)
    up = constrain(x @ p["up"], ("batch", None, "mlp"))
    xm, z = jnp.split(up, 2, axis=-1)  # (B, S, du)
    if state is None:
        state = mlstm_init_state(cfg, B)
        conv_in = xm
        trim = 0
    else:
        conv_in = jnp.concatenate([state["conv"].astype(xm.dtype), xm], axis=1)
        trim = state["conv"].shape[1]
    c = _causal_depthwise_conv(conv_in, p["conv_w"], p["conv_b"])[:, trim:]
    c = jax.nn.silu(c)

    q = jnp.einsum("bsd,dhk->bshk", c, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", c, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xm, p["wv"])
    ig = jnp.einsum("bsd,dh->bsh", c, p["wi"]) + p["bi"]
    fg = jnp.einsum("bsd,dh->bsh", c, p["wf"]) + p["bf"]

    h, new_inner = _mlstm_scan(
        q, k, v, ig, fg,
        {"C": state["C"], "n": state["n"], "m": state["m"]},
        chunk_size,
    )
    h = rms_norm(h, p["out_norm"]).reshape(B, S, du).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ p["down"]
    kk = cfg.xlstm.conv_kernel - 1
    conv_tail = (
        xm[:, -kk:]
        if S >= kk
        else jnp.concatenate([state["conv"][:, S - kk:].astype(xm.dtype), xm], 1)
    )
    new_state = dict(new_inner, conv=conv_tail.astype(jnp.float32))
    return out, new_state


def mlstm_decode_step(
    p: Dict[str, jax.Array], cfg: Any, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    return mlstm_forward(p, cfg, x, state=state, chunk_size=1)


def mlstm_init_state(cfg: Any, batch: int) -> Dict[str, jax.Array]:
    du, H, dh = _mlstm_dims(cfg)
    kk = cfg.xlstm.conv_kernel - 1
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, kk, du), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_specs(cfg: Any) -> Dict[str, Spec]:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dff = int(cfg.xlstm.s_proj_factor * d)
    return {
        "wx": Spec((d, 4, H, dh), ("embed", None, "heads", "head_dim"), init="scaled"),
        "wr": Spec((4, H, dh, dh), (None, "heads", "head_dim", None), init="scaled"),
        "b": Spec((4, H, dh), (None, "heads", "head_dim"), init="zeros"),
        "out_norm": Spec((dh,), ("head_dim",), init="zeros"),
        "ffn_gate": Spec((d, dff), ("embed", "mlp"), init="scaled"),
        "ffn_up": Spec((d, dff), ("embed", "mlp"), init="scaled"),
        "ffn_down": Spec((dff, d), ("mlp", "embed"), init="scaled"),
    }


def _slstm_scan(
    gx: jax.Array,  # (B, S, 4, H, dh) input contributions to i,f,z,o
    wr: jax.Array,  # (4, H, dh, dh) recurrent weights
    b: jax.Array,   # (4, H, dh)
    state: Dict[str, jax.Array],
    chunk_size: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    def step(carry, x_t):
        c, n, h, m = carry  # each (B, H, dh)
        rec = jnp.einsum("bhj,ghij->bghi", h, wr)  # (B, 4, H, dh)
        g = x_t + rec + b
        i_t, f_t, z_t, o_t = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        logf = -jax.nn.softplus(-f_t)
        m_new = jnp.maximum(logf + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * jnp.tanh(z_t)
        n = f_p * n + i_p
        h_new = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new

    xs = jnp.moveaxis(gx.astype(jnp.float32), 1, 0)
    carry, hs = chunked_scan(
        step,
        (state["c"], state["n"], state["h"], state["m"]),
        xs,
        chunk_size=chunk_size,
    )
    c, n, h, m = carry
    return jnp.moveaxis(hs, 0, 1), {"c": c, "n": n, "h": h, "m": m}


def slstm_forward(
    p: Dict[str, jax.Array],
    cfg: Any,
    x: jax.Array,
    *,
    state: Dict[str, jax.Array] = None,
    chunk_size: int = 128,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, d = x.shape
    H = cfg.n_heads
    if state is None:
        state = slstm_init_state(cfg, B)
    gx = jnp.einsum("bsd,dghk->bsghk", x, p["wx"])  # (B, S, 4, H, dh)
    h, new_state = _slstm_scan(gx, p["wr"], p["b"], state, chunk_size)
    h = rms_norm(h, p["out_norm"]).reshape(B, S, d).astype(x.dtype)
    # gated FFN (projection factor 4/3)
    y = jax.nn.silu(h @ p["ffn_gate"]) * (h @ p["ffn_up"])
    return y @ p["ffn_down"], new_state


def slstm_decode_step(
    p: Dict[str, jax.Array], cfg: Any, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    return slstm_forward(p, cfg, x, state=state, chunk_size=1)


def slstm_init_state(cfg: Any, batch: int) -> Dict[str, jax.Array]:
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}
