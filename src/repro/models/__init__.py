"""Model substrate: layers, MoE, SSM, xLSTM, assembled LMs, registry."""

from .params import Spec, abstract_params, init_params, logical_axes, tree_bytes
from .registry import build_model, cache_specs, input_specs, make_batch
from .transformer import DecoderLM, chunked_cross_entropy, pad_vocab
from .encdec import EncDecLM

__all__ = [
    "Spec",
    "abstract_params",
    "init_params",
    "logical_axes",
    "tree_bytes",
    "build_model",
    "cache_specs",
    "input_specs",
    "make_batch",
    "DecoderLM",
    "EncDecLM",
    "chunked_cross_entropy",
    "pad_vocab",
]
