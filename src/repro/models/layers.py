"""Core transformer layers: norms, RoPE, chunked flash attention, MLP.

The attention here is the pure-jnp *chunked online-softmax* (flash) form:
peak memory is O(chunk^2) instead of O(S^2), it supports the segment-ID
masks produced by the First-Fit sequence packer (``data/packing.py``), GQA,
sliding windows, and decode against a KV cache.  It is the XLA-partitionable
reference path used by the dry-run; ``kernels/packed_attention`` is the
Pallas TPU version validated against it.

Conventions:
  q: (B, S, H, D)   k/v: (B, S, KVH, D)   segment_ids: (B, S) int32, 0 = pad
  positions: (B, S) int32 — *within-segment* positions (used for RoPE);
  causality uses absolute sequence indices, so packed segments stay causal.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.context import constrain
from .params import Spec

__all__ = [
    "rms_norm",
    "layer_norm",
    "norm",
    "norm_specs",
    "rope",
    "attention_specs",
    "attention",
    "decode_attention",
    "mlp_specs",
    "mlp",
    "KVCache",
]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def layer_norm(
    x: jax.Array,
    scale: Optional[jax.Array],
    bias: Optional[jax.Array],
    eps: float = 1e-5,
) -> jax.Array:
    """LayerNorm; with scale=bias=None this is OLMo's non-parametric LN."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def norm_specs(norm_type: str, d: int) -> Dict[str, Spec]:
    if norm_type == "rmsnorm":
        return {"scale": Spec((d,), ("embed",), init="zeros")}
    if norm_type == "layernorm":
        return {
            "scale": Spec((d,), ("embed",), init="ones"),
            "bias": Spec((d,), ("embed",), init="zeros"),
        }
    if norm_type == "layernorm_np":  # non-parametric (OLMo)
        return {}
    raise ValueError(f"unknown norm type {norm_type!r}")


def norm(params: Dict[str, jax.Array], norm_type: str, x: jax.Array) -> jax.Array:
    if norm_type == "rmsnorm":
        return rms_norm(x, params["scale"])
    if norm_type == "layernorm":
        return layer_norm(x, params["scale"], params["bias"])
    if norm_type == "layernorm_np":
        return layer_norm(x, None, None)
    raise ValueError(f"unknown norm type {norm_type!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Apply RoPE.  x: (B, S, H, D), positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (jnp reference; XLA-partitionable)
# ---------------------------------------------------------------------------


def _mask_chunk(
    q_idx: jax.Array,     # (cq,) absolute indices
    kv_idx: jax.Array,    # (ck,)
    seg_q: jax.Array,     # (B, cq)
    seg_kv: jax.Array,    # (B, ck)
    causal: bool,
    window: int,
) -> jax.Array:
    """(B, cq, ck) bool mask: segment match & causality & sliding window."""
    m = (seg_q[:, :, None] == seg_kv[:, None, :]) & (seg_kv[:, None, :] != 0)
    if causal:
        m &= q_idx[None, :, None] >= kv_idx[None, None, :]
    if window > 0:
        m &= (q_idx[None, :, None] - kv_idx[None, None, :]) < window
    return m


def _flash_q_chunk(
    q: jax.Array,        # (B, cq, H, D) fp32 compute
    k: jax.Array,        # (B, S, H, D) (KV heads pre-repeated to H)
    v: jax.Array,        # (B, S, H, D)
    q_idx: jax.Array,    # (cq,)
    seg_q: jax.Array,    # (B, cq)
    seg_kv: jax.Array,   # (B, S)
    *,
    causal: bool,
    window: int,
    chunk_kv: int,
    scale: float,
) -> jax.Array:
    B, cq, H, D = q.shape
    S = k.shape[1]
    n_kv = S // chunk_kv

    k = k.reshape(B, n_kv, chunk_kv, H, D)
    v = v.reshape(B, n_kv, chunk_kv, H, D)
    seg_kv = seg_kv.reshape(B, n_kv, chunk_kv)
    kv_idx = jnp.arange(S, dtype=jnp.int32).reshape(n_kv, chunk_kv)

    def step(carry, xs):
        m_run, l_run, acc = carry
        k_c, v_c, seg_c, idx_c = xs
        # logits: (B, H, cq, ck) — H stays sharded over the model axis
        # bf16 operands, fp32 accumulation (MXU-native flash numerics)
        s = jnp.einsum(
            "bqhd,bkhd->bhqk",
            q,
            k_c,
            preferred_element_type=jnp.float32,
        ) * scale
        mask = _mask_chunk(q_idx, idx_c, seg_q, seg_c, causal, window)
        s = jnp.where(mask[:, None, :, :], s, _NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # fully-masked rows: s == m_new == NEG_INF would give p = 1; zero
        # them so padded query positions produce exactly 0 (matches the
        # Pallas kernel and the dense oracle).
        p = jnp.where(mask[:, None, :, :], p, 0.0)
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + p.sum(axis=-1)
        acc_new = alpha[..., None] * acc + jnp.einsum(
            "bhqk,bkhd->bhqd",
            p.astype(v_c.dtype),
            v_c,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, cq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, cq), jnp.float32)
    a0 = jnp.zeros((B, H, cq, D), jnp.float32)
    (m_f, l_f, acc), _ = lax.scan(
        step,
        (m0, l0, a0),
        (
            jnp.moveaxis(k, 1, 0),
            jnp.moveaxis(v, 1, 0),
            jnp.moveaxis(seg_kv, 1, 0),
            kv_idx,
        ),
    )
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    return jnp.moveaxis(out, -2, 1)  # (B, cq, H, D)


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KVH, D) -> (B, S, KVH*n_rep, D).

    For GQA under tensor parallelism the repeat is a no-comm *split* of the
    (replicated) KV heads onto the model-sharded H axis — this keeps the
    attention logits sharded over heads even when KVH < mesh model size
    (the un-repeated grouped einsum forces XLA to replicate the logits,
    measured at +3.2 GB all-reduce per layer on qwen2-72b train_4k).
    """
    if n_rep == 1:
        return k
    B, S, KVH, D = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KVH, n_rep, D))
    return k.reshape(B, S, KVH * n_rep, D)


def flash_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, KVH, D)
    v: jax.Array,            # (B, Skv, KVH, D)
    segment_ids_q: jax.Array,   # (B, Sq)
    segment_ids_kv: jax.Array,  # (B, Skv)
    *,
    causal: bool = True,
    window: int = 0,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Chunked online-softmax attention with segment masking.  O(c^2) memory."""
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    scale = 1.0 / math.sqrt(D)

    k = constrain(repeat_kv(k, H // KVH), ("batch", None, "heads", None))
    v = constrain(repeat_kv(v, H // KVH), ("batch", None, "heads", None))

    chunk_q = min(chunk_q, Sq)
    chunk_kv = min(chunk_kv, k.shape[1])
    # pad Sq/Skv to chunk multiples (segment id 0 == masked padding)
    def pad_to(x, c, axis):
        rem = (-x.shape[axis]) % c
        if rem == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, rem)
        return jnp.pad(x, widths)

    qp = pad_to(q, chunk_q, 1)
    kp = pad_to(k, chunk_kv, 1)
    vp = pad_to(v, chunk_kv, 1)
    sq = pad_to(segment_ids_q, chunk_q, 1)
    skv = pad_to(segment_ids_kv, chunk_kv, 1)

    Sq_p = qp.shape[1]
    n_q = Sq_p // chunk_q
    qp = qp.reshape(B, n_q, chunk_q, H, D)
    sq_c = sq.reshape(B, n_q, chunk_q)
    q_idx = (
        jnp.arange(Sq_p, dtype=jnp.int32).reshape(n_q, chunk_q) + q_offset
    )

    def one_chunk(xs):
        q_c, seg_c, idx_c = xs
        return _flash_q_chunk(
            q_c, kp, vp, idx_c, seg_c, skv,
            causal=causal, window=window, chunk_kv=chunk_kv, scale=scale,
        )

    out = lax.map(
        one_chunk, (jnp.moveaxis(qp, 1, 0), jnp.moveaxis(sq_c, 1, 0), q_idx)
    )  # (n_q, B, cq, H, D)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq_p, H, D)
    return out[:, :Sq].astype(q.dtype)


def _decode_attention_local(
    q: jax.Array,          # (B, 1, H, D)
    k_cache: jax.Array,    # (B, S_local, KVH, D)
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,)
    offset,                # global index of this shard's first cache slot
    axes: Tuple[str, ...],  # collective axes ((),) = single device
    *,
    window: int,
) -> jax.Array:
    """Flash-decode shard body: local partial softmax + tiny cross-shard
    combine (pmax of the max, psum of denominator/numerator)."""
    B, _, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    qf = q.reshape(B, KVH, G, D).astype(jnp.float32)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale                                           # (B, KVH, G, S)
    idx = offset + jnp.arange(S, dtype=jnp.int32)[None, :]  # (1, S) global
    cache_len = jnp.asarray(cache_len).reshape(-1, 1)
    valid = idx < cache_len
    if window > 0:
        valid &= idx >= (cache_len - window)
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)

    m = s.max(axis=-1)                                   # (B, KVH, G)
    for ax in axes:
        m = lax.pmax(m, ax)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum(
        "bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if axes:
        l = lax.psum(l, axes)
        acc = lax.psum(acc, axes)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention_distributed(
    q: jax.Array,          # (B, 1, H, D) — batch over data, repl. over model
    k_cache: jax.Array,    # (B, S, KVH, D) — S sharded over the model axis
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,)
    *,
    window: int = 0,
) -> Optional[jax.Array]:
    """Distributed flash-decode over a sequence-sharded KV cache.

    GQA KV-head counts are usually smaller than the model axis (qwen2: 8
    heads vs 16 shards), so the decode cache shards over the *sequence*
    dim.  Plain attention over that layout forces XLA to gather the cache
    or the logits every layer (measured 9.1 GB/step/device on qwen2-72b
    decode_32k).  This shard_map computes each shard's partial online
    softmax locally and combines with a pmax+2 psums of (B, H)-sized
    tensors — ~1 MB/layer (EXPERIMENTS.md §Perf).

    Returns None when no mesh context is active or the layout doesn't
    shard the cache sequence (callers fall back to the dense path).
    """
    from jax.sharding import PartitionSpec as P

    try:  # jax >= 0.6: top-level export, replication check named check_vma
        from jax import shard_map
        _sm_kwargs = {"check_vma": False}
    except ImportError:  # jax 0.4/0.5: experimental path, check_rep
        from jax.experimental.shard_map import shard_map
        _sm_kwargs = {"check_rep": False}

    from ..distributed.context import _STATE  # same-module convention

    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return None
    mesh, rules = ctx
    from ..distributed.sharding import axes_to_pspec

    B, S = k_cache.shape[0], k_cache.shape[1]
    kv_spec = axes_to_pspec(
        ("batch", "kv_seq", "kv_heads", None), k_cache.shape, rules, mesh
    )
    seq_entry = kv_spec[1]
    if seq_entry is None:
        return None  # cache not sequence-sharded: dense path is fine
    seq_axes = seq_entry if isinstance(seq_entry, tuple) else (seq_entry,)
    batch_entry = kv_spec[0]

    n_shards = 1
    for ax in seq_axes:
        n_shards *= mesh.shape[ax]
    s_local = S // n_shards

    def body(q_l, k_l, v_l, len_l):
        # global offset of this shard's slice (row-major over seq_axes)
        offset = jnp.zeros((), jnp.int32)
        for ax in seq_axes:
            offset = offset * mesh.shape[ax] + lax.axis_index(ax)
        offset = offset * s_local
        return _decode_attention_local(
            q_l, k_l, v_l, len_l, offset, tuple(seq_axes), window=window
        )

    q_spec = P(batch_entry, None, None, None)
    len_spec = P(batch_entry)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, len_spec),
        out_specs=q_spec,
        **_sm_kwargs,
    )(q, k_cache, v_cache, jnp.asarray(cache_len).reshape(B))


def decode_attention(
    q: jax.Array,          # (B, 1, H, D)
    k_cache: jax.Array,    # (B, S, KVH, D)
    v_cache: jax.Array,    # (B, S, KVH, D)
    cache_len: jax.Array,  # (B,) or scalar — number of valid cache entries
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a dense KV cache (serving decode)."""
    B, _, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    qf = q.reshape(B, KVH, G, D).astype(jnp.float32)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk",
        qf,
        k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    idx = jnp.arange(S, dtype=jnp.int32)[None, :]  # (1, S)
    cache_len = jnp.asarray(cache_len).reshape(-1, 1)  # (B or 1, S)
    valid = idx < cache_len
    if window > 0:
        valid &= idx >= (cache_len - window)
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd",
        p,
        v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + flash core)
# ---------------------------------------------------------------------------


def attention_specs(cfg: Any, cross: bool = False) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim_
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    specs: Dict[str, Any] = {
        "wq": Spec((d, H, hd), ("embed", "heads", "head_dim"), init="scaled"),
        "wk": Spec((d, KVH, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": Spec((d, KVH, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wo": Spec((H, hd, d), ("heads", "head_dim", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        specs["bq"] = Spec((H, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = Spec((KVH, hd), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = Spec((KVH, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = Spec((hd,), ("head_dim",), init="zeros")
        specs["k_norm"] = Spec((hd,), ("head_dim",), init="zeros")
    return specs


@dataclasses.dataclass
class KVCache:
    """Dense per-layer KV cache carried through decode steps."""

    k: jax.Array  # (B, S_max, KVH, D)
    v: jax.Array  # (B, S_max, KVH, D)


def _project_qkv(
    p: Dict[str, jax.Array], cfg: Any, x: jax.Array, x_kv: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    # TP layout inside the block: heads over model, sequence gathered
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def attention(
    p: Dict[str, jax.Array],
    cfg: Any,
    x: jax.Array,                 # (B, S, d)
    segment_ids: jax.Array,       # (B, S)
    positions: jax.Array,         # (B, S)
    *,
    causal: bool = True,
    x_kv: Optional[jax.Array] = None,           # cross-attention source
    segment_ids_kv: Optional[jax.Array] = None,
    positions_kv: Optional[jax.Array] = None,
    use_rope: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill).  Returns (out, (k, v))."""
    x_kv = x if x_kv is None else x_kv
    segment_ids_kv = segment_ids if segment_ids_kv is None else segment_ids_kv
    positions_kv = positions if positions_kv is None else positions_kv

    q, k, v = _project_qkv(p, cfg, x, x_kv)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions_kv, cfg.rope_theta)
    out = flash_attention(
        q, k, v, segment_ids, segment_ids_kv,
        causal=causal, window=cfg.sliding_window,
    )
    out = constrain(out, ("batch", None, "heads", None))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def attention_decode(
    p: Dict[str, jax.Array],
    cfg: Any,
    x: jax.Array,              # (B, 1, d)
    position: jax.Array,       # (B,) within-sequence position of the token
    cache: KVCache,
    cache_len: jax.Array,      # (B,) valid entries *including* this token
    *,
    use_rope: bool = True,
) -> Tuple[jax.Array, KVCache]:
    """One decode step: append to cache, attend over it."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, x)
    if use_rope:
        pos = position.reshape(B, 1)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    # scatter the new token into the cache at cache_len - 1
    write_idx = (cache_len - 1).astype(jnp.int32)  # (B,)
    b_idx = jnp.arange(B, dtype=jnp.int32)
    k_cache = cache.k.at[b_idx, write_idx].set(k[:, 0].astype(cache.k.dtype))
    v_cache = cache.v.at[b_idx, write_idx].set(v[:, 0].astype(cache.v.dtype))
    # distributed flash-decode when the cache is sequence-sharded under the
    # active mesh; dense path otherwise (single device, tests)
    out = decode_attention_distributed(
        q, k_cache, v_cache, cache_len, window=cfg.sliding_window
    )
    if out is None:
        out = decode_attention(
            q, k_cache, v_cache, cache_len, window=cfg.sliding_window
        )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, KVCache(k=k_cache, v=v_cache)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: Any, d_ff: Optional[int] = None) -> Dict[str, Spec]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w_gate": Spec((d, f), ("embed", "mlp"), init="scaled"),
            "w_up": Spec((d, f), ("embed", "mlp"), init="scaled"),
            "w_down": Spec((f, d), ("mlp", "embed"), init="scaled"),
        }
    return {
        "w_up": Spec((d, f), ("embed", "mlp"), init="scaled"),
        "w_down": Spec((f, d), ("mlp", "embed"), init="scaled"),
    }


def mlp(p: Dict[str, jax.Array], cfg: Any, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = constrain(h, ("batch", None, "mlp"))
    return h @ p["w_down"]
