"""Mamba-1 selective SSM block (jamba's 'M' layers).

TPU adaptation notes (DESIGN.md §2): the CUDA selective-scan kernel fuses the
(B, L, d_inner, d_state) state expansion in registers; here the recurrence
runs as a chunked, remat-bounded ``lax.scan`` (``scan_utils.chunked_scan``)
with ``d_inner`` sharded over the model axis (column-parallel in_proj,
row-parallel out_proj), so the per-chip state slab stays in the MB range.
Decode carries (conv window, ssm state) — O(1) per token, which is what makes
jamba eligible for the long_500k shape.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.context import constrain
from .params import Spec
from .scan_utils import chunked_scan

__all__ = ["mamba_specs", "mamba_forward", "mamba_decode_step", "MambaState"]

MambaState = Dict[str, jax.Array]  # {"conv": (B, k-1, di), "ssm": (B, di, ds)}


def mamba_specs(cfg: Any) -> Dict[str, Spec]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.inner(d)
    r = s.rank(d)
    ds = s.d_state
    return {
        "in_proj": Spec((d, 2 * di), ("embed", "mlp"), init="scaled"),
        "conv_w": Spec((s.d_conv, di), (None, "mlp"), init="scaled", scale=1.0),
        "conv_b": Spec((di,), ("mlp",), init="zeros"),
        "x_proj": Spec((di, r + 2 * ds), ("mlp", None), init="scaled"),
        "dt_proj": Spec((r, di), (None, "mlp"), init="scaled"),
        "dt_bias": Spec((di,), ("mlp",), init="zeros"),
        "A_log": Spec((di, ds), ("mlp", None), init="ones"),
        "D": Spec((di,), ("mlp",), init="ones"),
        "out_proj": Spec((di, d), ("mlp", "embed"), init="scaled"),
    }


def _causal_depthwise_conv(
    x: jax.Array, w: jax.Array, b: jax.Array
) -> jax.Array:
    """x: (B, S, di), w: (k, di) depthwise causal conv.

    Implemented as k shifted multiply-adds rather than
    ``conv_general_dilated`` with ``feature_group_count=di``: the SPMD
    partitioner shards grouped convs along *features* and all-gathers the
    full global batch — measured 17 GB/device/layer on jamba train_4k
    under the fsdp layout (EXPERIMENTS.md §Perf).  Elementwise shifts keep
    whatever sharding the input has; FLOPs are identical (k multiply-adds
    per element).
    """
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x[:, :-i], ((0, 0), (i, 0), (0, 0)))
        out = out + shifted * w[k - 1 - i]
    return out + b


def _ssm_scan(
    dt: jax.Array,      # (B, S, di) softplus'd
    x: jax.Array,       # (B, S, di) post-conv activations
    Bmat: jax.Array,    # (B, S, ds)
    Cmat: jax.Array,    # (B, S, ds)
    A: jax.Array,       # (di, ds) negative
    h0: jax.Array,      # (B, di, ds)
    chunk_size: int,
) -> Tuple[jax.Array, jax.Array]:
    """Selective scan: h_t = exp(dt A) h + (dt x) B_t;  y_t = h_t . C_t."""

    def step(h, xs):
        dt_t, x_t, b_t, c_t = xs  # (B, di), (B, di), (B, ds), (B, ds)
        a = jnp.exp(dt_t[..., None] * A[None])              # (B, di, ds)
        inc = (dt_t * x_t)[..., None] * b_t[:, None, :]     # (B, di, ds)
        h = a * h + inc
        y = jnp.einsum("bds,bs->bd", h, c_t)                # (B, di)
        return h, y

    xs = (
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(Bmat, 1, 0),
        jnp.moveaxis(Cmat, 1, 0),
    )
    h, ys = chunked_scan(step, h0, xs, chunk_size=chunk_size)
    return h, jnp.moveaxis(ys, 0, 1)  # (B, S, di)


def mamba_forward(
    p: Dict[str, jax.Array],
    cfg: Any,
    x: jax.Array,  # (B, S, d)
    *,
    state: MambaState = None,
    chunk_size: int = 128,
) -> Tuple[jax.Array, MambaState]:
    """Full-sequence Mamba block.  Returns (out, final_state)."""
    s = cfg.ssm
    B, S, _ = x.shape
    di, ds = s.inner(cfg.d_model), s.d_state
    r = s.rank(cfg.d_model)

    xz = constrain(x @ p["in_proj"], ("batch", None, "mlp"))
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each

    if state is not None:
        conv_in = jnp.concatenate([state["conv"].astype(x_in.dtype), x_in], axis=1)
        conv_out = _causal_depthwise_conv(conv_in, p["conv_w"], p["conv_b"])
        conv_out = conv_out[:, state["conv"].shape[1]:]
        h0 = state["ssm"]
    else:
        conv_out = _causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"])
        h0 = jnp.zeros((B, di, ds), jnp.float32)

    xc = jax.nn.silu(conv_out)
    dbc = xc @ p["x_proj"]  # (B, S, r + 2 ds)
    dt_raw, Bmat, Cmat = jnp.split(dbc, [r, r + ds], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])  # (B, S, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    h, y = _ssm_scan(
        dt.astype(jnp.float32),
        xc.astype(jnp.float32),
        Bmat.astype(jnp.float32),
        Cmat.astype(jnp.float32),
        A,
        h0.astype(jnp.float32),
        chunk_size,
    )
    y = (y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = {
        "conv": x_in[:, -(s.d_conv - 1):].astype(jnp.float32)
        if S >= s.d_conv - 1
        else jnp.pad(x_in, ((0, 0), (s.d_conv - 1 - S, 0), (0, 0))).astype(
            jnp.float32
        ),
        "ssm": h,
    }
    return out, new_state


def mamba_decode_step(
    p: Dict[str, jax.Array],
    cfg: Any,
    x: jax.Array,       # (B, 1, d)
    state: MambaState,  # conv window (B, k-1, di) + ssm state (B, di, ds)
) -> Tuple[jax.Array, MambaState]:
    """O(1) single-token Mamba step."""
    s = cfg.ssm
    r = s.rank(cfg.d_model)
    ds = s.d_state

    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B, 1, di)

    window = jnp.concatenate(
        [state["conv"].astype(x_in.dtype), x_in], axis=1
    )  # (B, k, di)
    conv = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(conv)  # (B, di)

    dbc = xc @ p["x_proj"]
    dt_raw, Bmat, Cmat = jnp.split(dbc, [r, r + ds], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])  # (B, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None])
    inc = (dt * xc).astype(jnp.float32)[..., None] * Bmat.astype(jnp.float32)[
        :, None, :
    ]
    h = a * state["ssm"] + inc
    y = jnp.einsum("bds,bs->bd", h, Cmat.astype(jnp.float32))
    y = (y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(x.dtype)
    y = y[:, None, :] * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": window[:, 1:].astype(jnp.float32), "ssm": h}


def mamba_init_state(cfg: Any, batch: int) -> MambaState:
    s = cfg.ssm
    di = s.inner(cfg.d_model)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di), jnp.float32),
        "ssm": jnp.zeros((batch, di, s.d_state), jnp.float32),
    }
