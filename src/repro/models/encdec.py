"""Encoder-decoder LM (seamless-m4t backbone).

The audio frontend is a stub per the assignment: ``input_specs()`` provides
precomputed frame embeddings for the encoder (B, S_enc, d).  The decoder is a
standard causal stack with cross-attention to the encoder output; decode
shapes lower the *decoder* step with the encoder output (and cross K/V)
cached.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import (
    KVCache,
    attention,
    attention_decode,
    attention_specs,
    decode_attention,
    mlp,
    mlp_specs,
    norm,
    norm_specs,
    _project_qkv,
)
from ..distributed.context import constrain
from .params import Spec
from .transformer import _remat, chunked_cross_entropy, pad_vocab

__all__ = ["EncDecLM"]


@dataclasses.dataclass
class EncDecLM:
    cfg: Any

    # ---- parameters -----------------------------------------------------------
    def _enc_layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": norm_specs(cfg.norm_type, cfg.d_model),
            "self_attn": attention_specs(cfg),
            "ln2": norm_specs(cfg.norm_type, cfg.d_model),
            "ffn": mlp_specs(cfg),
        }

    def _dec_layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": norm_specs(cfg.norm_type, cfg.d_model),
            "self_attn": attention_specs(cfg),
            "ln_cross": norm_specs(cfg.norm_type, cfg.d_model),
            "cross_attn": attention_specs(cfg),
            "ln2": norm_specs(cfg.norm_type, cfg.d_model),
            "ffn": mlp_specs(cfg),
        }

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        v = pad_vocab(cfg.vocab_size)

        def stack(n: int, tree: Any) -> Any:
            return jax.tree.map(
                lambda s: Spec((n,) + s.shape, ("layers",) + s.axes,
                               init=s.init, scale=s.scale, dtype=s.dtype),
                tree,
                is_leaf=lambda x: isinstance(x, Spec),
            )

        return {
            # unit-variance embeddings (see transformer.py rationale)
            "embed": Spec((v, cfg.d_model), ("vocab", "embed"), init="normal",
                          scale=1.0),
            "enc_blocks": stack(cfg.n_encoder_layers, self._enc_layer_specs()),
            "enc_norm": norm_specs(cfg.norm_type, cfg.d_model),
            "dec_blocks": stack(cfg.n_layers, self._dec_layer_specs()),
            "final_norm": norm_specs(cfg.norm_type, cfg.d_model),
            "lm_head": Spec((v, cfg.d_model), ("vocab", "embed"), init="scaled"),
        }

    # ---- encoder -----------------------------------------------------------------
    def encode(
        self,
        params: Dict[str, Any],
        enc_embeds: jax.Array,       # (B, Se, d) stub frame embeddings
        enc_segment_ids: jax.Array,  # (B, Se)
        *,
        remat_policy: Optional[str] = "nothing",
    ) -> jax.Array:
        cfg = self.cfg
        B, Se, _ = enc_embeds.shape
        pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))

        def body(x, p):
            x = constrain(x, ("batch", "seq", None))
            h = norm(p["ln1"], cfg.norm_type, x)
            out, _ = attention(
                p["self_attn"], cfg, h, enc_segment_ids, pos, causal=False
            )
            x = x + out
            h = norm(p["ln2"], cfg.norm_type, x)
            return x + mlp(p["ffn"], cfg, h), None

        if remat_policy is not None:
            body = _remat(body, remat_policy)
        x, _ = lax.scan(body, enc_embeds, params["enc_blocks"])
        return norm(params["enc_norm"], cfg.norm_type, x)

    # ---- decoder (training / prefill over full sequence) ---------------------------
    def _decoder_hidden(
        self,
        params: Dict[str, Any],
        tokens: jax.Array,
        segment_ids: jax.Array,
        positions: jax.Array,
        enc_out: jax.Array,
        enc_segment_ids: jax.Array,
        *,
        remat_policy: Optional[str] = "nothing",
        collect_cache: bool = False,
    ):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        B, Se, _ = enc_out.shape
        enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))

        def body(x, p):
            x = constrain(x, ("batch", "seq", None))
            h = norm(p["ln1"], cfg.norm_type, x)
            out, (k, v) = attention(p["self_attn"], cfg, h, segment_ids, positions)
            x = x + out
            h = norm(p["ln_cross"], cfg.norm_type, x)
            out, (ck, cv) = attention(
                p["cross_attn"], cfg, h, segment_ids, positions,
                causal=False,
                x_kv=enc_out, segment_ids_kv=enc_segment_ids,
                positions_kv=enc_pos, use_rope=False,
            )
            x = x + out
            h = norm(p["ln2"], cfg.norm_type, x)
            x = x + mlp(p["ffn"], cfg, h)
            cache = {"k": k, "v": v, "ck": ck, "cv": cv} if collect_cache else None
            return x, cache

        if remat_policy is not None and not collect_cache:
            body = _remat(body, remat_policy)
        x, caches = lax.scan(body, x, params["dec_blocks"])
        return norm(params["final_norm"], cfg.norm_type, x), caches

    # ---- entry points ------------------------------------------------------------
    def loss(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],
        *,
        remat_policy: Optional[str] = "nothing",
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        enc_out = self.encode(
            params, batch["enc_embeds"], batch["enc_segment_ids"],
            remat_policy=remat_policy,
        )
        x, _ = self._decoder_hidden(
            params, batch["tokens"], batch["segment_ids"], batch["positions"],
            enc_out, batch["enc_segment_ids"], remat_policy=remat_policy,
        )
        loss, metrics = chunked_cross_entropy(x, params["lm_head"], batch["labels"])
        return loss, dict(metrics, loss=loss)

    def prefill(
        self, params: Dict[str, Any], batch: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        enc_out = self.encode(
            params, batch["enc_embeds"], batch["enc_segment_ids"],
            remat_policy=None,
        )
        x, caches = self._decoder_hidden(
            params, batch["tokens"], batch["segment_ids"], batch["positions"],
            enc_out, batch["enc_segment_ids"],
            remat_policy=None, collect_cache=True,
        )
        seg = batch["segment_ids"]
        last = jnp.maximum(jnp.sum((seg > 0).astype(jnp.int32), axis=1) - 1, 0)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        logits = x_last.astype(jnp.float32) @ params["lm_head"].T.astype(jnp.float32)
        cache = {
            "blocks": caches,
            "enc_segment_ids": batch["enc_segment_ids"],
            "len": jnp.sum((seg > 0).astype(jnp.int32), axis=1),
        }
        return logits, cache

    def decode_step(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],
        cache: Dict[str, Any],
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One decoder token; cross K/V are precomputed in the cache."""
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0)  # (B, 1, d)
        new_len = cache["len"] + 1
        position = cache["len"]
        enc_valid = jnp.sum(
            (cache["enc_segment_ids"] > 0).astype(jnp.int32), axis=1
        )

        def body(x, xs):
            p, c = xs
            x = constrain(x, ("batch", None, None))
            h = norm(p["ln1"], cfg.norm_type, x)
            out, kv = attention_decode(
                p["self_attn"], cfg, h, position,
                KVCache(k=c["k"], v=c["v"]), new_len,
            )
            x = x + out
            h = norm(p["ln_cross"], cfg.norm_type, x)
            q, _, _ = _project_qkv(p["cross_attn"], cfg, h, h)
            out = decode_attention(q, c["ck"], c["cv"], enc_valid)
            out = jnp.einsum("bshk,hkd->bsd", out, p["cross_attn"]["wo"])
            x = x + out
            h = norm(p["ln2"], cfg.norm_type, x)
            x = x + mlp(p["ffn"], cfg, h)
            return x, {"k": kv.k, "v": kv.v, "ck": c["ck"], "cv": c["cv"]}

        x, new_blocks = lax.scan(body, x, (params["dec_blocks"], cache["blocks"]))
        x = norm(params["final_norm"], cfg.norm_type, x)
        logits = x[:, 0].astype(jnp.float32) @ params["lm_head"].T.astype(jnp.float32)
        return logits, {
            "blocks": new_blocks,
            "enc_segment_ids": cache["enc_segment_ids"],
            "len": new_len,
        }

    def init_cache(
        self, batch_size: int, max_len: int, enc_len: int, dtype: Any = jnp.bfloat16
    ) -> Dict[str, Any]:
        cfg = self.cfg
        n = cfg.n_layers
        kvh, hd = cfg.n_kv_heads, cfg.head_dim_
        return {
            "blocks": {
                "k": jnp.zeros((n, batch_size, max_len, kvh, hd), dtype),
                "v": jnp.zeros((n, batch_size, max_len, kvh, hd), dtype),
                "ck": jnp.zeros((n, batch_size, enc_len, kvh, hd), dtype),
                "cv": jnp.zeros((n, batch_size, enc_len, kvh, hd), dtype),
            },
            "enc_segment_ids": jnp.ones((batch_size, enc_len), jnp.int32),
            "len": jnp.zeros((batch_size,), jnp.int32),
        }
