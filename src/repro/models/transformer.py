"""Decoder-only LM assembled from an ``ArchConfig``.

The layer stack is ``lax.scan``'d over *periods* of the (possibly
heterogeneous) ``layer_pattern`` — e.g. jamba's ``MMMMAMMM`` — with the
pattern unrolled inside the scan body and per-position parameters stacked
over periods.  This keeps the HLO size O(period) regardless of depth (95
layers compile as 1 scanned period body), which is what makes the 512-device
dry-run of the large configs tractable.

Three entry points, matching the assigned input shapes:
  - ``loss``        : training forward + chunked cross-entropy (train_4k)
  - ``prefill``     : full-sequence forward building the KV/state caches
                      (prefill_32k)
  - ``decode_step`` : one new token against the caches (decode_32k,
                      long_500k)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed.context import constrain
from . import moe as moe_lib
from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from .layers import (
    KVCache,
    attention,
    attention_decode,
    attention_specs,
    mlp,
    mlp_specs,
    norm,
    norm_specs,
)
from .params import Spec

__all__ = ["DecoderLM", "chunked_cross_entropy", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple of 256 so it shards over any mesh axis."""
    return ((v + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------


def chunked_cross_entropy(
    hidden: jax.Array,      # (B, S, d)
    table: jax.Array,       # (V, d) embedding/unembedding table
    labels: jax.Array,      # (B, S) int32, -1 = masked
    *,
    chunk: int = 512,
    z_loss: float = 1e-4,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = hidden.shape[1] // chunk
    hidden = hidden.reshape(B, n, chunk, d)
    labels = labels.reshape(B, n, chunk)

    @jax.checkpoint
    def chunk_loss(h_c: jax.Array, l_c: jax.Array):
        # batch over the data axes ONLY so the vocab dim can take "model":
        # the (b, chunk, V) logits then stay fully sharded and the only
        # cross-shard work is the tiny (b, chunk) logsumexp combine —
        # vs ~15 GB/step of replicated-logit all-reduce otherwise (§Perf).
        h_c = constrain(h_c, ("batch_data", None, None))
        logits = jnp.einsum(
            "bsd,vd->bsv", h_c.astype(jnp.float32), table.astype(jnp.float32)
        )
        logits = constrain(logits, ("batch_data", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        idx = jnp.maximum(l_c, 0)
        picked = jnp.take_along_axis(logits, idx[..., None], axis=-1)[..., 0]
        valid = (l_c >= 0).astype(jnp.float32)
        ce = (lse - picked) * valid
        zl = jnp.square(lse) * valid
        return ce.sum(), zl.sum(), valid.sum()

    def body(carry, xs):
        ce_s, zl_s, n_s = carry
        h_c, l_c = xs
        ce, zl, nv = chunk_loss(h_c, l_c)
        return (ce_s + ce, zl_s + zl, n_s + nv), None

    (ce_sum, zl_sum, n_valid), _ = lax.scan(
        body,
        (jnp.zeros((), jnp.float32),) * 3,
        (jnp.moveaxis(hidden, 1, 0), jnp.moveaxis(labels, 1, 0)),
    )
    n_valid = jnp.maximum(n_valid, 1.0)
    loss = ce_sum / n_valid + z_loss * zl_sum / n_valid
    return loss, {"ce": ce_sum / n_valid, "tokens": n_valid}


# ---------------------------------------------------------------------------
# Block spec / apply dispatch table
# ---------------------------------------------------------------------------


def _block_specs(cfg: Any, pos: int) -> Dict[str, Any]:
    """Parameter specs for the block at position ``pos`` within the period."""
    char = cfg.pattern[pos]
    specs: Dict[str, Any] = {"ln1": norm_specs(cfg.norm_type, cfg.d_model)}
    if char == "A":
        specs["mixer"] = attention_specs(cfg)
    elif char == "M":
        specs["mixer"] = ssm_lib.mamba_specs(cfg)
    elif char == "l":
        specs["mixer"] = xlstm_lib.mlstm_specs(cfg)
    elif char == "s":
        specs["mixer"] = xlstm_lib.slstm_specs(cfg)
    else:
        raise ValueError(f"unknown pattern char {char!r}")
    if char in ("A", "M") and (cfg.d_ff or cfg.moe):
        specs["ln2"] = norm_specs(cfg.norm_type, cfg.d_model)
        if cfg.moe is not None and cfg.moe.is_moe_layer(pos):
            specs["ffn"] = moe_lib.moe_specs(cfg)
        elif cfg.d_ff:
            specs["ffn"] = mlp_specs(cfg)
    return specs


def _stack_period(cfg: Any, spec_tree: Any) -> Any:
    """Prepend the scanned 'layers' (periods) dimension to every spec."""
    n = cfg.n_periods

    def stack(s: Spec) -> Spec:
        return Spec(
            shape=(n,) + s.shape,
            axes=("layers",) + s.axes,
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
        )

    return jax.tree.map(stack, spec_tree, is_leaf=lambda x: isinstance(x, Spec))


def _zero_aux() -> Dict[str, jax.Array]:
    z = jnp.zeros((), jnp.float32)
    return {"moe_load_balance": z, "moe_z_loss": z, "moe_drop_fraction": z}


def _add_aux(a: Dict[str, jax.Array], b: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    return {k: a[k] + b.get(k, 0.0) for k in a}


# ---------------------------------------------------------------------------
# DecoderLM
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DecoderLM:
    cfg: Any

    # ---- parameters ---------------------------------------------------------
    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        v = pad_vocab(cfg.vocab_size)
        specs: Dict[str, Any] = {
            # unit-variance embeddings for untied models: every block starts
            # with a norm, so N(0,1) rows keep rsqrt(var) ~ 1 and the embed
            # gradient on the same scale as the rest (0.02-scale init +
            # rms_norm amplifies the embed grad ~2500x).  Tied models keep
            # the small init — the same table is the unembed projection.
            "embed": Spec((v, cfg.d_model), ("vocab", "embed"), init="normal",
                          scale=0.02 if cfg.tie_embeddings else 1.0),
            "final_norm": norm_specs(cfg.norm_type, cfg.d_model),
            "blocks": {
                str(pos): _stack_period(cfg, _block_specs(cfg, pos))
                for pos in range(len(cfg.pattern))
            },
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = Spec(
                (v, cfg.d_model), ("vocab", "embed"), init="scaled"
            )
        return specs

    def _table(self, params: Dict[str, Any]) -> jax.Array:
        return params["embed"] if self.cfg.tie_embeddings else params["lm_head"]

    # ---- embedding ----------------------------------------------------------
    def _embed(self, params: Dict[str, Any], batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.frontend == "vision" and "vision_embeds" in batch:
            # frontend stub: precomputed patch embeddings fill the prefix
            nv = batch["vision_embeds"].shape[1]
            x = x.at[:, :nv].set(batch["vision_embeds"].astype(x.dtype))
        return x

    # ---- block application ----------------------------------------------------
    def _apply_block_train(
        self,
        char: str,
        p: Dict[str, Any],
        cfg: Any,
        x: jax.Array,
        seg: jax.Array,
        pos_ids: jax.Array,
        aux: Dict[str, jax.Array],
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x = constrain(x, ("batch", "seq", None))
        h = norm(p["ln1"], cfg.norm_type, x)
        if char == "A":
            out, _ = attention(p["mixer"], cfg, h, seg, pos_ids)
        elif char == "M":
            out, _ = ssm_lib.mamba_forward(p["mixer"], cfg, h)
        elif char == "l":
            out, _ = xlstm_lib.mlstm_forward(p["mixer"], cfg, h)
        else:
            out, _ = xlstm_lib.slstm_forward(p["mixer"], cfg, h)
        x = x + constrain(out, ("batch", "seq", None))
        if "ffn" in p:
            h = norm(p["ln2"], cfg.norm_type, x)
            if "router" in p["ffn"]:
                out, moe_aux = moe_lib.moe_layer(p["ffn"], cfg, h)
                aux = _add_aux(aux, moe_aux)
            else:
                out = mlp(p["ffn"], cfg, h)
            x = x + constrain(out, ("batch", "seq", None))
        return x, aux

    # ---- training forward -----------------------------------------------------
    def hidden_states(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],
        *,
        remat_policy: Optional[str] = "nothing",
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self._embed(params, batch)
        seg = batch["segment_ids"]
        pos_ids = batch["positions"]

        def period_body(carry, period_params):
            x, aux = carry
            for pos, char in enumerate(cfg.pattern):
                x, aux = self._apply_block_train(
                    char, period_params[str(pos)], cfg, x, seg, pos_ids, aux
                )
            return (x, aux), None

        if remat_policy is not None:
            period_body = _remat(period_body, remat_policy)

        (x, aux), _ = lax.scan(period_body, (x, _zero_aux()), params["blocks"])
        x = norm(params["final_norm"], cfg.norm_type, x)
        return x, aux

    def loss(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],
        *,
        remat_policy: Optional[str] = "nothing",
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x, aux = self.hidden_states(params, batch, remat_policy=remat_policy)
        loss, metrics = chunked_cross_entropy(
            x, self._table(params), batch["labels"]
        )
        loss = loss + aux["moe_load_balance"] + aux["moe_z_loss"]
        metrics = dict(metrics, **aux, loss=loss)
        return loss, metrics

    # ---- serving: prefill -------------------------------------------------------
    def prefill(
        self, params: Dict[str, Any], batch: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Returns (last-token logits (B, V), cache pytree)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        seg = batch["segment_ids"]
        pos_ids = batch["positions"]
        B, S = seg.shape

        def period_body(x, period_params):
            caches = {}
            for pos, char in enumerate(cfg.pattern):
                p = period_params[str(pos)]
                x = constrain(x, ("batch", "seq", None))
                h = norm(p["ln1"], cfg.norm_type, x)
                if char == "A":
                    out, (k, v) = attention(p["mixer"], cfg, h, seg, pos_ids)
                    caches[str(pos)] = {"k": k, "v": v}
                elif char == "M":
                    out, st = ssm_lib.mamba_forward(p["mixer"], cfg, h)
                    caches[str(pos)] = st
                elif char == "l":
                    out, st = xlstm_lib.mlstm_forward(p["mixer"], cfg, h)
                    caches[str(pos)] = st
                else:
                    out, st = xlstm_lib.slstm_forward(p["mixer"], cfg, h)
                    caches[str(pos)] = st
                x = x + out
                if "ffn" in p:
                    h = norm(p["ln2"], cfg.norm_type, x)
                    if "router" in p["ffn"]:
                        out, _ = moe_lib.moe_layer(p["ffn"], cfg, h)
                    else:
                        out = mlp(p["ffn"], cfg, h)
                    x = x + out
            return x, caches

        x, caches = lax.scan(period_body, x, params["blocks"])
        x = norm(params["final_norm"], cfg.norm_type, x)
        # last valid position per row
        last = jnp.maximum(jnp.sum((seg > 0).astype(jnp.int32), axis=1) - 1, 0)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        logits = x_last.astype(jnp.float32) @ self._table(params).T.astype(
            jnp.float32
        )
        cache = {
            "blocks": caches,
            "len": jnp.sum((seg > 0).astype(jnp.int32), axis=1),
        }
        return logits, cache

    # ---- serving: decode ---------------------------------------------------------
    def decode_step(
        self,
        params: Dict[str, Any],
        batch: Dict[str, jax.Array],  # {"tokens": (B, 1)}
        cache: Dict[str, Any],
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One token for every sequence in the batch.  Cache is donated."""
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0)  # (B, 1, d)
        new_len = cache["len"] + 1  # includes the new token
        position = cache["len"]     # 0-based position of the new token

        def period_body(x, xs):
            period_params, period_cache = xs
            new_caches = {}
            for pos, char in enumerate(cfg.pattern):
                p = period_params[str(pos)]
                c = period_cache[str(pos)]
                x = constrain(x, ("batch", None, None))
                h = norm(p["ln1"], cfg.norm_type, x)
                if char == "A":
                    out, kv = attention_decode(
                        p["mixer"], cfg, h, position,
                        KVCache(k=c["k"], v=c["v"]), new_len,
                    )
                    new_caches[str(pos)] = {"k": kv.k, "v": kv.v}
                elif char == "M":
                    out, st = ssm_lib.mamba_decode_step(p["mixer"], cfg, h, c)
                    new_caches[str(pos)] = st
                elif char == "l":
                    out, st = xlstm_lib.mlstm_decode_step(p["mixer"], cfg, h, c)
                    new_caches[str(pos)] = st
                else:
                    out, st = xlstm_lib.slstm_decode_step(p["mixer"], cfg, h, c)
                    new_caches[str(pos)] = st
                x = x + out
                if "ffn" in p:
                    h = norm(p["ln2"], cfg.norm_type, x)
                    if "router" in p["ffn"]:
                        out, _ = moe_lib.moe_layer(p["ffn"], cfg, h)
                    else:
                        out = mlp(p["ffn"], cfg, h)
                    x = x + out
            return x, new_caches

        x, new_blocks = lax.scan(period_body, x, (params["blocks"], cache["blocks"]))
        x = norm(params["final_norm"], cfg.norm_type, x)
        logits = x[:, 0].astype(jnp.float32) @ self._table(params).T.astype(
            jnp.float32
        )
        return logits, {"blocks": new_blocks, "len": new_len}

    # ---- cache allocation ----------------------------------------------------------
    def init_cache(
        self, batch_size: int, max_len: int, dtype: Any = jnp.bfloat16
    ) -> Dict[str, Any]:
        """Dense cache pytree (used to build dry-run ShapeDtypeStructs too)."""
        cfg = self.cfg
        n = cfg.n_periods
        blocks: Dict[str, Any] = {}
        for pos, char in enumerate(cfg.pattern):
            if char == "A":
                kv_shape = (n, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim_)
                blocks[str(pos)] = {
                    "k": jnp.zeros(kv_shape, dtype),
                    "v": jnp.zeros(kv_shape, dtype),
                }
            elif char == "M":
                st = ssm_lib.mamba_init_state(cfg, batch_size)
                blocks[str(pos)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n,) + a.shape), st
                )
            elif char == "l":
                st = xlstm_lib.mlstm_init_state(cfg, batch_size)
                blocks[str(pos)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n,) + a.shape), st
                )
            else:
                st = xlstm_lib.slstm_init_state(cfg, batch_size)
                blocks[str(pos)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n,) + a.shape), st
                )
        return {
            "blocks": blocks,
            "len": jnp.zeros((batch_size,), jnp.int32),
        }


def _remat(fn, policy: str):
    policies = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "everything": jax.checkpoint_policies.everything_saveable,
    }
    return jax.checkpoint(fn, policy=policies[policy], prevent_cse=False)
