"""Model registry: ``ArchConfig`` -> model object + input specs.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of the given (arch x shape) cell — weak-type-correct,
shardable, and never allocated.  This is the single source of truth for both
the multi-pod dry-run and the smoke tests (which materialize the same specs
with real arrays).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..configs.shapes import ShapeConfig
from .encdec import EncDecLM
from .transformer import DecoderLM

__all__ = ["build_model", "input_specs", "make_batch", "cache_specs"]


def build_model(cfg: ArchConfig):
    return EncDecLM(cfg) if cfg.encdec else DecoderLM(cfg)


# ---------------------------------------------------------------------------
# Input specs per (arch, shape)
# ---------------------------------------------------------------------------


def _lm_train_specs(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    specs = {
        "tokens": i32(B, S),
        "labels": i32(B, S),
        "segment_ids": i32(B, S),
        "positions": i32(B, S),
    }
    if cfg.frontend == "vision":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    return specs


def _encdec_train_specs(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    Se, Sd = S // 2, S // 2
    return {
        "enc_embeds": jax.ShapeDtypeStruct((B, Se, cfg.d_model), jnp.bfloat16),
        "enc_segment_ids": i32(B, Se),
        "tokens": i32(B, Sd),
        "labels": i32(B, Sd),
        "segment_ids": i32(B, Sd),
        "positions": i32(B, Sd),
    }


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.encdec:
            return _encdec_train_specs(cfg, B, S)
        return _lm_train_specs(cfg, B, S)
    # decode: one new token against a cache of S
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
    }
    return specs


def cache_specs(
    cfg: ArchConfig, shape: ShapeConfig, dtype: Any = jnp.bfloat16
) -> Any:
    """ShapeDtypeStruct tree for the decode cache of one cell."""
    model = build_model(cfg)
    if cfg.encdec:
        enc_len = max(shape.seq_len // 8, 128)
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len, enc_len,
                                     dtype=dtype)
        )
    else:
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype=dtype)
        )
    return cache


# ---------------------------------------------------------------------------
# Materialized batches (smoke tests, examples)
# ---------------------------------------------------------------------------


def make_batch(
    cfg: ArchConfig, shape_kind: str, B: int, S: int, seed: int = 0
) -> Dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    v = cfg.vocab_size

    def tok(b, s):
        return jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)

    if cfg.encdec:
        Se, Sd = S // 2, S // 2
        return {
            "enc_embeds": jnp.asarray(
                rng.normal(size=(B, Se, cfg.d_model)) * 0.02, jnp.float32
            ),
            "enc_segment_ids": jnp.ones((B, Se), jnp.int32),
            "tokens": tok(B, Sd),
            "labels": tok(B, Sd),
            "segment_ids": jnp.ones((B, Sd), jnp.int32),
            "positions": jnp.broadcast_to(
                jnp.arange(Sd, dtype=jnp.int32)[None], (B, Sd)
            ),
        }
    batch = {
        "tokens": tok(B, S),
        "labels": tok(B, S),
        "segment_ids": jnp.ones((B, S), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
    }
    if cfg.frontend == "vision":
        nv = min(cfg.frontend_tokens, S)
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, nv, cfg.d_model)) * 0.02, jnp.float32
        )
    return batch
