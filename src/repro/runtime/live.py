"""The live streaming backend: asyncio master/worker cluster for the IRM.

``run_live(stream, config)`` is the live counterpart of ``core.sim.simulate``
— same signature shape, same ``SimResult`` output — but instead of a
discrete-event model it runs a *real* concurrent system on the asyncio
event loop:

  - a ``Master`` broker holds the backlog in per-image FIFO queues and
    hands messages P2P to idle PEs;
  - a ``WorkerPool`` hosts PEs as asyncio tasks executing a pluggable
    payload (calibrated sleep, or a real JAX kernel per message);
  - a ``Lifecycle`` actuator boots/retires workers on the IRM's packing
    decisions, with the configured boot/start delays;
  - a control-loop task steps the *unmodified* ``IRM`` once per ``dt``
    against a ``LiveCluster`` view and records a ``SimResult``-compatible
    trace (``TraceRecorder``), and injects ``SimConfig.fail_worker_at``
    worker failures at their nominal tick exactly like the simulator
    (``Lifecycle.kill_worker``: PE tasks cancelled, in-flight messages
    requeued at the queue head, at-least-once).

Time: everything is expressed in scenario seconds; ``RuntimeConfig.
time_scale`` sets how many wall seconds one scenario second costs (see
``clock.ScaledClock``).  Ticks are stamped at their *nominal* times
``n * dt`` so IRM read-interval/cooldown gating matches the simulator;
message start/done times read the real (scaled) clock, which is where the
live backend's genuine concurrency jitter enters the record.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.irm import IRM, IRMConfig
from ..core.queues import HostRequest
from ..core.resources import Resources
from ..core.sim import SimConfig, SimResult, WorkerState
from ..core.workloads import Stream
from ..obs.audit import emit_packing_audit
from .clock import ScaledClock
from .lifecycle import Lifecycle
from .master import Master
from .payloads import make_payload
from .trace import TraceRecorder, measure_workers
from .transport import make_transport
from .worker import WorkerPool

__all__ = ["RuntimeConfig", "LiveCluster", "run_live"]


@dataclasses.dataclass
class RuntimeConfig:
    """Knobs specific to the live backend (cluster shape stays in SimConfig)."""

    # wall seconds per scenario second (0.02 → a 60 s scenario runs in 1.2 s)
    time_scale: float = 0.02
    # payload executed per message: "sleep" (calibrated) or "jax" (real kernel)
    payload: str = "sleep"
    payload_kwargs: Dict[str, object] = dataclasses.field(default_factory=dict)
    # where workers physically run: "inproc" (asyncio tasks on the master's
    # loop — zero-copy, the original backend) or "multiproc" (each worker a
    # real OS process with command/data queues; messages cross a pickle
    # boundary and per-worker CPU is *measured*, not emulated)
    transport: str = "inproc"
    transport_kwargs: Dict[str, object] = dataclasses.field(
        default_factory=dict
    )
    # what the profiler learns from on a multiproc transport: "emulated"
    # keeps the simulator's CPU-draw model (so packing decisions stay on
    # the sim's scale — the parity suites' contract) while real OS numbers
    # are still collected for the drift ledger; "os" feeds the real
    # measurements (time.thread_time per message) to the unmodified
    # MasterProfiler instead, making the drift *act* on decisions
    measurement: str = "emulated"
    # how often a vector-gated idle PE re-checks the blocked head (scenario
    # seconds); None → the control dt
    poll_interval: Optional[float] = None
    # The paper's threshold predictor can starve a sub-``queue_low`` tail
    # forever (see the synthetic scenario's ``nearly_completes`` note).  The
    # simulator burns simulated time to ``t_max`` in that state; burning
    # *wall* time would be pure waste, so the live driver exits early once
    # the cluster has provably stalled — arrivals closed, backlog static
    # below every trigger, zero PEs, and both IRM queues empty — for this
    # many scenario seconds.  ``None`` disables the early exit.
    starvation_grace: Optional[float] = 30.0


class LiveCluster:
    """``ClusterView`` implementation over the live master/worker state.

    The observation methods mirror ``core.sim.SimCluster`` line for line —
    same estimate caching, same accumulation order — so the IRM sees the
    same *kind* of cluster through both backends; only the dynamics behind
    the view differ (real tasks instead of event heaps).
    """

    def __init__(
        self,
        cfg: SimConfig,
        irm: IRM,
        master: Master,
        pool: WorkerPool,
        lifecycle: Lifecycle,
    ):
        self.cfg = cfg
        self.irm = irm
        self.master = master
        self.pool = pool
        self.lifecycle = lifecycle
        self._dims = tuple(cfg.resource_dims)
        self._multi = len(self._dims) > 1
        if self._multi:
            if self._dims[0] != "cpu":
                raise ValueError(
                    f"resource_dims[0] must be 'cpu', got {self._dims}"
                )
            irm.profiler.set_resource_dims(self._dims)

    # ---- ClusterView protocol ---------------------------------------------
    def queue_length(self) -> float:
        return self.master.queue_length()

    def queue_image_mix(self) -> Dict[str, float]:
        return self.master.queue_image_mix()

    def worker_scheduled_loads(self) -> List:
        est = self.irm.profiler.estimate
        cache: Dict[str, object] = {}
        if self._multi:
            D = len(self._dims)
            vout: List[Resources] = []
            for w in self.pool.workers:
                if w.state is WorkerState.OFF:
                    vout.append(Resources(self._dims, np.zeros(D)))
                    continue
                load = np.zeros(D)
                for pe in w.pes:
                    img = pe.image
                    v = cache.get(img)
                    if v is None:
                        v = cache[img] = est(img).values
                    load = load + v
                vout.append(Resources(self._dims, load))
            return vout
        out: List[float] = []
        for w in self.pool.workers:
            if w.state is WorkerState.OFF:
                out.append(0.0)
                continue
            load = 0.0
            for pe in w.pes:
                img = pe.image
                v = cache.get(img)
                if v is None:
                    v = cache[img] = est(img)
                load += v
            out.append(load)
        return out

    def backlog_resource_demand(self) -> Optional[Resources]:
        # The ROADMAP's decision-latency budget item: read the master's
        # incremental per-image counters (O(images)) instead of walking
        # the backlog head message by message — one estimate lookup and
        # one vector op per image class, not per queued message.  The
        # 64-message cap matches the sim's scan so the predictor sees the
        # same demand signal on both backends.
        if not self._multi:
            return None
        est = self.irm.profiler.estimate
        total: Optional[Resources] = None
        for img, cnt in self.master.backlog_image_counts(64):
            v = est(img) * cnt
            total = v if total is None else total + v
        return total

    def try_start_pe(self, req: HostRequest) -> bool:
        return self.pool.try_start_pe(req)

    def scale_workers(self, target: int) -> None:
        self.lifecycle.scale_workers(target)


async def _arrival_feed(
    stream: Stream, master: Master, clock: ScaledClock
) -> None:
    """Inject the stream's batches at their scheduled (virtual) times.

    Batches that are already due are pushed *without* awaiting, so the
    t=0 batch reaches the master before the control loop's first tick
    (the simulator likewise enqueues arrivals before measuring a tick) —
    otherwise the predictor's first read would see an empty queue and the
    next one a spurious rate-of-change spike.
    """
    try:
        for t_batch, msgs in sorted(stream.batches, key=lambda b: b[0]):
            if t_batch > clock.now():
                await clock.sleep_until(t_batch)
            for m in msgs:
                master.push_back(m)
    finally:
        master.close_arrivals()


async def _drive(
    stream: Stream,
    cfg: SimConfig,
    irm: IRM,
    rt: RuntimeConfig,
    stats: Optional[Dict[str, object]],
    bus=None,
) -> SimResult:
    clock = ScaledClock(rt.time_scale)
    total = stream.num_messages
    master = Master(total_expected=total, bus=bus)
    # construct the payload before starting the clock: JaxPayload warms the
    # jit cache at init, and that wall time must not burn virtual time
    payload = make_payload(rt.payload, **rt.payload_kwargs)
    poll = rt.poll_interval if rt.poll_interval is not None else cfg.dt
    if rt.measurement not in ("emulated", "os"):
        raise ValueError(
            f"measurement must be 'emulated' or 'os', got {rt.measurement!r}"
        )
    tkwargs = dict(rt.transport_kwargs)
    if rt.transport == "multiproc":
        tkwargs.setdefault("measurement", rt.measurement)
    elif rt.measurement != "emulated":
        raise ValueError(
            "measurement='os' requires transport='multiproc' (the in-process"
            " backend has no OS boundary to measure)"
        )
    transport = make_transport(rt.transport, **tkwargs)
    if hasattr(transport, "set_payload_spec"):
        # process-backed workers build their own payload instance
        transport.set_payload_spec(rt.payload, rt.payload_kwargs)
    pool = WorkerPool(cfg, master, clock, payload, poll_interval=poll,
                      transport=transport)
    lifecycle = Lifecycle(pool, cfg, clock)
    cluster = LiveCluster(cfg, irm, master, pool, lifecycle)
    recorder = TraceRecorder(cfg)
    rng = np.random.default_rng(cfg.seed)
    dims = tuple(cfg.resource_dims)

    clock.start()
    if bus is not None:
        # live event stamps read the real scaled clock; the nominal tick
        # rides along in the envelope's ``tick`` field
        bus.now = clock.now
        irm.packing_manager.audit = bus.audit
    transport.connect()  # data-channel consumer needs the running loop
    feeder = asyncio.get_running_loop().create_task(
        _arrival_feed(stream, master, clock), name="arrival-feed"
    )
    # let the feeder push the t=0 batches before the first control tick
    await asyncio.sleep(0)
    step_wall_ms: List[float] = []
    wall0 = time.perf_counter()
    try:
        t = 0.0
        last_report_t = -1e9
        stall_since: Optional[float] = None
        fail_at = cfg.fail_worker_at
        while t <= cfg.t_max:
            await clock.sleep_until(t)
            # fault injection precedes boot promotion, as in the sim's
            # tick; the hook re-arms each tick until the victim slot
            # exists (the sim retries the same way for a late worker)
            lifecycle.nominal_t = t
            if bus is not None:
                bus.tick = t
            if fail_at is not None and t >= fail_at[1] \
                    and fail_at[0] < len(pool.workers):
                lifecycle.kill_worker(fail_at[0])
                fail_at = None
            pool.promote_booted(t)
            # under measurement="os" the transport feeds real per-message
            # CPU to the probes; the emulated draws are still recorded in
            # the trace (drift stays observable) but must not double-feed
            measured_cpu, dim_measure = measure_workers(
                pool.workers, cfg, rng, dims,
                accumulate=rt.measurement == "emulated",
            )
            if t - last_report_t >= cfg.report_interval:
                for w in pool.workers:
                    if w.state is WorkerState.ACTIVE and w.pes:
                        report = w.probe.report()
                        if report:
                            if len(dims) > 1:
                                report = {
                                    img: Resources(dims, vec)
                                    for img, vec in report.items()
                                }
                            irm.ingest_report(report)
                last_report_t = t
            w0 = time.perf_counter()
            step_metrics = irm.step(t, cluster)
            step_wall_ms.append((time.perf_counter() - w0) * 1e3)
            if bus is not None:
                emit_packing_audit(bus, irm.config.allocator.algorithm,
                                   step_metrics.packing)
            recorder.record(
                t,
                measured_cpu,
                dim_measure,
                cluster.worker_scheduled_loads(),
                pool.workers,
                int(master.queue_length()),
                lifecycle.requested_target,
                master.backlog_head(64),
                irm.profiler.estimate,
            )
            if master.drained.is_set():
                break
            if (
                rt.starvation_grace is not None
                and master.arrivals_closed
                and master.queue_length() > 0
                and pool.pe_count() == 0
                and len(irm.container_queue) == 0
                and len(irm.allocation_queue) == 0
            ):
                if stall_since is None:
                    stall_since = t
                elif t - stall_since >= rt.starvation_grace:
                    break  # predictor-starved tail: nothing can ever change
            else:
                stall_since = None
            t = round(t + cfg.dt, 9)
    finally:
        feeder.cancel()
        await asyncio.gather(feeder, return_exceptions=True)
        await pool.shutdown()

    if stats is not None:
        wall_s = time.perf_counter() - wall0
        arr = np.asarray(step_wall_ms) if step_wall_ms else np.zeros(1)
        stats.update(
            wall_s=wall_s,
            ticks=len(step_wall_ms),
            irm_step_ms_mean=float(arr.mean()),
            irm_step_ms_p50=float(np.percentile(arr, 50)),
            irm_step_ms_p99=float(np.percentile(arr, 99)),
            messages_per_s=len(master.completed) / max(wall_s, 1e-9),
            transport=transport.stats(),
        )
    return recorder.finalize(
        completed=len(master.completed),
        total=total,
        makespan=master.max_done_t,
        messages=[m for _, b in stream.batches for m in b],
        requeued=master.requeued,
    )


def run_live(
    stream: Stream,
    config: Optional[SimConfig] = None,
    irm: Optional[IRM] = None,
    irm_config: Optional[IRMConfig] = None,
    runtime: Optional[RuntimeConfig] = None,
    stats: Optional[Dict[str, object]] = None,
    bus=None,
) -> SimResult:
    """Run the IRM against a workload stream on the live asyncio runtime.

    Same contract as ``core.sim.simulate``: passing an existing ``irm``
    keeps its profiler state across runs (the paper's persistent-profile
    experiment); the returned ``SimResult`` feeds the same summaries,
    expectations, and figure dumps.  ``stats``, when given, is filled with
    wall-clock throughput and IRM decision-latency numbers
    (``benchmarks/runtime_throughput.py`` reads them).
    """
    cfg = config or SimConfig()
    if irm is None:
        irm = IRM(irm_config or IRMConfig())
    else:
        irm.begin_run()
    rt = runtime or RuntimeConfig()
    return asyncio.run(_drive(stream, cfg, irm, rt, stats, bus=bus))
