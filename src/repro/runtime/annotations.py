"""Thread-affinity annotation vocabulary for the live runtime.

PR 7 split the runtime across a real OS-process boundary, which turned
two prose invariants into load-bearing facts:

- **loop-only** code runs exclusively on the master's asyncio event-loop
  thread.  Everything that mutates the master-side mirrors (``LivePE``
  state, ``Master``'s queues) must be loop-only — that is *why* the
  runtime needs no locks.
- **worker-side** code runs inside a worker OS process (or one of its PE
  threads).  It may block freely (``queue.Queue.get``, ``time.sleep``,
  the payload's ``run_sync``) but must never touch master-side state.

These decorators make the affinity machine-readable.  They are identity
decorators at runtime — zero overhead, no wrapping — but
``repro.analysis`` (the AST invariant checker) consumes them statically:

- rule R1 (blocking-in-async) exempts ``@worker_side`` bodies and
  ``@loop_only(blocking="reason")`` sections from the no-blocking-calls
  scan, and flags loop-reachable code that calls into ``@worker_side``;
- rule R2 (affinity) requires every mirror/queue mutation and every
  data-channel read to sit in a ``@loop_only`` (or ``async def``)
  function, and forbids them inside ``@worker_side``.

``@loop_only`` takes an optional ``blocking=`` reason for the few
deliberate places where the loop thread *does* block — e.g. the
transport's kill path, whose synchronous data-channel tail-drain is
exactly what makes a worker kill race-free.  The reason string is
mandatory when the keyword is used (the checker rejects an empty one):
an annotated blocking section must say why freezing the loop is safe.

``@transition`` extends the vocabulary to the delivery protocol itself:
it declares which entity state machine (message / worker slot / PE) a
function advances, on which event, from which source states to which
destination.  Rule R7 extracts these declarations, verifies each against
AST evidence in the same function (a matching ``bus.emit`` literal or a
``PEState``/``WorkerState`` mirror assignment), assembles the per-entity
machines, and pins them in ``protocol_manifest.json`` — which the model
checker explores and rule R8 replays against recorded event logs.
"""

from __future__ import annotations

from typing import Callable, Optional, TypeVar, overload

__all__ = ["loop_only", "worker_side", "transition"]

F = TypeVar("F", bound=Callable)


@overload
def loop_only(fn: F) -> F: ...


@overload
def loop_only(*, blocking: str) -> Callable[[F], F]: ...


def loop_only(fn: Optional[F] = None, *, blocking: Optional[str] = None):
    """Mark a function as event-loop-thread-only.

    Bare ``@loop_only`` declares "this runs on the loop thread and never
    blocks it".  ``@loop_only(blocking="why it is safe")`` additionally
    declares a deliberate blocking section on the loop thread — the
    checker allows blocking primitives inside it but requires the reason.
    """

    def mark(f: F) -> F:
        f.__loop_only__ = True
        f.__loop_blocking_reason__ = blocking
        return f

    if fn is not None:
        return mark(fn)
    return mark


def worker_side(fn: F) -> F:
    """Mark a function as running inside a worker process / PE thread.

    Worker-side code may block (that thread *is* the worker's CPU) but
    must never mutate master-side mirrors or call ``@loop_only`` code.
    Nested ``def``s inherit the annotation — a thread target defined
    inside a ``@worker_side`` entry point is worker-side too.
    """
    fn.__worker_side__ = True
    return fn


def transition(
    entity: str,
    event: str,
    src: str,
    dst: str,
    *,
    failing: bool = False,
    scope: Optional[str] = None,
) -> Callable[[F], F]:
    """Declare a protocol state-machine transition this function performs.

    ``entity`` is ``"msg"``, ``"worker"``, or ``"pe"``; ``event`` is
    either a pinned observability event type (contains a dot, e.g.
    ``"msg.pulled"``) or an *internal* transition name without one (e.g.
    ``"ready"`` — a state change that produces no event, used by the
    trace-conformance replay as an ε-edge).  ``src`` lists the allowed
    source states, ``|``-separated; ``dst`` is the single destination.

    ``failing=True`` marks a failure edge: the replay treats the instance
    as dead afterwards (a failed worker slot is never rebooted, so any
    later event for it is a violation).  ``scope="worker"`` widens a PE
    transition to every PE owned by the event's worker (a worker kill
    stops all its PEs at once).

    Identity decorator, stackable; rule R7 cross-checks each declaration
    against AST evidence in the decorated function and fails on stale or
    missing declarations, so the stack next to the code *is* the
    committed protocol.
    """

    def mark(f: F) -> F:
        declared = list(getattr(f, "__protocol_transitions__", ()))
        declared.append(
            {
                "entity": entity,
                "event": event,
                "src": src.split("|"),
                "dst": dst,
                "failing": failing,
                "scope": scope,
            }
        )
        f.__protocol_transitions__ = declared
        return f

    return mark
