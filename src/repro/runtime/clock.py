"""Scaled virtual clock for the live runtime.

The live runtime executes on the asyncio event loop in *wall-clock* time,
but every scenario, delay, and IRM threshold in this repo is expressed in
*scenario seconds* (the paper's SNIC-testbed time base).  ``ScaledClock``
maps between the two: one scenario second costs ``time_scale`` wall
seconds, so a 60-scenario-second smoke run with ``time_scale=0.02``
finishes in ~1.2 s of wall time while keeping every *relative* delay —
worker boot vs. PE start vs. message service time — exactly as configured.

All runtime components speak scenario seconds; only ``sleep``/``wait``
touch the wall.  This is the same trick HarmonicIO-style benchmark
harnesses use to compress hours-long streams into CI-sized runs without
changing the scheduling dynamics under test.
"""

from __future__ import annotations

import asyncio
import time
from typing import Optional, Tuple

__all__ = ["ScaledClock"]


class ScaledClock:
    """Virtual time over the running asyncio loop.

    ``now()`` returns scenario seconds since ``start()``; ``sleep(d)``
    suspends the calling task for ``d`` scenario seconds (``d *
    time_scale`` wall seconds).  Must be started inside a running loop.
    """

    def __init__(self, time_scale: float = 0.02):
        if time_scale <= 0:
            raise ValueError(f"time_scale must be positive, got {time_scale}")
        self.time_scale = float(time_scale)
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._t0: float = 0.0
        self._mono0: float = 0.0

    def start(self) -> None:
        """Anchor virtual t=0 at the current loop time."""
        self._loop = asyncio.get_running_loop()
        self._t0 = self._loop.time()
        # cross-process anchor: CLOCK_MONOTONIC is system-wide, so a worker
        # OS process can reconstruct this clock from (mono0, time_scale)
        # and stamp messages on the same scenario time base (see
        # ``transport.MultiprocTransport``)
        self._mono0 = time.monotonic()

    def anchor(self) -> Tuple[float, float]:
        """(monotonic t=0, time_scale) — enough to rebuild the clock in
        another process via ``(time.monotonic() - mono0) / time_scale``."""
        assert self._loop is not None, "ScaledClock.start() not called"
        return self._mono0, self.time_scale

    def now(self) -> float:
        """Scenario seconds elapsed since ``start()``."""
        assert self._loop is not None, "ScaledClock.start() not called"
        return (self._loop.time() - self._t0) / self.time_scale

    def to_wall(self, virtual_seconds: float) -> float:
        """Convert a scenario-seconds interval to wall seconds."""
        return virtual_seconds * self.time_scale

    async def sleep(self, virtual_seconds: float) -> None:
        """Suspend for ``virtual_seconds`` scenario seconds (>=0 yields)."""
        if virtual_seconds > 0:
            await asyncio.sleep(virtual_seconds * self.time_scale)
        else:
            await asyncio.sleep(0)

    async def sleep_until(self, virtual_t: float) -> None:
        """Sleep until the virtual clock reads ``virtual_t`` (no-op if past)."""
        await self.sleep(virtual_t - self.now())
