"""Live master: asyncio message broker with per-image FIFO queues.

The HarmonicIO master holds the stream backlog and hands messages directly
to idle PEs (P2P): a PE of image ``i`` asks for work and receives the
*globally first* queued message of that image.  This module reproduces the
master as an in-process asyncio broker with exactly the simulator's queue
structure — per-image FIFO deques keyed by a global arrival sequence number
(front re-inserts take decreasing negative numbers, i.e. ``insert(0, m)``
semantics) — so backlog observations (`queue_length`, `queue_image_mix`,
``backlog_head``) are defined identically on both backends.

Handoff is pull-based: PEs call ``pull`` (synchronous, single-threaded on
the event loop, so no locks) and park on a per-image ``asyncio.Event``
while their queue is empty.  Completion tracking lives here too: the
driver awaits ``drained`` instead of polling.  A pulled message is *in
flight* until it is either completed or requeued (worker failure) — the
drain check requires that count to hit zero, so a backlog that happens to
be empty while PEs still hold messages can never end the run early.
"""

from __future__ import annotations

import asyncio
import heapq
from collections import deque
from itertools import islice
from typing import Deque, Dict, List, Optional, Tuple

from ..core.workloads import Message
from .annotations import transition

__all__ = ["Master"]


class Master:
    """In-process asyncio broker: the live runtime's stream master."""

    def __init__(self, total_expected: int = 0, bus=None):
        # optional observability event bus; everything that holds a master
        # (pool, transports, lifecycle) reads it from here
        self.bus = bus
        self._img_queues: Dict[str, Deque[Tuple[int, Message]]] = {}
        self._qlen = 0
        self._seq_back = 0
        self._seq_front = 0
        self._events: Dict[str, asyncio.Event] = {}
        self.total_expected = int(total_expected)
        self.completed: List[Message] = []
        self.max_done_t = 0.0
        self.arrivals_closed = False
        self.drained = asyncio.Event()
        # messages pulled by a PE but neither completed nor requeued yet
        self.in_flight = 0
        # messages harvested from failed workers and re-inserted at the head
        self.requeued = 0

    # ---- enqueue ----------------------------------------------------------
    def _event(self, image: str) -> asyncio.Event:
        ev = self._events.get(image)
        if ev is None:
            ev = self._events[image] = asyncio.Event()
        return ev

    @transition("msg", "msg.enqueued", src="created", dst="enqueued")
    def push_back(self, m: Message) -> None:
        """Normal arrival: append in global FIFO order."""
        self._seq_back += 1
        dq = self._img_queues.get(m.image)
        if dq is None:
            dq = self._img_queues[m.image] = deque()
        dq.append((self._seq_back, m))
        self._qlen += 1
        self._event(m.image).set()
        if self.bus is not None:
            self.bus.emit("msg.enqueued", msg_id=m.msg_id, image=m.image,
                          arrival=m.arrival)

    def push_front(self, m: Message) -> None:
        """Head re-insert (failure requeue): ``list.insert(0, m)`` semantics."""
        self._seq_front -= 1
        dq = self._img_queues.get(m.image)
        if dq is None:
            dq = self._img_queues[m.image] = deque()
        dq.appendleft((self._seq_front, m))
        self._qlen += 1
        self._event(m.image).set()

    @transition("msg", "msg.requeued", src="pulled|started", dst="requeued")
    def requeue(self, m: Message) -> None:
        """Return an in-flight message to the queue head (worker failure).

        The simulator's at-least-once path: the message loses its start
        stamp, re-enters at the head with a decreasing negative sequence
        number, and stops counting as in flight.  ``requeued`` keeps the
        accounting the fault-parity suite compares across backends.
        """
        m.start_t = -1.0
        self.push_front(m)
        self.in_flight -= 1
        self.requeued += 1
        if self.bus is not None:
            self.bus.emit("msg.requeued", msg_id=m.msg_id, image=m.image)

    def close_arrivals(self) -> None:
        """No further pushes will come; enables drain detection."""
        self.arrivals_closed = True
        self._check_drained()

    # ---- backlog observation (identical shape to SimCluster) --------------
    def queue_length(self) -> float:
        return float(self._qlen)

    def _image_heads(self) -> List[Tuple[int, str, int]]:
        """(head seq, image, queued count) per non-empty image queue,
        sorted by each image's first occurrence in global FIFO order —
        the IRM's apportionment breaks ties by this order, same as the
        sim backend."""
        return sorted(
            (dq[0][0], img, len(dq))
            for img, dq in self._img_queues.items()
            if dq
        )

    def queue_image_mix(self) -> Dict[str, float]:
        if self._qlen == 0:
            return {}
        n = float(self._qlen)
        return {img: cnt / n for _, img, cnt in self._image_heads()}

    def backlog_head(self, k: int) -> List[Message]:
        """The first ``k`` queued messages in global FIFO order."""
        if self._qlen == 0 or k <= 0:
            return []
        live = [iter(dq) for dq in self._img_queues.values() if dq]
        if len(live) == 1:
            return [m for _, m in islice(live[0], k)]
        return [m for _, m in islice(heapq.merge(*live), k)]

    def backlog_image_counts(self, k: int) -> List[Tuple[str, int]]:
        """Per-image counts of the first ``min(k, len)`` backlog messages.

        Ordered by each image's first occurrence in global FIFO order (the
        same insertion order as ``queue_image_mix``).  While the whole
        backlog fits in ``k`` — the steady-state case — the per-image
        deque lengths (maintained O(1) by every push/pull/requeue) answer
        directly, O(images) instead of a k-message scan; only a deeper
        backlog walks sequence numbers, and even then no per-message
        estimate lookups happen downstream.
        """
        if self._qlen == 0 or k <= 0:
            return []
        if self._qlen <= k:
            return [(img, cnt) for _, img, cnt in self._image_heads()]
        counts: Dict[str, int] = {}
        for m in self.backlog_head(k):
            counts[m.image] = counts.get(m.image, 0) + 1
        return list(counts.items())

    # ---- P2P handoff ------------------------------------------------------
    def head(self, image: str) -> Optional[Message]:
        """Peek this image's FIFO head (head-blocking gates inspect it)."""
        dq = self._img_queues.get(image)
        return dq[0][1] if dq else None

    def pull(self, image: str) -> Optional[Message]:
        """Pop this image's FIFO head; clears the wakeup when it empties."""
        dq = self._img_queues.get(image)
        if not dq:
            return None
        _, m = dq.popleft()
        self._qlen -= 1
        self.in_flight += 1
        if not dq:
            self._event(image).clear()
        return m

    async def wait_for_work(self, image: str, wall_timeout: float) -> None:
        """Park until a message of ``image`` arrives or the timeout passes."""
        ev = self._event(image)
        try:
            await asyncio.wait_for(ev.wait(), max(wall_timeout, 0.0))
        except asyncio.TimeoutError:
            pass

    # ---- completion -------------------------------------------------------
    def complete(self, msg: Message) -> None:
        self.completed.append(msg)
        self.in_flight -= 1
        if msg.done_t > self.max_done_t:
            self.max_done_t = msg.done_t
        self._check_drained()

    def _check_drained(self) -> None:
        # ``in_flight == 0`` is load-bearing: with ``total_expected``
        # unset (0) the completed-count condition is vacuously true, and
        # an empty backlog alone does not mean the work is done — pulled
        # messages live at PEs (or, during a worker kill, briefly in the
        # harvester's hands) without being queued anywhere.
        if (
            self.arrivals_closed
            and self._qlen == 0
            and self.in_flight == 0
            and len(self.completed) >= self.total_expected
        ):
            self.drained.set()
