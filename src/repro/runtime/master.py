"""Live master: asyncio message broker with per-image FIFO queues.

The HarmonicIO master holds the stream backlog and hands messages directly
to idle PEs (P2P): a PE of image ``i`` asks for work and receives the
*globally first* queued message of that image.  This module reproduces the
master as an in-process asyncio broker with exactly the simulator's queue
structure — per-image FIFO deques keyed by a global arrival sequence number
(front re-inserts take decreasing negative numbers, i.e. ``insert(0, m)``
semantics) — so backlog observations (`queue_length`, `queue_image_mix`,
``backlog_head``) are defined identically on both backends.

Handoff is pull-based: PEs call ``pull`` (synchronous, single-threaded on
the event loop, so no locks) and park on a per-image ``asyncio.Event``
while their queue is empty.  Completion tracking lives here too: the
driver awaits ``drained`` instead of polling.
"""

from __future__ import annotations

import asyncio
import heapq
from collections import deque
from itertools import islice
from typing import Deque, Dict, List, Optional, Tuple

from ..core.workloads import Message

__all__ = ["Master"]


class Master:
    """In-process asyncio broker: the live runtime's stream master."""

    def __init__(self, total_expected: int = 0):
        self._img_queues: Dict[str, Deque[Tuple[int, Message]]] = {}
        self._qlen = 0
        self._seq_back = 0
        self._seq_front = 0
        self._events: Dict[str, asyncio.Event] = {}
        self.total_expected = int(total_expected)
        self.completed: List[Message] = []
        self.max_done_t = 0.0
        self.arrivals_closed = False
        self.drained = asyncio.Event()

    # ---- enqueue ----------------------------------------------------------
    def _event(self, image: str) -> asyncio.Event:
        ev = self._events.get(image)
        if ev is None:
            ev = self._events[image] = asyncio.Event()
        return ev

    def push_back(self, m: Message) -> None:
        """Normal arrival: append in global FIFO order."""
        self._seq_back += 1
        dq = self._img_queues.get(m.image)
        if dq is None:
            dq = self._img_queues[m.image] = deque()
        dq.append((self._seq_back, m))
        self._qlen += 1
        self._event(m.image).set()

    def push_front(self, m: Message) -> None:
        """Head re-insert (failure requeue): ``list.insert(0, m)`` semantics."""
        self._seq_front -= 1
        dq = self._img_queues.get(m.image)
        if dq is None:
            dq = self._img_queues[m.image] = deque()
        dq.appendleft((self._seq_front, m))
        self._qlen += 1
        self._event(m.image).set()

    def close_arrivals(self) -> None:
        """No further pushes will come; enables drain detection."""
        self.arrivals_closed = True
        self._check_drained()

    # ---- backlog observation (identical shape to SimCluster) --------------
    def queue_length(self) -> float:
        return float(self._qlen)

    def queue_image_mix(self) -> Dict[str, float]:
        # insertion order follows each image's first occurrence in global
        # FIFO order (deque-head sequence number) — the IRM's apportionment
        # breaks ties by this order, same as the sim backend.
        if self._qlen == 0:
            return {}
        heads = sorted(
            (dq[0][0], img, len(dq))
            for img, dq in self._img_queues.items()
            if dq
        )
        n = float(self._qlen)
        return {img: cnt / n for _, img, cnt in heads}

    def backlog_head(self, k: int) -> List[Message]:
        """The first ``k`` queued messages in global FIFO order."""
        if self._qlen == 0 or k <= 0:
            return []
        live = [iter(dq) for dq in self._img_queues.values() if dq]
        if len(live) == 1:
            return [m for _, m in islice(live[0], k)]
        return [m for _, m in islice(heapq.merge(*live), k)]

    # ---- P2P handoff ------------------------------------------------------
    def head(self, image: str) -> Optional[Message]:
        """Peek this image's FIFO head (head-blocking gates inspect it)."""
        dq = self._img_queues.get(image)
        return dq[0][1] if dq else None

    def pull(self, image: str) -> Optional[Message]:
        """Pop this image's FIFO head; clears the wakeup when it empties."""
        dq = self._img_queues.get(image)
        if not dq:
            return None
        _, m = dq.popleft()
        self._qlen -= 1
        if not dq:
            self._event(image).clear()
        return m

    async def wait_for_work(self, image: str, wall_timeout: float) -> None:
        """Park until a message of ``image`` arrives or the timeout passes."""
        ev = self._event(image)
        try:
            await asyncio.wait_for(ev.wait(), max(wall_timeout, 0.0))
        except asyncio.TimeoutError:
            pass

    # ---- completion -------------------------------------------------------
    def complete(self, msg: Message) -> None:
        self.completed.append(msg)
        if msg.done_t > self.max_done_t:
            self.max_done_t = msg.done_t
        self._check_drained()

    def _check_drained(self) -> None:
        if (
            self.arrivals_closed
            and self._qlen == 0
            and len(self.completed) >= self.total_expected
        ):
            self.drained.set()
