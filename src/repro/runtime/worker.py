"""Live workers: asyncio tasks hosting processing elements.

A ``LiveWorker`` models one worker VM (boot delay, per-image probe,
hosting capacity in resource fractions); each PE it hosts is a real
asyncio task running the pull-execute loop the paper describes:

    start delay → idle → P2P pull from the master → execute payload →
    idle → ... → idle-timeout self-termination

State enums are shared with the simulator (``core.sim.PEState`` /
``WorkerState``) so observation code — scheduled-load views, measurement,
trace recording — reads both backends with identical logic.  All state
mutation happens on the event loop thread; payload *compute* may run in
executor threads (see ``payloads.JaxPayload``) but completion bookkeeping
re-enters the loop.

Vector mode: non-CPU dimensions are rigid, so an idle PE only pulls while
its worker's *currently running* messages leave room in every auxiliary
dimension (the sim's congestion gate, restated over live BUSY PEs — the
live runtime cannot key on ``done_t > t`` because a running message's
completion time is unknown until the payload returns).  The FIFO head
blocks rather than being skipped, exactly as in the simulator.
"""

from __future__ import annotations

import asyncio
import heapq
from bisect import insort
from typing import Dict, List, Optional, Set, Tuple

from ..core.profiler import WorkerProbe
from ..core.queues import HostRequest
from ..core.sim import PEState, SimConfig, WorkerState
from ..core.workloads import Message
from .clock import ScaledClock
from .master import Master

__all__ = ["LivePE", "LiveWorker", "WorkerPool", "live_worker_fits_message"]


def live_worker_fits_message(pes, msg: Message, dims: Tuple[str, ...]) -> bool:
    """Rigid non-CPU gate over a live worker's *busy* PEs."""
    mres = msg.resources
    busy = PEState.BUSY
    for d in dims[1:]:
        need = mres.get(d, 0.0) if mres else 0.0
        committed = 0.0
        for pe in pes:
            pmsg = pe.msg
            if pe.state is busy and pmsg is not None and pmsg.resources:
                committed += pmsg.resources.get(d, 0.0)
        if committed + need > 1.0 + 1e-9:
            return False
    return True


class LivePE:
    """One processing element: state + the asyncio task driving it."""

    __slots__ = ("image", "state", "msg", "idle_since", "estimate", "uid",
                 "task")

    def __init__(self, image: str, estimate, uid: int):
        self.image = image
        self.state = PEState.STARTING
        self.msg: Optional[Message] = None
        self.idle_since = -1.0
        self.estimate = estimate  # size estimate at placement time (scheduled)
        self.uid = uid
        self.task: Optional[asyncio.Task] = None


class LiveWorker:
    """One worker VM: boots with a delay, hosts PE tasks, carries a probe."""

    __slots__ = ("idx", "state", "ready_t", "pes", "probe")

    def __init__(self, idx: int, t: float, boot_delay: float):
        self.idx = idx
        self.state = (
            WorkerState.BOOTING if boot_delay > 0 else WorkerState.ACTIVE
        )
        self.ready_t = t + boot_delay
        self.pes: List[LivePE] = []
        self.probe = WorkerProbe()


class WorkerPool:
    """Hosts workers and runs their PEs as asyncio tasks."""

    def __init__(
        self,
        cfg: SimConfig,
        master: Master,
        clock: ScaledClock,
        payload,
        poll_interval: float,
    ):
        self.cfg = cfg
        self.master = master
        self.clock = clock
        self.payload = payload
        # how often a gated (vector-blocked) idle PE re-checks the head,
        # in scenario seconds
        self.poll_interval = poll_interval
        self.workers: List[LiveWorker] = []
        self._dims = tuple(cfg.resource_dims)
        self._multi = len(self._dims) > 1
        self._pe_uid = 0
        self._tasks: Set[asyncio.Task] = set()
        # Fleet-scale indices, mirroring ``SimCluster``'s: every state
        # transition runs through the pool so per-tick queries
        # (promote_booted, n_alive, pe_count, the lifecycle's anti-churn
        # guard) cost O(transitions), not O(workers).
        #   _booting     idx -> ready_t for exactly the BOOTING workers
        #   _active_idx  sorted indices of ACTIVE workers (ascending scan
        #                order == the old full scan filtered to ACTIVE)
        #   _off_heap    min-heap of OFF slot indices; stale entries (slot
        #                rebooted meanwhile) are discarded lazily on peek
        self._booting: Dict[int, float] = {}
        self._active_idx: List[int] = []
        self._off_heap: List[int] = []
        self._n_alive = 0
        self._pe_total = 0

    # ---- lifecycle hooks (called by Lifecycle / the driver) ----------------
    def promote_booted(self, t: float) -> None:
        """BOOTING → ACTIVE once the boot delay has elapsed."""
        if not self._booting:
            return
        due = [idx for idx, rt in self._booting.items() if t >= rt]
        for idx in due:
            del self._booting[idx]
            self.workers[idx].state = WorkerState.ACTIVE
            insort(self._active_idx, idx)

    def n_alive(self) -> int:
        return self._n_alive

    def pe_count(self) -> int:
        return self._pe_total

    def boot_in_flight(self, t: float) -> bool:
        """True while any boot is genuinely pending (BOOTING, delay not
        yet elapsed) — the lifecycle's anti-churn predicate, answered from
        the booting index instead of a pool scan."""
        return any(t < rt for rt in self._booting.values())

    def active_indices(self) -> List[int]:
        """Sorted indices of ACTIVE workers (shared list — don't mutate)."""
        return self._active_idx

    # ---- scaling actuation (called by Lifecycle) ---------------------------
    def add_worker(self, t: float) -> LiveWorker:
        """Append a fresh worker slot and register it in the indices."""
        w = LiveWorker(len(self.workers), t, self.cfg.worker_boot_delay)
        self.workers.append(w)
        self._n_alive += 1
        if w.state is WorkerState.BOOTING:
            self._booting[w.idx] = w.ready_t
        else:  # zero boot delay: born ACTIVE
            insort(self._active_idx, w.idx)
        return w

    def lowest_off_slot(self) -> Optional[LiveWorker]:
        """Peek the lowest-index OFF slot without claiming it.

        The returned slot may belong to a *failed* worker — the caller
        decides (a failed lowest slot blocks reuse of higher OFF slots,
        exactly like the old ``next(w for w in workers if OFF)`` scan,
        because it stays at the top of the heap un-popped)."""
        heap = self._off_heap
        while heap:
            w = self.workers[heap[0]]
            if w.state is not WorkerState.OFF:
                heapq.heappop(heap)  # stale: slot was rebooted since
                continue
            return w
        return None

    def reboot_slot(self, w: LiveWorker, ready_t: float) -> None:
        """OFF → BOOTING on a slot returned by ``lowest_off_slot``."""
        assert self._off_heap and self._off_heap[0] == w.idx
        heapq.heappop(self._off_heap)
        w.state = WorkerState.BOOTING
        w.ready_t = ready_t
        self._booting[w.idx] = ready_t
        self._n_alive += 1

    def deactivate(self, w: LiveWorker) -> None:
        """ACTIVE → OFF (scale-down of an empty worker)."""
        w.state = WorkerState.OFF
        self._active_idx.remove(w.idx)
        heapq.heappush(self._off_heap, w.idx)
        self._n_alive -= 1

    def kill_worker(self, idx: int) -> List[Message]:
        """Abruptly terminate a worker: cancel its PE tasks, harvest the
        messages they were processing.

        The task-level mechanics of the sim's ``fail_worker_at`` failure:
        everything here mutates synchronously on the event-loop thread, so
        a BUSY PE is either still awaiting its payload (the cancellation
        lands there; its ``finally`` runs later against an already-emptied
        worker) or has already run its completion bookkeeping — a
        harvested message can never also complete.  Harvest order is PE
        order, matching the sim's one-by-one ``insert(0, m)`` sequence, so
        the last PE's message ends up globally first once requeued.
        """
        w = self.workers[idx]
        harvested: List[Message] = []
        for pe in list(w.pes):
            if pe.msg is not None:
                harvested.append(pe.msg)
                pe.msg = None
            pe.state = PEState.STOPPED
            if pe.task is not None and not pe.task.done():
                pe.task.cancel()
        # the cancelled tasks' ``finally`` blocks find an emptied ``pes``
        # list and skip their own removal, so the count is settled here
        self._pe_total -= len(w.pes)
        w.pes = []
        if w.state is not WorkerState.OFF:
            if w.state is WorkerState.ACTIVE:
                self._active_idx.remove(idx)
            else:  # BOOTING victim
                self._booting.pop(idx, None)
            self._n_alive -= 1
            heapq.heappush(self._off_heap, idx)
        w.state = WorkerState.OFF
        return harvested

    # ---- placement actuation ----------------------------------------------
    def try_start_pe(self, req: HostRequest) -> bool:
        """Start a PE on the placed worker; False while the VM still boots."""
        idx = req.target_worker
        if idx is None or idx >= len(self.workers):
            return False
        w = self.workers[idx]
        if w.state is not WorkerState.ACTIVE:
            return False  # "a new VM still initializing" (paper V-B.2)
        self._pe_uid += 1
        pe = LivePE(req.image, req.size_estimate, uid=self._pe_uid)
        w.pes.append(pe)
        self._pe_total += 1
        pe.task = asyncio.get_running_loop().create_task(
            self._pe_main(w, pe), name=f"pe-{w.idx}-{pe.uid}-{req.image}"
        )
        self._tasks.add(pe.task)
        pe.task.add_done_callback(self._tasks.discard)
        return True

    # ---- the PE loop -------------------------------------------------------
    def _gate_ok(self, worker: LiveWorker, msg: Message) -> bool:
        return not self._multi or live_worker_fits_message(
            worker.pes, msg, self._dims
        )

    async def _pe_main(self, worker: LiveWorker, pe: LivePE) -> None:
        cfg = self.cfg
        clock = self.clock
        master = self.master
        try:
            await clock.sleep(cfg.pe_start_delay)
            pe.state = PEState.IDLE
            pe.idle_since = clock.now()
            while True:
                head = master.head(pe.image)
                if head is not None and self._gate_ok(worker, head):
                    msg = master.pull(pe.image)
                    # single-threaded loop: the head cannot change between
                    # peek and pull without an await in between
                    assert msg is head
                    pe.state = PEState.BUSY
                    pe.msg = msg
                    msg.start_t = clock.now()
                    await self.payload(msg, clock)
                    msg.done_t = clock.now()
                    pe.msg = None
                    pe.state = PEState.IDLE
                    pe.idle_since = clock.now()
                    master.complete(msg)
                    continue
                remaining = cfg.container_idle_timeout - (
                    clock.now() - pe.idle_since
                )
                if remaining <= 0:
                    break  # graceful self-termination
                if head is not None:
                    # vector-gated head: poll (head-blocking FIFO — the
                    # blocked head is never skipped)
                    await clock.sleep(min(remaining, self.poll_interval))
                else:
                    await master.wait_for_work(
                        pe.image, clock.to_wall(remaining)
                    )
        except asyncio.CancelledError:
            pass  # driver shutdown: drop the PE silently
        finally:
            pe.state = PEState.STOPPED
            try:
                worker.pes.remove(pe)
            except ValueError:
                pass  # kill_worker already cleared the list (and the count)
            else:
                self._pe_total -= 1

    # ---- shutdown ----------------------------------------------------------
    async def shutdown(self) -> None:
        """Cancel and reap every outstanding PE task."""
        tasks = [t for t in self._tasks if not t.done()]
        for t in tasks:
            t.cancel()
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
