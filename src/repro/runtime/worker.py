"""Live workers: slot bookkeeping over a pluggable Transport.

A ``LiveWorker`` models one worker VM (boot delay, per-image probe,
hosting capacity in resource fractions); each PE it hosts runs the
pull-execute loop the paper describes:

    start delay → idle → P2P pull from the master → execute payload →
    idle → ... → idle-timeout self-termination

*Where* that loop physically runs is the transport's business
(``runtime.transport``): an asyncio task on the master's own loop
(``InProcTransport`` — the original backend, bit-identical) or a thread
inside a separate worker OS process (``MultiprocTransport``).  The pool
itself is transport-blind: it owns the worker slots, their state indices,
and the ``LivePE`` objects every observer reads — for a process-backed
worker those are master-side *mirrors* kept current by data-channel
events, but the observation code cannot tell the difference.

State enums are shared with the simulator (``core.sim.PEState`` /
``WorkerState``) so observation code — scheduled-load views, measurement,
trace recording — reads all backends with identical logic.  All state
mutation happens on the event loop thread; payload *compute* may run in
executor threads or worker processes, but completion bookkeeping
re-enters the loop.

Vector mode: non-CPU dimensions are rigid, so an idle PE only pulls while
its worker's *currently running* messages leave room in every auxiliary
dimension (the sim's congestion gate, restated over live BUSY PEs — the
live runtime cannot key on ``done_t > t`` because a running message's
completion time is unknown until the payload returns).  The FIFO head
blocks rather than being skipped, exactly as in the simulator.
"""

from __future__ import annotations

import heapq
from bisect import insort
from typing import Dict, List, Optional, Tuple

from ..core.profiler import WorkerProbe
from ..core.queues import HostRequest
from ..core.sim import PEState, SimConfig, WorkerState
from ..core.workloads import Message
from .annotations import loop_only, transition
from .clock import ScaledClock
from .master import Master
from .transport import InProcTransport, Transport

__all__ = ["LivePE", "LiveWorker", "WorkerPool", "live_worker_fits_message"]


def live_worker_fits_message(pes, msg: Message, dims: Tuple[str, ...]) -> bool:
    """Rigid non-CPU gate over a live worker's *busy* PEs."""
    mres = msg.resources
    busy = PEState.BUSY
    for d in dims[1:]:
        need = mres.get(d, 0.0) if mres else 0.0
        committed = 0.0
        for pe in pes:
            pmsg = pe.msg
            if pe.state is busy and pmsg is not None and pmsg.resources:
                committed += pmsg.resources.get(d, 0.0)
        if committed + need > 1.0 + 1e-9:
            return False
    return True


class LivePE:
    """One processing element: state + the asyncio task driving it."""

    __slots__ = ("image", "state", "msg", "idle_since", "estimate", "uid",
                 "task")

    def __init__(self, image: str, estimate, uid: int):
        self.image = image
        self.state = PEState.STARTING
        self.msg: Optional[Message] = None
        self.idle_since = -1.0
        self.estimate = estimate  # size estimate at placement time (scheduled)
        self.uid = uid
        self.task: Optional[asyncio.Task] = None


class LiveWorker:
    """One worker VM: boots with a delay, hosts PE tasks, carries a probe."""

    __slots__ = ("idx", "state", "ready_t", "pes", "probe")

    @transition("worker", "ready", src="booting", dst="active")
    def __init__(self, idx: int, t: float, boot_delay: float):
        self.idx = idx
        self.state = (
            WorkerState.BOOTING if boot_delay > 0 else WorkerState.ACTIVE
        )
        self.ready_t = t + boot_delay
        self.pes: List[LivePE] = []
        self.probe = WorkerProbe()


class WorkerPool:
    """Hosts worker slots; their PEs run wherever the transport puts them."""

    def __init__(
        self,
        cfg: SimConfig,
        master: Master,
        clock: ScaledClock,
        payload,
        poll_interval: float,
        transport: Optional[Transport] = None,
    ):
        self.cfg = cfg
        self.master = master
        self.clock = clock
        self.payload = payload
        # how often a gated (vector-blocked) idle PE re-checks the head,
        # in scenario seconds
        self.poll_interval = poll_interval
        self.transport = transport if transport is not None else InProcTransport()
        self.transport.bind(self)
        self.workers: List[LiveWorker] = []
        self._dims = tuple(cfg.resource_dims)
        self._multi = len(self._dims) > 1
        self._pe_uid = 0
        # Fleet-scale indices, mirroring ``SimCluster``'s: every state
        # transition runs through the pool so per-tick queries
        # (promote_booted, n_alive, pe_count, the lifecycle's anti-churn
        # guard) cost O(transitions), not O(workers).
        #   _booting     idx -> ready_t for exactly the BOOTING workers
        #   _active_idx  sorted indices of ACTIVE workers (ascending scan
        #                order == the old full scan filtered to ACTIVE)
        #   _off_heap    min-heap of OFF slot indices; stale entries (slot
        #                rebooted meanwhile) are discarded lazily on peek
        self._booting: Dict[int, float] = {}
        self._active_idx: List[int] = []
        self._off_heap: List[int] = []
        self._n_alive = 0
        self._pe_total = 0

    # ---- lifecycle hooks (called by Lifecycle / the driver) ----------------
    @loop_only
    @transition("worker", "worker.active", src="booting", dst="active")
    def promote_booted(self, t: float) -> None:
        """BOOTING → ACTIVE once the boot delay has elapsed."""
        if not self._booting:
            return
        due = [idx for idx, rt in self._booting.items() if t >= rt]
        bus = self.master.bus
        for idx in due:
            del self._booting[idx]
            self.workers[idx].state = WorkerState.ACTIVE
            insort(self._active_idx, idx)
            if bus is not None:
                bus.emit("worker.active", worker=idx)

    def n_alive(self) -> int:
        return self._n_alive

    def pe_count(self) -> int:
        return self._pe_total

    def boot_in_flight(self, t: float) -> bool:
        """True while any boot is genuinely pending (BOOTING, delay not
        yet elapsed) — the lifecycle's anti-churn predicate, answered from
        the booting index instead of a pool scan."""
        return any(t < rt for rt in self._booting.values())

    def active_indices(self) -> List[int]:
        """Sorted indices of ACTIVE workers (shared list — don't mutate)."""
        return self._active_idx

    # ---- scaling actuation (called by Lifecycle) ---------------------------
    @loop_only
    @transition("worker", "worker.boot", src="created", dst="booting")
    def add_worker(self, t: float) -> LiveWorker:
        """Append a fresh worker slot and register it in the indices."""
        w = LiveWorker(len(self.workers), t, self.cfg.worker_boot_delay)
        self.workers.append(w)
        self._n_alive += 1
        if w.state is WorkerState.BOOTING:
            self._booting[w.idx] = w.ready_t
        else:  # zero boot delay: born ACTIVE
            insort(self._active_idx, w.idx)
        if self.master.bus is not None:
            self.master.bus.emit("worker.boot", worker=w.idx,
                                 ready_t=w.ready_t)
        # provision the backing resource now so it overlaps the boot delay
        # (a process transport forks here; in-process this is a no-op)
        self.transport.start_worker(w)
        return w

    def lowest_off_slot(self) -> Optional[LiveWorker]:
        """Peek the lowest-index OFF slot without claiming it.

        The returned slot may belong to a *failed* worker — the caller
        decides (a failed lowest slot blocks reuse of higher OFF slots,
        exactly like the old ``next(w for w in workers if OFF)`` scan,
        because it stays at the top of the heap un-popped)."""
        heap = self._off_heap
        while heap:
            w = self.workers[heap[0]]
            if w.state is not WorkerState.OFF:
                heapq.heappop(heap)  # stale: slot was rebooted since
                continue
            return w
        return None

    @loop_only
    @transition("worker", "worker.boot", src="off", dst="booting")
    def reboot_slot(self, w: LiveWorker, ready_t: float) -> None:
        """OFF → BOOTING on a slot returned by ``lowest_off_slot``."""
        assert self._off_heap and self._off_heap[0] == w.idx
        heapq.heappop(self._off_heap)
        w.state = WorkerState.BOOTING
        w.ready_t = ready_t
        self._booting[w.idx] = ready_t
        self._n_alive += 1
        if self.master.bus is not None:
            self.master.bus.emit("worker.boot", worker=w.idx,
                                 ready_t=ready_t)
        self.transport.start_worker(w)

    @loop_only
    @transition("worker", "worker.deactivate", src="active", dst="off")
    def deactivate(self, w: LiveWorker) -> None:
        """ACTIVE → OFF (scale-down of an empty worker)."""
        w.state = WorkerState.OFF
        self._active_idx.remove(w.idx)
        heapq.heappush(self._off_heap, w.idx)
        self._n_alive -= 1
        if self.master.bus is not None:
            self.master.bus.emit("worker.deactivate", worker=w.idx)
        self.transport.stop_worker(w)

    @loop_only
    @transition("worker", "worker.kill", src="booting|active", dst="off",
                failing=True)
    def kill_worker(self, idx: int) -> List[Message]:
        """Abruptly terminate a worker and harvest the messages it was
        processing.

        The transport does the backend-specific demolition — cancelling
        PE tasks in-process, or SIGKILL + data-channel drain for a worker
        OS process — and returns exactly the in-flight messages that can
        provably never complete (a completion that already reached the
        master wins over harvesting, so a message can never do both).
        Harvest order is PE order, matching the sim's one-by-one
        ``insert(0, m)`` sequence, so the last PE's message ends up
        globally first once requeued.  Everything here runs synchronously
        on the event-loop thread.
        """
        w = self.workers[idx]
        harvested = self.transport.kill_worker(w)
        # any PE still listed belongs to the corpse: settle the count here
        # (an in-process cancelled task's ``finally`` finds the emptied
        # ``pes`` list and skips its own removal)
        self._pe_total -= len(w.pes)
        w.pes = []
        if w.state is not WorkerState.OFF:
            if w.state is WorkerState.ACTIVE:
                self._active_idx.remove(idx)
            else:  # BOOTING victim
                self._booting.pop(idx, None)
            self._n_alive -= 1
            heapq.heappush(self._off_heap, idx)
        w.state = WorkerState.OFF
        return harvested

    # ---- placement actuation ----------------------------------------------
    @loop_only
    @transition("pe", "pe.spawn", src="created", dst="starting")
    def try_start_pe(self, req: HostRequest) -> bool:
        """Start a PE on the placed worker; False while the VM still boots."""
        idx = req.target_worker
        if idx is None or idx >= len(self.workers):
            return False
        w = self.workers[idx]
        if w.state is not WorkerState.ACTIVE:
            return False  # "a new VM still initializing" (paper V-B.2)
        self._pe_uid += 1
        pe = LivePE(req.image, req.size_estimate, uid=self._pe_uid)
        w.pes.append(pe)
        self._pe_total += 1
        if self.master.bus is not None:
            self.master.bus.emit("pe.spawn", worker=idx, pe=pe.uid,
                                 image=req.image)
        self.transport.spawn_pe(w, pe)
        return True

    # ---- shared gate (both transports' pull paths run through this) --------
    def _gate_ok(self, worker: LiveWorker, msg: Message) -> bool:
        return not self._multi or live_worker_fits_message(
            worker.pes, msg, self._dims
        )

    # ---- shutdown ----------------------------------------------------------
    async def shutdown(self) -> None:
        """Tear down every PE/worker the transport still hosts."""
        await self.transport.close()
