"""Live workers: asyncio tasks hosting processing elements.

A ``LiveWorker`` models one worker VM (boot delay, per-image probe,
hosting capacity in resource fractions); each PE it hosts is a real
asyncio task running the pull-execute loop the paper describes:

    start delay → idle → P2P pull from the master → execute payload →
    idle → ... → idle-timeout self-termination

State enums are shared with the simulator (``core.sim.PEState`` /
``WorkerState``) so observation code — scheduled-load views, measurement,
trace recording — reads both backends with identical logic.  All state
mutation happens on the event loop thread; payload *compute* may run in
executor threads (see ``payloads.JaxPayload``) but completion bookkeeping
re-enters the loop.

Vector mode: non-CPU dimensions are rigid, so an idle PE only pulls while
its worker's *currently running* messages leave room in every auxiliary
dimension (the sim's congestion gate, restated over live BUSY PEs — the
live runtime cannot key on ``done_t > t`` because a running message's
completion time is unknown until the payload returns).  The FIFO head
blocks rather than being skipped, exactly as in the simulator.
"""

from __future__ import annotations

import asyncio
from typing import List, Optional, Set, Tuple

from ..core.profiler import WorkerProbe
from ..core.queues import HostRequest
from ..core.sim import PEState, SimConfig, WorkerState
from ..core.workloads import Message
from .clock import ScaledClock
from .master import Master

__all__ = ["LivePE", "LiveWorker", "WorkerPool", "live_worker_fits_message"]


def live_worker_fits_message(pes, msg: Message, dims: Tuple[str, ...]) -> bool:
    """Rigid non-CPU gate over a live worker's *busy* PEs."""
    mres = msg.resources
    busy = PEState.BUSY
    for d in dims[1:]:
        need = mres.get(d, 0.0) if mres else 0.0
        committed = 0.0
        for pe in pes:
            pmsg = pe.msg
            if pe.state is busy and pmsg is not None and pmsg.resources:
                committed += pmsg.resources.get(d, 0.0)
        if committed + need > 1.0 + 1e-9:
            return False
    return True


class LivePE:
    """One processing element: state + the asyncio task driving it."""

    __slots__ = ("image", "state", "msg", "idle_since", "estimate", "uid",
                 "task")

    def __init__(self, image: str, estimate, uid: int):
        self.image = image
        self.state = PEState.STARTING
        self.msg: Optional[Message] = None
        self.idle_since = -1.0
        self.estimate = estimate  # size estimate at placement time (scheduled)
        self.uid = uid
        self.task: Optional[asyncio.Task] = None


class LiveWorker:
    """One worker VM: boots with a delay, hosts PE tasks, carries a probe."""

    __slots__ = ("idx", "state", "ready_t", "pes", "probe")

    def __init__(self, idx: int, t: float, boot_delay: float):
        self.idx = idx
        self.state = (
            WorkerState.BOOTING if boot_delay > 0 else WorkerState.ACTIVE
        )
        self.ready_t = t + boot_delay
        self.pes: List[LivePE] = []
        self.probe = WorkerProbe()


class WorkerPool:
    """Hosts workers and runs their PEs as asyncio tasks."""

    def __init__(
        self,
        cfg: SimConfig,
        master: Master,
        clock: ScaledClock,
        payload,
        poll_interval: float,
    ):
        self.cfg = cfg
        self.master = master
        self.clock = clock
        self.payload = payload
        # how often a gated (vector-blocked) idle PE re-checks the head,
        # in scenario seconds
        self.poll_interval = poll_interval
        self.workers: List[LiveWorker] = []
        self._dims = tuple(cfg.resource_dims)
        self._multi = len(self._dims) > 1
        self._pe_uid = 0
        self._tasks: Set[asyncio.Task] = set()

    # ---- lifecycle hooks (called by Lifecycle / the driver) ----------------
    def promote_booted(self, t: float) -> None:
        """BOOTING → ACTIVE once the boot delay has elapsed."""
        for w in self.workers:
            if w.state is WorkerState.BOOTING and t >= w.ready_t:
                w.state = WorkerState.ACTIVE

    def n_alive(self) -> int:
        return sum(1 for w in self.workers if w.state is not WorkerState.OFF)

    def pe_count(self) -> int:
        return sum(len(w.pes) for w in self.workers)

    def kill_worker(self, idx: int) -> List[Message]:
        """Abruptly terminate a worker: cancel its PE tasks, harvest the
        messages they were processing.

        The task-level mechanics of the sim's ``fail_worker_at`` failure:
        everything here mutates synchronously on the event-loop thread, so
        a BUSY PE is either still awaiting its payload (the cancellation
        lands there; its ``finally`` runs later against an already-emptied
        worker) or has already run its completion bookkeeping — a
        harvested message can never also complete.  Harvest order is PE
        order, matching the sim's one-by-one ``insert(0, m)`` sequence, so
        the last PE's message ends up globally first once requeued.
        """
        w = self.workers[idx]
        harvested: List[Message] = []
        for pe in list(w.pes):
            if pe.msg is not None:
                harvested.append(pe.msg)
                pe.msg = None
            pe.state = PEState.STOPPED
            if pe.task is not None and not pe.task.done():
                pe.task.cancel()
        w.pes = []
        w.state = WorkerState.OFF
        return harvested

    # ---- placement actuation ----------------------------------------------
    def try_start_pe(self, req: HostRequest) -> bool:
        """Start a PE on the placed worker; False while the VM still boots."""
        idx = req.target_worker
        if idx is None or idx >= len(self.workers):
            return False
        w = self.workers[idx]
        if w.state is not WorkerState.ACTIVE:
            return False  # "a new VM still initializing" (paper V-B.2)
        self._pe_uid += 1
        pe = LivePE(req.image, req.size_estimate, uid=self._pe_uid)
        w.pes.append(pe)
        pe.task = asyncio.get_running_loop().create_task(
            self._pe_main(w, pe), name=f"pe-{w.idx}-{pe.uid}-{req.image}"
        )
        self._tasks.add(pe.task)
        pe.task.add_done_callback(self._tasks.discard)
        return True

    # ---- the PE loop -------------------------------------------------------
    def _gate_ok(self, worker: LiveWorker, msg: Message) -> bool:
        return not self._multi or live_worker_fits_message(
            worker.pes, msg, self._dims
        )

    async def _pe_main(self, worker: LiveWorker, pe: LivePE) -> None:
        cfg = self.cfg
        clock = self.clock
        master = self.master
        try:
            await clock.sleep(cfg.pe_start_delay)
            pe.state = PEState.IDLE
            pe.idle_since = clock.now()
            while True:
                head = master.head(pe.image)
                if head is not None and self._gate_ok(worker, head):
                    msg = master.pull(pe.image)
                    # single-threaded loop: the head cannot change between
                    # peek and pull without an await in between
                    assert msg is head
                    pe.state = PEState.BUSY
                    pe.msg = msg
                    msg.start_t = clock.now()
                    await self.payload(msg, clock)
                    msg.done_t = clock.now()
                    pe.msg = None
                    pe.state = PEState.IDLE
                    pe.idle_since = clock.now()
                    master.complete(msg)
                    continue
                remaining = cfg.container_idle_timeout - (
                    clock.now() - pe.idle_since
                )
                if remaining <= 0:
                    break  # graceful self-termination
                if head is not None:
                    # vector-gated head: poll (head-blocking FIFO — the
                    # blocked head is never skipped)
                    await clock.sleep(min(remaining, self.poll_interval))
                else:
                    await master.wait_for_work(
                        pe.image, clock.to_wall(remaining)
                    )
        except asyncio.CancelledError:
            pass  # driver shutdown: drop the PE silently
        finally:
            pe.state = PEState.STOPPED
            try:
                worker.pes.remove(pe)
            except ValueError:
                pass

    # ---- shutdown ----------------------------------------------------------
    async def shutdown(self) -> None:
        """Cancel and reap every outstanding PE task."""
        tasks = [t for t in self._tasks if not t.done()]
        for t in tasks:
            t.cancel()
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
