"""Pluggable PE payloads: what a live processing element *does* per message.

A payload is an async callable ``(msg, clock) -> None`` awaited by the PE
task while it holds the message; when it returns, the message is complete.
Two built-ins:

- ``sleep`` — a calibrated timed wait: the PE occupies its slot for exactly
  ``msg.duration`` scenario seconds, so service times mirror the stream
  generator's distributions and the live runtime's scheduling dynamics are
  directly comparable to the discrete-event simulator.
- ``jax`` — runs a real repro kernel (the grouped-matmul reference path,
  which executes on CPU) in a worker thread per message, then pads with a
  calibrated sleep up to ``msg.duration``.  This exercises genuine
  serialization/compute interleaving on the event loop: the master keeps
  brokering and the IRM keeps packing while XLA crunches.

Payloads resolve by name through ``make_payload`` so scenarios/CLI can
select them (``--payload jax``), mirroring ``core.binpack.make_packer``.
Each payload also exposes ``run_sync(msg, time_scale)``, the blocking
variant a process-backed transport executes on its worker-side PE threads
(``runtime.transport.MultiprocTransport``) — there the payload *is* the
worker's real, measurable CPU.
"""

from __future__ import annotations

import asyncio
import time
from typing import Callable, Dict

from .annotations import worker_side

__all__ = ["SleepPayload", "JaxPayload", "make_payload", "PAYLOADS"]


class SleepPayload:
    """Occupy the PE for ``msg.duration`` scenario seconds (timed wait)."""

    name = "sleep"

    async def __call__(self, msg, clock) -> None:
        await clock.sleep(msg.duration)

    @worker_side
    def run_sync(self, msg, time_scale: float) -> None:
        """Blocking variant for a transport's worker-process PE thread."""
        if msg.duration > 0:
            time.sleep(msg.duration * time_scale)


class JaxPayload:
    """Run a real JAX kernel per message, padded to ``msg.duration``.

    Each message triggers one grouped-matmul (``kernels.grouped_matmul.gmm``
    on its jnp reference path, so it runs on CPU without a TPU) in a thread
    executor — the event loop, master broker, and IRM stay live while the
    computation runs — then sleeps whatever remains of the message's
    scenario-time duration so the *schedule* stays calibrated to the
    stream's service-time distribution regardless of host speed.
    """

    name = "jax"

    def __init__(self, experts: int = 4, rows: int = 64, dim: int = 64):
        # Import here so the live runtime stays usable without jax installed
        # (the sleep payload has no such dependency).
        import jax.numpy as jnp
        import numpy as np

        from ..kernels.grouped_matmul.ops import gmm

        self._gmm = gmm
        rng = np.random.default_rng(0)
        self._x = jnp.asarray(
            rng.standard_normal((experts, rows, dim)), jnp.float32
        )
        self._w = jnp.asarray(
            rng.standard_normal((experts, dim, dim)), jnp.float32
        )
        self._sizes = jnp.full((experts,), rows, jnp.int32)
        self._compute()  # warm the jit cache outside any message's budget

    @worker_side
    def _compute(self) -> None:
        self._gmm(self._x, self._w, self._sizes, use_kernel=False).block_until_ready()

    async def __call__(self, msg, clock) -> None:
        loop = asyncio.get_running_loop()
        wall0 = time.perf_counter()
        await loop.run_in_executor(None, self._compute)
        spent_virtual = (time.perf_counter() - wall0) / clock.time_scale
        await clock.sleep(msg.duration - spent_virtual)

    @worker_side
    def run_sync(self, msg, time_scale: float) -> None:
        """Blocking variant for a transport's worker-process PE thread:
        the kernel runs on the PE thread itself (that *is* the worker's
        CPU now), then pads to the message's calibrated duration."""
        wall0 = time.perf_counter()
        self._compute()
        remaining = msg.duration * time_scale - (time.perf_counter() - wall0)
        if remaining > 0:
            time.sleep(remaining)


PAYLOADS: Dict[str, Callable[[], object]] = {
    "sleep": SleepPayload,
    "jax": JaxPayload,
}


def make_payload(name: str, **kwargs):
    """Resolve a payload by name (mirrors ``core.binpack.make_packer``)."""
    try:
        factory = PAYLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown payload {name!r}; available: {sorted(PAYLOADS)}"
        ) from None
    return factory(**kwargs)
