"""Worker lifecycle actuator: executes the IRM's scale decisions live.

``Lifecycle.scale_workers`` is the live counterpart of the simulator's
worker pool management and follows the same rules, so a packing run's
``target_workers`` produces the same pool trajectory on both backends:

  - the target is advisory and capped at ``max_workers`` (the paper's
    5-VM SNIC quota) — ``requested_target`` keeps the uncapped ask so
    Fig. 10's "IRM keeps requesting beyond the cap" behavior is visible;
  - scale-up reuses the lowest OFF slot before appending a new worker —
    unless that slot belongs to a *failed* worker (the sim never reboots
    a dead VM; the IRM routes around it instead); either way the worker
    boots with ``worker_boot_delay`` before it can host PEs (placements
    on it fail and TTL-requeue meanwhile);
  - scale-down deactivates only ACTIVE workers with *no* PEs, highest
    index first — PEs are never evicted, they idle out on their own.

``Lifecycle.kill_worker`` is the live port of the sim's ``fail_worker_at``
failure: the victim's PE tasks are cancelled, their in-flight messages
harvested and requeued at the master's queue head (``Master.requeue``:
negative-sequence front insert, at-least-once), and the slot is marked
failed so scale-up never resurrects it.  Placements already targeting the
dead worker fail ``try_start_pe`` and TTL-requeue through the container
queue — the paper's V-B.2 recovery loop, unchanged.
"""

from __future__ import annotations

from typing import Set

from ..core.sim import SimConfig
from .annotations import loop_only, transition
from .clock import ScaledClock
from .worker import WorkerPool

__all__ = ["Lifecycle"]


class Lifecycle:
    """Spawns and retires live workers on the IRM's packing decisions."""

    def __init__(self, pool: WorkerPool, cfg: SimConfig, clock: ScaledClock):
        self.pool = pool
        self.cfg = cfg
        self.clock = clock
        self.requested_target = 0
        self.failed: Set[int] = set()
        # The control tick this actuator is executing in.  The driver sets
        # it to the nominal tick time before each ``IRM.step`` — the same
        # time base ``promote_booted`` runs on — so boot stamps and the
        # anti-churn guard below can never disagree with boot promotion
        # when the event loop falls behind wall clock (the real scaled
        # clock may run ahead of the nominal tick under load).  The sim
        # stamps ``ready_t`` with tick time for the same reason.
        self.nominal_t = 0.0

    @loop_only
    @transition("worker", "worker.kill", src="booting|active", dst="off",
                failing=True)
    def kill_worker(self, idx: int) -> int:
        """Inject a worker failure; returns how many messages requeued.

        Mirrors ``SimCluster._inject_failure``: in-flight messages bounce
        back to the queue head one by one (the last PE's message ends up
        globally first), the worker goes OFF, and its slot is excluded
        from future scale-ups.  Idempotent: a second kill of the same
        slot is a no-op, as in the sim.
        """
        if not 0 <= idx < len(self.pool.workers) or idx in self.failed:
            return 0
        n_pes = len(self.pool.workers[idx].pes)
        harvested = self.pool.kill_worker(idx)
        self.failed.add(idx)
        for m in harvested:
            self.pool.master.requeue(m)
        bus = self.pool.master.bus
        if bus is not None:
            bus.emit("worker.kill", worker=idx, pes=n_pes,
                     requeued=len(harvested))
        return len(harvested)

    @loop_only
    def scale_workers(self, target: int) -> None:
        self.requested_target = target
        cfg = self.cfg
        pool = self.pool
        t = self.nominal_t
        capped = min(target, cfg.max_workers)
        n_alive = pool.n_alive()
        # boot additional workers: reuse the lowest OFF slot unless it is
        # a failed one (a dead lowest slot blocks reuse, matching the old
        # lowest-index scan — the pool never reboots past a corpse)
        while n_alive < capped:
            slot = pool.lowest_off_slot()
            if slot is not None and slot.idx not in self.failed:
                pool.reboot_slot(slot, t + cfg.worker_boot_delay)
            else:
                pool.add_worker(t)
            n_alive += 1
        # Deactivate empty workers above the target (highest index first).
        # Live-only anti-churn guard: scale-down is deferred while a boot
        # is genuinely in flight (BOOTING and younger than the boot
        # delay).  Boot completions are asynchronous here, so a packing
        # run can observe "5 alive, target 4" while four of the five are
        # still initializing and the only ACTIVE worker is the empty one —
        # deactivating it would park the whole pool behind a phantom bin
        # (placements First-Fit into the OFF slot and fail until TTL
        # death).  The tick-synchronized simulator cannot reach that
        # interleaving, so this guard does not diverge from it on any
        # pinned scenario; it only suppresses the live-concurrency race.
        # The age check keeps the guard honest under failure injection: a
        # BOOTING slot whose delay has already elapsed (a stale boot — it
        # will be promoted or was orphaned by a kill) must not pin the
        # pool at max size forever.
        if n_alive > capped and not pool.boot_in_flight(t):
            workers = pool.workers
            # descending active indices == the old reversed full scan
            # filtered to ACTIVE; copy because deactivate() mutates it
            for idx in reversed(list(pool.active_indices())):
                if n_alive <= capped:
                    break
                w = workers[idx]
                if not w.pes:
                    pool.deactivate(w)
                    n_alive -= 1
