"""Worker lifecycle actuator: executes the IRM's scale decisions live.

``Lifecycle.scale_workers`` is the live counterpart of the simulator's
worker pool management and follows the same rules, so a packing run's
``target_workers`` produces the same pool trajectory on both backends:

  - the target is advisory and capped at ``max_workers`` (the paper's
    5-VM SNIC quota) — ``requested_target`` keeps the uncapped ask so
    Fig. 10's "IRM keeps requesting beyond the cap" behavior is visible;
  - scale-up reuses the lowest OFF slot before appending a new worker;
    either way the worker boots with ``worker_boot_delay`` before it can
    host PEs (placements on it fail and TTL-requeue meanwhile);
  - scale-down deactivates only ACTIVE workers with *no* PEs, highest
    index first — PEs are never evicted, they idle out on their own.
"""

from __future__ import annotations

from ..core.sim import SimConfig, WorkerState
from .clock import ScaledClock
from .worker import LiveWorker, WorkerPool

__all__ = ["Lifecycle"]


class Lifecycle:
    """Spawns and retires live workers on the IRM's packing decisions."""

    def __init__(self, pool: WorkerPool, cfg: SimConfig, clock: ScaledClock):
        self.pool = pool
        self.cfg = cfg
        self.clock = clock
        self.requested_target = 0

    def scale_workers(self, target: int) -> None:
        self.requested_target = target
        cfg = self.cfg
        workers = self.pool.workers
        t = self.clock.now()
        capped = min(target, cfg.max_workers)
        n_alive = sum(1 for w in workers if w.state is not WorkerState.OFF)
        # boot additional workers
        while n_alive < capped:
            slot = next(
                (w for w in workers if w.state is WorkerState.OFF), None
            )
            if slot is not None:
                slot.state = WorkerState.BOOTING
                slot.ready_t = t + cfg.worker_boot_delay
            else:
                workers.append(
                    LiveWorker(len(workers), t, cfg.worker_boot_delay)
                )
            n_alive += 1
        # Deactivate empty workers above the target (highest index first).
        # Live-only anti-churn guard: scale-down is deferred while any
        # worker is still BOOTING.  Boot completions are asynchronous here,
        # so a packing run can observe "5 alive, target 4" while four of
        # the five are still initializing and the only ACTIVE worker is the
        # empty one — deactivating it would park the whole pool behind a
        # phantom bin (placements First-Fit into the OFF slot and fail
        # until TTL death).  The tick-synchronized simulator cannot reach
        # that interleaving, so this guard does not diverge from it on any
        # pinned scenario; it only suppresses the live-concurrency race.
        if n_alive > capped and not any(
            w.state is WorkerState.BOOTING for w in workers
        ):
            for w in reversed(workers):
                if n_alive <= capped:
                    break
                if w.state is WorkerState.ACTIVE and not w.pes:
                    w.state = WorkerState.OFF
                    n_alive -= 1
