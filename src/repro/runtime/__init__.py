"""Live streaming runtime: a real asyncio master/worker backend for the IRM.

The third ``ClusterView`` implementation (after the discrete-event
simulator and the serving engine): an in-process but genuinely concurrent
master/worker system — per-image FIFO broker, PE tasks running pluggable
payloads, lifecycle actuation with boot delays — that the *unmodified*
IRM schedules.  ``run_live`` mirrors ``core.sim.simulate`` and returns a
``SimResult``, so every scenario, summary metric, and expectation check
runs on either backend (``run_scenario(..., backend="live")``).

Master↔worker communication goes through an explicit ``Transport``
(``runtime.transport``): ``InProcTransport`` keeps the original
zero-copy asyncio semantics, ``MultiprocTransport`` promotes each worker
to an OS process behind pickled command/data queues
(``run_scenario(..., backend="multiproc")``).
"""

from .clock import ScaledClock
from .lifecycle import Lifecycle
from .live import LiveCluster, RuntimeConfig, run_live
from .master import Master
from .payloads import JaxPayload, SleepPayload, make_payload
from .trace import TraceRecorder
from .transport import (
    InProcTransport,
    MultiprocTransport,
    Transport,
    make_transport,
)
from .worker import LivePE, LiveWorker, WorkerPool

__all__ = [
    "ScaledClock",
    "Lifecycle",
    "LiveCluster",
    "RuntimeConfig",
    "run_live",
    "Master",
    "JaxPayload",
    "SleepPayload",
    "make_payload",
    "TraceRecorder",
    "Transport",
    "InProcTransport",
    "MultiprocTransport",
    "make_transport",
    "LivePE",
    "LiveWorker",
    "WorkerPool",
]
