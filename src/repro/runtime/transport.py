"""The master↔worker boundary as an explicit, swappable Transport layer.

The live runtime used to hard-wire its workers into the master's event
loop: PEs were asyncio tasks calling ``Master.pull``/``complete`` as plain
method calls, so serialization and transfer cost — which the HarmonicIO
benchmark comparison shows *dominate* streams of individual objects — were
structurally invisible, and per-worker CPU could only be emulated.  This
module re-cuts that boundary the way Pilot-Streaming separates the
resource broker from its compute units: everything that crosses between
the master's control plane and a worker travels through a ``Transport``,
and the rest of the runtime (``Master``, ``WorkerPool``, ``Lifecycle``,
the driver) no longer knows — or cares — where a worker physically runs.

Two channels per worker, mirroring the HarmonicIO wire protocol:

  - the **control channel** carries commands (``start_pe``, pull replies,
    ``stop``) from the master side to the worker;
  - the **data channel** carries worker→master traffic: pull requests,
    completed ``Message`` payloads, PE exits, and CPU measurements.

Two implementations:

``InProcTransport``
    The previous asyncio backend, repackaged: PEs are asyncio tasks on the
    master's own loop and both channels are direct method calls — zero
    copies, zero serialization.  Semantics are bit-identical to the
    pre-transport runtime (the parity and fault suites pin this), which is
    what makes it the refactor's control group.

``MultiprocTransport``
    Each worker is a real ``multiprocessing.Process``.  The control
    channel is an ``mp.Queue`` into the worker; the data channel is an
    ``mp.Queue`` back out, drained by a single poller task on the event
    loop (single-consumer by construction, so a worker kill can drain the
    tail of the data channel synchronously without racing a reader).
    Inside the process, PEs run on an in-process thread pool: each PE
    thread loops pull → execute payload → report completion, exactly the
    paper's processing-element loop, but with every message crossing a
    genuine OS boundary through ``pickle`` (`serialize`/`deserialize`
    hooks, byte- and time-accounted).  Workers measure *real* CPU —
    ``time.thread_time`` per message and ``os.times`` per process — so the
    gap between the paper's emulated profiler and actual OS measurement
    becomes a first-class number (``stats()["profiler_drift_pp"]``,
    benchmarked by ``benchmarks/transport_bench.py``).

The master-side mirror: the parent keeps a ``LivePE`` object per remote
PE (state, current message, placement estimate), updated from data-channel
events.  Everything that observes the cluster — scheduled-load views, the
emulated measurement model, trace recording, the vector congestion gate —
reads that mirror with the exact same code as the in-process backend, so
the IRM sees the same *kind* of cluster through every transport.
"""

from __future__ import annotations

import asyncio
import os
import pickle
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

from ..core.sim import PEState, WorkerState
from ..core.workloads import Message
from .annotations import loop_only, transition, worker_side

__all__ = [
    "Transport",
    "InProcTransport",
    "MultiprocTransport",
    "make_transport",
    "TRANSPORTS",
]


class Transport:
    """Interface between the master's control plane and its workers.

    A transport is *bound* to one ``WorkerPool`` (``bind``), told when the
    run's clock starts (``connect`` — the moment the loop exists), asked
    to host PEs (``spawn_pe``) on workers it was told to provision
    (``start_worker``/``stop_worker``), and finally torn down (``close``).
    ``kill_worker`` implements the abrupt-failure path and must preserve
    the at-least-once contract: it returns exactly the messages that were
    in flight at the victim and can provably no longer complete.
    """

    name = "abstract"

    def bind(self, pool) -> None:
        """Attach to a ``WorkerPool`` (gives access to master/clock/cfg)."""
        self.pool = pool

    def connect(self) -> None:
        """Called once inside the running loop, after ``clock.start()``."""

    def start_worker(self, worker) -> None:
        """Provision the backing resource for a (re)booted worker slot."""

    def stop_worker(self, worker) -> None:
        """Release a deactivated (scaled-down, PE-less) worker's backing."""

    def spawn_pe(self, worker, pe) -> None:
        """Start the pull-execute loop for a freshly placed PE."""
        raise NotImplementedError

    def kill_worker(self, worker) -> List[Message]:
        """Abruptly terminate a worker; return its harvested in-flight
        messages (completions that already reached the data channel are
        applied, not harvested — a message can never do both)."""
        raise NotImplementedError

    async def close(self) -> None:
        """Tear down every PE/worker this transport still hosts."""
        raise NotImplementedError

    # ---- serialization hooks (the data channel's wire format) -------------
    def serialize(self, msg: Message) -> bytes:
        return pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)

    def deserialize(self, blob: bytes) -> Message:
        return pickle.loads(blob)

    def stats(self) -> Dict[str, object]:
        """Wire-level counters (bytes, serialization time, CPU reports)."""
        return {"transport": self.name}


class InProcTransport(Transport):
    """Direct object handoff on the master's own event loop (zero-copy).

    This *is* the original asyncio backend: ``spawn_pe`` creates an
    asyncio task running the pull-execute loop against the master's plain
    method calls, and ``kill_worker`` harvests synchronously on the loop
    thread.  No bytes ever cross a boundary, so the serialize hooks go
    unused and ``stats()`` reports zeros.
    """

    name = "inproc"

    def __init__(self) -> None:
        self._tasks: set = set()

    def spawn_pe(self, worker, pe) -> None:
        pe.task = asyncio.get_running_loop().create_task(
            self._pe_main(worker, pe),
            name=f"pe-{worker.idx}-{pe.uid}-{pe.image}",
        )
        self._tasks.add(pe.task)
        pe.task.add_done_callback(self._tasks.discard)

    # ---- the PE loop (verbatim the pre-transport asyncio PE) --------------
    @transition("pe", "ready", src="starting", dst="idle")
    @transition("msg", "msg.pulled", src="enqueued|requeued", dst="pulled")
    @transition("pe", "msg.pulled", src="idle", dst="busy")
    @transition("msg", "msg.started", src="pulled", dst="started")
    @transition("msg", "msg.completed", src="started", dst="completed")
    @transition("pe", "msg.completed", src="busy", dst="idle")
    @transition("pe", "pe.exit", src="idle", dst="stopped")
    async def _pe_main(self, worker, pe) -> None:
        pool = self.pool
        cfg = pool.cfg
        clock = pool.clock
        master = pool.master
        bus = master.bus
        try:
            await clock.sleep(cfg.pe_start_delay)
            pe.state = PEState.IDLE
            pe.idle_since = clock.now()
            while True:
                head = master.head(pe.image)
                if head is not None and pool._gate_ok(worker, head):
                    msg = master.pull(pe.image)
                    # single-threaded loop: the head cannot change between
                    # peek and pull without an await in between
                    assert msg is head
                    pe.state = PEState.BUSY
                    pe.msg = msg
                    if bus is not None:
                        bus.emit("msg.pulled", msg_id=msg.msg_id,
                                 image=msg.image, worker=worker.idx,
                                 pe=pe.uid)
                    msg.start_t = clock.now()
                    if bus is not None:
                        bus.emit("msg.started", msg_id=msg.msg_id,
                                 image=msg.image, worker=worker.idx,
                                 pe=pe.uid)
                    await pool.payload(msg, clock)
                    msg.done_t = clock.now()
                    pe.msg = None
                    pe.state = PEState.IDLE
                    pe.idle_since = clock.now()
                    if bus is not None:
                        bus.emit("msg.completed", msg_id=msg.msg_id,
                                 image=msg.image, worker=worker.idx,
                                 pe=pe.uid, start_t=msg.start_t,
                                 done_t=msg.done_t, arrival=msg.arrival)
                    master.complete(msg)
                    continue
                remaining = cfg.container_idle_timeout - (
                    clock.now() - pe.idle_since
                )
                if remaining <= 0:
                    if bus is not None:
                        bus.emit("pe.exit", worker=worker.idx, pe=pe.uid,
                                 image=pe.image)
                    break  # graceful self-termination
                if head is not None:
                    # vector-gated head: poll (head-blocking FIFO — the
                    # blocked head is never skipped)
                    await clock.sleep(min(remaining, pool.poll_interval))
                else:
                    await master.wait_for_work(
                        pe.image, clock.to_wall(remaining)
                    )
        except asyncio.CancelledError:
            pass  # driver shutdown: drop the PE silently
        finally:
            pe.state = PEState.STOPPED
            try:
                worker.pes.remove(pe)
            except ValueError:
                pass  # kill_worker already cleared the list (and the count)
            else:
                pool._pe_total -= 1

    @loop_only
    @transition("pe", "worker.kill", src="starting|idle|busy", dst="stopped",
                scope="worker")
    def kill_worker(self, worker) -> List[Message]:
        """Cancel the victim's PE tasks synchronously on the loop thread.

        A BUSY PE is either still awaiting its payload (the cancellation
        lands there; its ``finally`` runs later against an already-emptied
        worker) or has already run its completion bookkeeping — a
        harvested message can never also complete.  Harvest order is PE
        order, matching the sim's one-by-one ``insert(0, m)`` sequence.
        """
        harvested: List[Message] = []
        for pe in list(worker.pes):
            if pe.msg is not None:
                harvested.append(pe.msg)
                pe.msg = None
            pe.state = PEState.STOPPED
            if pe.task is not None and not pe.task.done():
                pe.task.cancel()
        return harvested

    async def close(self) -> None:
        tasks = [t for t in self._tasks if not t.done()]
        for t in tasks:
            t.cancel()
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)

    def stats(self) -> Dict[str, object]:
        return {
            "transport": self.name,
            "data_msgs_out": 0,
            "data_msgs_in": 0,
            "data_bytes_out": 0,
            "data_bytes_in": 0,
            "serialize_ms": 0.0,
        }


# ---------------------------------------------------------------------------
# Multiprocess transport
# ---------------------------------------------------------------------------

# data-channel event tags (worker → master)
_EV_READY = 0      # (tag, pe_uid) — PE finished its start delay
_EV_PULL = 1       # (tag, pe_uid, image, decode_ms)
_EV_COMPLETE = 2   # (tag, pe_uid, blob, start_t, done_t, cpu_s, encode_ms,
#                     proc_cpu_s)
_EV_PE_EXIT = 3    # (tag, pe_uid) — idle-timeout self-termination
_EV_METRICS = 4    # (tag, pe_uid, registry_delta) — mergeable metrics flush

# control-channel command tags (master → worker)
_CMD_START_PE = 0  # (tag, pe_uid, image)
_CMD_REPLY = 1     # (tag, pe_uid, blob_or_None)
_CMD_STOP = 2      # (tag,)


def _proc_cpu_seconds() -> float:
    t = os.times()
    return t.user + t.system


@worker_side
def _mp_worker_main(
    widx: int,
    cmd_q,
    data_q,
    time_scale: float,
    mono0: float,
    pe_start_delay: float,
    idle_timeout: float,
    poll_interval: float,
    payload_spec: Tuple[str, dict],
    obs_enabled: bool = False,
) -> None:
    """Entry point of one worker process.

    The main thread is a dispatcher: it reads control-channel commands and
    routes pull replies to the PE threads.  Each PE is a thread running
    the paper's pull-execute loop against the data channel; message
    payloads execute synchronously on the PE thread (that *is* the
    worker's CPU), measured with ``time.thread_time`` per message and
    ``os.times`` per process.
    """
    from .payloads import make_payload

    payload = make_payload(payload_spec[0], **payload_spec[1])
    cpu0 = _proc_cpu_seconds()
    stop = threading.Event()
    replies: Dict[int, "queue.Queue"] = {}

    def now() -> float:
        return (time.monotonic() - mono0) / time_scale

    def _pe_thread(uid: int, image: str) -> None:
        # Per-thread metrics registry: deltas are flushed over the data
        # channel *before* the completion they describe, so FIFO ordering
        # guarantees the master's merged counters equal the applied
        # completions exactly at a clean drain, and overshoot by at most
        # the killed worker's unflushed in-flight messages under SIGKILL.
        reg = None
        if obs_enabled:
            from ..obs.metrics import MetricsRegistry

            reg = MetricsRegistry()
        time.sleep(pe_start_delay * time_scale)
        data_q.put((_EV_READY, uid))
        idle_since = now()
        while not stop.is_set():
            data_q.put((_EV_PULL, uid, image))
            try:
                blob = replies[uid].get(timeout=1.0)
            except queue.Empty:
                continue  # master is slow or shutting down; re-check stop
            if blob is None:
                remaining = idle_timeout - (now() - idle_since)
                if remaining <= 0:
                    data_q.put((_EV_PE_EXIT, uid))
                    return  # graceful self-termination
                time.sleep(min(remaining, poll_interval) * time_scale)
                continue
            w0 = time.perf_counter()
            msg = pickle.loads(blob)
            decode_ms = (time.perf_counter() - w0) * 1e3
            start_t = now()
            tcpu0 = time.thread_time()
            payload.run_sync(msg, time_scale)
            cpu_s = time.thread_time() - tcpu0
            done_t = now()
            msg.start_t = start_t
            msg.done_t = done_t
            w0 = time.perf_counter()
            out = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
            encode_ms = (time.perf_counter() - w0) * 1e3 + decode_ms
            if reg is not None:
                reg.counter("worker.msgs_completed").inc()
                reg.counter("worker.payload_cpu_s").inc(cpu_s)
                reg.histogram("worker.service_s").observe(done_t - start_t)
                # flush BEFORE the completion (see the registry note above)
                data_q.put((_EV_METRICS, uid, reg.delta()))
            data_q.put((
                _EV_COMPLETE, uid, out, start_t, done_t, cpu_s, encode_ms,
                _proc_cpu_seconds() - cpu0,
            ))
            idle_since = now()

    threads: List[threading.Thread] = []
    while True:
        try:
            cmd = cmd_q.get(timeout=0.5)
        except queue.Empty:
            if stop.is_set():
                break
            continue
        tag = cmd[0]
        if tag == _CMD_START_PE:
            uid, image = cmd[1], cmd[2]
            replies[uid] = queue.Queue()
            th = threading.Thread(
                target=_pe_thread, args=(uid, image),
                name=f"pe-{widx}-{uid}", daemon=True,
            )
            threads.append(th)
            th.start()
        elif tag == _CMD_REPLY:
            rq = replies.get(cmd[1])
            if rq is not None:
                rq.put(cmd[2])
        elif tag == _CMD_STOP:
            stop.set()
            break
    for th in threads:
        th.join(timeout=1.0)


class _ProcHandle:
    """Master-side bookkeeping for one worker process."""

    __slots__ = ("proc", "cmd_q", "data_q", "pes", "proc_cpu_s")

    def __init__(self, proc, cmd_q, data_q):
        self.proc = proc
        self.cmd_q = cmd_q
        self.data_q = data_q
        self.pes: Dict[int, object] = {}  # pe_uid -> LivePE mirror
        self.proc_cpu_s = 0.0  # latest os.times() user+sys delta reported


class MultiprocTransport(Transport):
    """Workers as OS processes with command/data queues per worker.

    The poller task is the data channels' *only* consumer in steady state
    and runs on the event loop thread; ``kill_worker`` also drains on the
    loop thread, so the two can never race (no executor threads touch the
    queues).  Completion bookkeeping therefore happens exactly where the
    in-process backend does it — on the loop — just triggered by wire
    events instead of awaited coroutines.
    """

    name = "multiproc"

    def __init__(
        self,
        start_method: Optional[str] = None,
        poll_wall: float = 0.002,
        measurement: str = "emulated",
    ):
        import multiprocessing as mp

        if start_method is None:
            start_method = (
                "fork" if "fork" in mp.get_all_start_methods() else "spawn"
            )
        self._ctx = mp.get_context(start_method)
        self.start_method = start_method
        self.poll_wall = float(poll_wall)
        if measurement not in ("emulated", "os"):
            raise ValueError(
                f"measurement must be 'emulated' or 'os', got {measurement!r}"
            )
        self.measurement = measurement
        self._procs: Dict[int, _ProcHandle] = {}
        self._retired: List[_ProcHandle] = []
        self._poller: Optional[asyncio.Task] = None
        self._payload_spec: Tuple[str, dict] = ("sleep", {})
        # wire counters (the data channel's serialization ledger)
        self.data_msgs_out = 0   # master → worker message payloads
        self.data_msgs_in = 0    # worker → master completed payloads
        self.data_bytes_out = 0
        self.data_bytes_in = 0
        self.serialize_ms = 0.0  # encode+decode, both sides, both directions
        self.workers_spawned = 0
        # measured-vs-emulated CPU ledger (per completed message)
        self._drift_sum_pp = 0.0
        self._drift_n = 0
        self._real_core_s = 0.0      # Σ thread-CPU seconds across messages
        self._emulated_core_s = 0.0  # Σ cpu_cores · duration (the model)
        self.proc_cpu_s_total = 0.0  # Σ os.times() deltas across processes

    # ---- provisioning ------------------------------------------------------
    def set_payload_spec(self, name: str, kwargs: dict) -> None:
        """What each worker process should construct as its PE payload."""
        self._payload_spec = (name, dict(kwargs))

    def connect(self) -> None:
        self._poller = asyncio.get_running_loop().create_task(
            self._poll_loop(), name="transport-poller"
        )

    @loop_only
    def start_worker(self, worker) -> None:
        pool = self.pool
        cfg = pool.cfg
        clock = pool.clock
        cmd_q = self._ctx.Queue()
        data_q = self._ctx.Queue()
        mono0, time_scale = clock.anchor()
        proc = self._ctx.Process(
            target=_mp_worker_main,
            args=(
                worker.idx, cmd_q, data_q, time_scale, mono0,
                cfg.pe_start_delay, cfg.container_idle_timeout,
                pool.poll_interval, self._payload_spec,
                pool.master.bus is not None,
            ),
            name=f"irm-worker-{worker.idx}",
            daemon=True,
        )
        proc.start()
        self._procs[worker.idx] = _ProcHandle(proc, cmd_q, data_q)
        self.workers_spawned += 1

    @loop_only
    def stop_worker(self, worker) -> None:
        # scale-down only retires PE-less workers, so the data channel is
        # quiet; park the handle for close() to join
        h = self._procs.pop(worker.idx, None)
        if h is not None:
            h.cmd_q.put_nowait((_CMD_STOP,))
            self._retired.append(h)

    @loop_only
    def spawn_pe(self, worker, pe) -> None:
        h = self._procs.get(worker.idx)
        if h is None:  # pragma: no cover - placement gates on ACTIVE state
            raise RuntimeError(f"worker {worker.idx} has no backing process")
        h.pes[pe.uid] = pe
        h.cmd_q.put_nowait((_CMD_START_PE, pe.uid, pe.image))

    # ---- the data-channel consumer ----------------------------------------
    @loop_only
    async def _poll_loop(self) -> None:
        try:
            while True:
                busy = False
                for idx in list(self._procs):
                    h = self._procs.get(idx)
                    if h is None:
                        continue
                    while True:
                        try:
                            ev = h.data_q.get_nowait()
                        except queue.Empty:
                            break
                        busy = True
                        self._handle_event(idx, h, ev)
                await asyncio.sleep(0.0 if busy else self.poll_wall)
        except asyncio.CancelledError:
            pass

    @loop_only
    @transition("pe", "ready", src="starting", dst="idle")
    @transition("pe", "pe.exit", src="idle", dst="stopped")
    def _handle_event(self, widx: int, h: _ProcHandle, ev: tuple) -> None:
        pool = self.pool
        tag = ev[0]
        if tag == _EV_METRICS:
            # metric deltas outlive their PE mirror (a flush can land after
            # the PE's exit event): merge unconditionally, never drop
            bus = pool.master.bus
            if bus is not None:
                bus.registry.merge(ev[2])
            return
        pe = h.pes.get(ev[1])
        if pe is None:
            return  # PE exited or worker was killed while the event flew
        if tag == _EV_PULL:
            self._on_pull(widx, h, pe)
        elif tag == _EV_COMPLETE:
            self._on_complete(widx, h, pe, ev)
        elif tag == _EV_READY:
            pe.state = PEState.IDLE
            pe.idle_since = pool.clock.now()
        elif tag == _EV_PE_EXIT:
            h.pes.pop(pe.uid, None)
            pe.state = PEState.STOPPED
            bus = pool.master.bus
            if bus is not None:
                bus.emit("pe.exit", worker=widx, pe=pe.uid, image=pe.image)
            worker = pool.workers[widx]
            try:
                worker.pes.remove(pe)
            except ValueError:
                pass  # kill_worker already cleared the list
            else:
                pool._pe_total -= 1

    @loop_only
    @transition("msg", "msg.pulled", src="enqueued|requeued", dst="pulled")
    @transition("pe", "msg.pulled", src="idle", dst="busy")
    @transition("msg", "msg.started", src="pulled", dst="started")
    def _on_pull(self, widx: int, h: _ProcHandle, pe) -> None:
        """The master side of a P2P pull: atomically peek the FIFO head,
        run the vector congestion gate against the mirror state, and ship
        the message — all on the loop thread, so the head cannot change
        between peek and pull (same invariant as the in-process PE)."""
        pool = self.pool
        master = pool.master
        worker = pool.workers[widx]
        head = master.head(pe.image)
        if (
            head is None
            or worker.state is not WorkerState.ACTIVE
            or not pool._gate_ok(worker, head)
        ):
            h.cmd_q.put_nowait((_CMD_REPLY, pe.uid, None))
            return
        msg = master.pull(pe.image)
        assert msg is head
        pe.state = PEState.BUSY
        pe.msg = msg
        bus = master.bus
        if bus is not None:
            bus.emit("msg.pulled", msg_id=msg.msg_id, image=msg.image,
                     worker=widx, pe=pe.uid)
            bus.emit("msg.started", msg_id=msg.msg_id, image=msg.image,
                     worker=widx, pe=pe.uid)
        msg.start_t = pool.clock.now()  # refined by the worker's own stamp
        w0 = time.perf_counter()
        blob = self.serialize(msg)
        self.serialize_ms += (time.perf_counter() - w0) * 1e3
        self.data_msgs_out += 1
        self.data_bytes_out += len(blob)
        h.cmd_q.put_nowait((_CMD_REPLY, pe.uid, blob))

    @loop_only
    @transition("msg", "msg.completed", src="started", dst="completed")
    @transition("pe", "msg.completed", src="busy", dst="idle")
    def _on_complete(self, widx: int, h: _ProcHandle, pe, ev: tuple) -> None:
        _, _, blob, start_t, done_t, cpu_s, encode_ms, proc_cpu_s = ev
        pool = self.pool
        msg = pe.msg
        if msg is None:
            return  # duplicate delivery after a kill-drain already applied it
        w0 = time.perf_counter()
        remote = self.deserialize(blob)
        self.serialize_ms += (time.perf_counter() - w0) * 1e3 + encode_ms
        self.data_msgs_in += 1
        self.data_bytes_in += len(blob)
        assert remote.msg_id == msg.msg_id
        # copy the worker's authoritative stamps onto the master's object
        # (the stream's own Message instances are what SimResult reports)
        msg.start_t = float(start_t)
        msg.done_t = float(done_t)
        # each report is cumulative for its process; fold the delta into
        # the run total (handles come and go with reboots/kills)
        self.proc_cpu_s_total += float(proc_cpu_s) - h.proc_cpu_s
        h.proc_cpu_s = float(proc_cpu_s)
        self._account_cpu(
            pool.workers[widx], pe, msg, float(cpu_s),
            float(done_t - start_t),
        )
        pe.msg = None
        pe.state = PEState.IDLE
        pe.idle_since = pool.clock.now()
        bus = pool.master.bus
        if bus is not None:
            bus.emit("msg.completed", msg_id=msg.msg_id, image=msg.image,
                     worker=widx, pe=pe.uid, start_t=msg.start_t,
                     done_t=msg.done_t, arrival=msg.arrival)
        pool.master.complete(msg)

    @loop_only
    def _account_cpu(
        self, worker, pe, msg: Message, cpu_s: float, busy_virtual_s: float
    ) -> None:
        """Fold one message's *real* CPU measurement into the drift ledger
        (and, under ``measurement='os'``, into the worker's probe so the
        unmodified ``MasterProfiler`` learns from OS numbers instead of
        the emulated model)."""
        pool = self.pool
        cores = float(pool.cfg.cores_per_worker)
        busy_wall = max(busy_virtual_s * pool.clock.time_scale, 1e-9)
        real_frac = (cpu_s / busy_wall) / cores
        emu_frac = msg.cpu_cores / cores
        self._drift_sum_pp += abs(emu_frac - real_frac) * 100.0
        self._drift_n += 1
        self._real_core_s += cpu_s
        self._emulated_core_s += msg.cpu_cores * busy_wall
        if self.measurement == "os":
            acc, counts = worker.probe.accumulators()
            dims = pool._dims
            if len(dims) > 1:
                import numpy as np

                vec = np.zeros(len(dims))
                vec[0] = min(real_frac, 1.0)
                if msg.resources:
                    for j, d in enumerate(dims[1:], start=1):
                        vec[j] = msg.resources.get(d, 0.0)
                sample = vec
            else:
                sample = min(real_frac, 1.0)
            if pe.image in acc:
                acc[pe.image] = acc[pe.image] + sample
                counts[pe.image] += 1
            else:
                acc[pe.image] = sample
                counts[pe.image] = 1

    # ---- failure injection -------------------------------------------------
    @loop_only(blocking=(
        "kill path deliberately stalls the loop: the SIGKILL'd process must "
        "be reaped and its data channel tail-drained synchronously so no "
        "completion can race the harvest (the poller is parked, not a "
        "second consumer)"
    ))
    @transition("pe", "worker.kill", src="starting|idle|busy", dst="stopped",
                scope="worker")
    def kill_worker(self, worker) -> List[Message]:
        """SIGKILL the worker process, then settle the data channel.

        Order matters for the at-least-once accounting the fault suite
        pins: (1) kill, so no *new* completions can be produced; (2) drain
        the data queue — completions the process flushed before dying are
        applied normally (those messages are done, not lost); (3) harvest
        whatever the mirror still marks in flight.  A message whose
        completion was only partially flushed at the kill is treated as
        lost and harvested — it will run again, which is exactly
        at-least-once.  All three steps run on the loop thread and the
        poller never blocks in a queue read, so no other consumer can
        interleave.
        """
        h = self._procs.pop(worker.idx, None)
        if h is not None:
            if h.proc.is_alive():
                h.proc.kill()  # SIGKILL — no cleanup, as a real VM failure
            h.proc.join(timeout=5.0)
            while True:
                try:
                    ev = h.data_q.get(timeout=0.05)
                except (queue.Empty, EOFError, OSError):
                    break
                except Exception:
                    break  # truncated pickle from the severed feeder pipe
                if ev[0] == _EV_COMPLETE:
                    pe = h.pes.get(ev[1])
                    if pe is not None:
                        self._on_complete(worker.idx, h, pe, ev)
                elif ev[0] in (_EV_PE_EXIT, _EV_METRICS):
                    # flushed metric deltas are applied like flushed
                    # completions: they describe work that really happened
                    self._handle_event(worker.idx, h, ev)
                # pending pulls/readies die with the worker
            h.cmd_q.cancel_join_thread()
            h.data_q.cancel_join_thread()
        harvested: List[Message] = []
        for pe in list(worker.pes):
            if pe.msg is not None:
                harvested.append(pe.msg)
                pe.msg = None
            pe.state = PEState.STOPPED
        return harvested

    # ---- teardown ----------------------------------------------------------
    @loop_only(blocking=(
        "teardown after the run: joins worker processes with bounded "
        "timeouts once the clock has stopped and no payload is in flight"
    ))
    async def close(self) -> None:
        if self._poller is not None:
            self._poller.cancel()
            await asyncio.gather(self._poller, return_exceptions=True)
            self._poller = None
        handles = list(self._procs.values()) + self._retired
        self._procs.clear()
        self._retired = []
        for h in handles:
            if h.proc.is_alive():
                try:
                    h.cmd_q.put_nowait((_CMD_STOP,))
                except Exception:
                    pass
        for h in handles:
            h.proc.join(timeout=1.0)
            if h.proc.is_alive():
                h.proc.terminate()
                h.proc.join(timeout=1.0)
            if h.proc.is_alive():  # pragma: no cover - last resort
                h.proc.kill()
                h.proc.join(timeout=1.0)
            h.cmd_q.cancel_join_thread()
            h.data_q.cancel_join_thread()

    # ---- wire/measurement ledger ------------------------------------------
    def stats(self) -> Dict[str, object]:
        n_in = max(self.data_msgs_in, 1)
        return {
            "transport": self.name,
            "start_method": self.start_method,
            "measurement": self.measurement,
            "workers_spawned": self.workers_spawned,
            "data_msgs_out": self.data_msgs_out,
            "data_msgs_in": self.data_msgs_in,
            "data_bytes_out": self.data_bytes_out,
            "data_bytes_in": self.data_bytes_in,
            "serialize_ms": self.serialize_ms,
            "ser_bytes_per_msg": (
                (self.data_bytes_out + self.data_bytes_in)
                / max(self.data_msgs_out + self.data_msgs_in, 1)
            ),
            "ser_ms_per_msg": self.serialize_ms / n_in,
            # emulated-vs-measured CPU, the headline fidelity number: mean
            # |model − os|, in percentage points of one worker's capacity
            "profiler_drift_pp": (
                self._drift_sum_pp / self._drift_n if self._drift_n else 0.0
            ),
            "real_cpu_core_s": self._real_core_s,
            "emulated_cpu_core_s": self._emulated_core_s,
            # whole-process CPU (os.times user+sys), includes the worker's
            # own dispatcher/queue overhead on top of the PE threads
            "proc_cpu_s": self.proc_cpu_s_total,
        }


TRANSPORTS = {
    "inproc": InProcTransport,
    "multiproc": MultiprocTransport,
}


def make_transport(name: str, **kwargs) -> Transport:
    """Resolve a transport by name (mirrors ``make_packer``/``make_payload``)."""
    try:
        factory = TRANSPORTS[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; available: {sorted(TRANSPORTS)}"
        ) from None
    return factory(**kwargs)
