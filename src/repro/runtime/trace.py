"""Trace recording: the live runtime's ``SimResult``-compatible record.

``TraceRecorder`` samples the cluster once per control tick and emits the
same per-tick time series the simulator records — measured/scheduled CPU
per worker, queue length, active/target/ideal worker counts, PE count,
and the per-dimension arrays in vector mode — packed into a
``core.sim.SimResult``.  Everything downstream (``scenarios.engine``
summary metrics, expectation checks, policy sweeps, the figure CSV dump)
therefore works unchanged on either backend.

Measurement model: the live runtime executes *real* concurrent work, but
its per-PE CPU draw is emulated with the simulator's model (busy PE →
``cpu_cores`` + Gaussian noise, idle PE → ``idle_pe_cpu_cores``, starting
PE → 0, clipped per worker) rather than read from the OS.  That keeps the
profiler's learned sizes, and therefore the packing decisions under test,
on the same scale as the simulator — which is exactly what the
cross-backend parity suite asserts.  Auxiliary dimensions are measured
exactly (reservations are deterministic), as in the sim.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

from ..core.sim import PEState, SimConfig, SimResult, WorkerState
from ..core.workloads import Message

__all__ = ["TraceRecorder", "measure_workers"]


def measure_workers(
    workers,
    cfg: SimConfig,
    rng: np.random.Generator,
    dims: Tuple[str, ...],
    accumulate: bool = True,
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Instantaneous measured usage per worker, accumulated into probes.

    Returns ``(cpu_row, dim_rows)`` where ``cpu_row`` is the measured CPU
    fraction per worker slot and ``dim_rows`` is the (n_workers, D)
    per-dimension matrix in vector mode (``None`` on the scalar path).
    Same draw model and probe accumulation as the simulator's ``measure``.

    ``accumulate=False`` records the emulated trace rows without feeding
    the probes — used when a transport supplies *real* OS measurements to
    the profiler instead (``RuntimeConfig.measurement="os"``), so the
    emulated draws stay visible in the trace for drift comparison but
    never reach the learning path.
    """
    multi = len(dims) > 1
    D = len(dims)
    cores_per_worker = float(cfg.cores_per_worker)
    noise_std = cfg.cpu_noise_std * cfg.cores_per_worker
    idle_draw = min(max(cfg.idle_pe_cpu_cores, 0.0), cores_per_worker)
    rng_normal = rng.normal
    busy, idle = PEState.BUSY, PEState.IDLE
    n = max(len(workers), 1)
    out = np.zeros(n)
    dim_out = np.zeros((n, D)) if multi else None
    for w in workers:
        if w.state is not WorkerState.ACTIVE:
            continue
        acc, counts = w.probe.accumulators()
        if multi:
            totals = np.zeros(D)
            for pe in w.pes:
                vec = np.zeros(D)
                if pe.state is busy and pe.msg is not None:
                    draw = pe.msg.cpu_cores * float(rng_normal(1.0, noise_std))
                    if draw < 0.0:
                        draw = 0.0
                    elif draw > cores_per_worker:
                        draw = cores_per_worker
                    vec[0] = draw / cores_per_worker
                    mres = pe.msg.resources
                    if mres:
                        for j in range(1, D):
                            vec[j] = mres.get(dims[j], 0.0)
                elif pe.state is idle:
                    vec[0] = idle_draw / cores_per_worker
                totals = totals + vec
                if accumulate:
                    img = pe.image
                    if img in acc:
                        acc[img] = acc[img] + vec
                        counts[img] += 1
                    else:
                        acc[img] = vec
                        counts[img] = 1
            clipped = np.minimum(totals, 1.0)
            dim_out[w.idx] = clipped
            out[w.idx] = clipped[0]
        else:
            cores = 0.0
            for pe in w.pes:
                if pe.state is busy and pe.msg is not None:
                    draw = pe.msg.cpu_cores * float(rng_normal(1.0, noise_std))
                    if draw < 0.0:
                        draw = 0.0
                    elif draw > cores_per_worker:
                        draw = cores_per_worker
                elif pe.state is idle:
                    draw = idle_draw
                else:
                    draw = 0.0
                cores += draw
                if accumulate:
                    img = pe.image
                    if img in acc:
                        acc[img] += draw / cores_per_worker
                        counts[img] += 1
                    else:
                        acc[img] = draw / cores_per_worker
                        counts[img] = 1
            u = cores / cores_per_worker
            out[w.idx] = u if u < 1.0 else 1.0
    return out, dim_out


class TraceRecorder:
    """Collects per-tick rows and finalizes them into a ``SimResult``."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.dims = tuple(cfg.resource_dims)
        self.multi = len(self.dims) > 1
        self.times: List[float] = []
        self.measured: List[np.ndarray] = []
        self.scheduled: List[np.ndarray] = []
        self.qlen: List[int] = []
        self.active: List[int] = []
        self.target: List[int] = []
        self.ideal: List[int] = []
        self.pe_count: List[int] = []
        self.measured_res: List[np.ndarray] = []
        self.scheduled_res: List[np.ndarray] = []

    def record(
        self,
        t: float,
        measured_cpu: np.ndarray,
        dim_measure: Optional[np.ndarray],
        scheduled_loads,
        workers,
        qlen: int,
        requested_target: int,
        backlog: List[Message],
        estimate,
    ) -> None:
        """Append one control-tick row (mirrors the simulator's recording)."""
        cfg = self.cfg
        W = cfg.max_workers
        D = len(self.dims)
        mrow = np.zeros(W)
        k = min(len(measured_cpu), W)
        mrow[:k] = measured_cpu[:k]
        srow = np.zeros(W)
        if self.multi:
            mres_row = np.zeros((W, D))
            if dim_measure is not None:
                mres_row[:k] = dim_measure[:k]
            sres_row = np.zeros((W, D))
            for j in range(min(len(scheduled_loads), W)):
                v = scheduled_loads[j].values
                c = v[0]
                srow[j] = c if c < 1.0 else 1.0
                sres_row[j] = np.minimum(v, 1.0)
            self.measured_res.append(mres_row)
            self.scheduled_res.append(sres_row)
        else:
            for j in range(min(len(scheduled_loads), W)):
                v = scheduled_loads[j]
                srow[j] = v if v < 1.0 else 1.0

        n_active = 0
        n_pes = 0
        if self.multi:
            busy_vec = np.zeros(D)
            for w in workers:
                n_pes += len(w.pes)
                if w.state is WorkerState.ACTIVE:
                    n_active += 1
                    for pe in w.pes:
                        busy_vec = busy_vec + pe.estimate.values
            backlog_vec = np.zeros(D)
            for msg in backlog:
                backlog_vec = backlog_vec + estimate(msg.image).values
            ideal = int(max(
                math.ceil(busy_vec[j] + (backlog_vec[j]
                                         if backlog_vec[j] < 64.0 else 64.0))
                for j in range(D)
            ))
        else:
            busy_load = 0.0
            for w in workers:
                n_pes += len(w.pes)
                if w.state is WorkerState.ACTIVE:
                    n_active += 1
                    for pe in w.pes:
                        busy_load += pe.estimate
            backlog_load = 0.0
            for msg in backlog:
                backlog_load += estimate(msg.image)
            ideal = int(math.ceil(
                busy_load + (backlog_load if backlog_load < 64.0 else 64.0)
            ))

        self.times.append(t)
        self.measured.append(mrow)
        self.scheduled.append(srow)
        self.qlen.append(qlen)
        self.active.append(n_active)
        self.target.append(requested_target)
        self.ideal.append(ideal)
        self.pe_count.append(n_pes)

    def finalize(
        self,
        completed: int,
        total: int,
        makespan: float,
        messages: List[Message],
        requeued: int = 0,
    ) -> SimResult:
        n = len(self.times)
        W = self.cfg.max_workers
        return SimResult(
            times=np.asarray(self.times, np.float64),
            measured_cpu=(
                np.stack(self.measured) if n else np.zeros((0, W))
            ),
            scheduled_cpu=(
                np.stack(self.scheduled) if n else np.zeros((0, W))
            ),
            queue_len=np.asarray(self.qlen, np.int64),
            active_workers=np.asarray(self.active, np.int64),
            target_workers=np.asarray(self.target, np.int64),
            ideal_bins=np.asarray(self.ideal, np.int64),
            pe_count=np.asarray(self.pe_count, np.int64),
            completed=completed,
            total=total,
            makespan=makespan,
            messages=messages,
            resource_dims=self.dims,
            measured_res=(
                np.stack(self.measured_res) if self.multi and n else None
            ),
            scheduled_res=(
                np.stack(self.scheduled_res) if self.multi and n else None
            ),
            requeued=requeued,
        )
