"""First-Fit sequence packing — the paper's technique in the data pipeline.

Documents are *items* (size = token count), fixed-length training rows are
*bins* (capacity = seq_len).  The online First-Fit packer fills rows from a
document stream exactly the way the IRM fills workers with PEs: lowest-index
open row that fits, new row only when none fits.  Packing efficiency (real
tokens / row capacity) is the data-pipeline analogue of the paper's 90-100%
worker utilization, and is benchmarked against the no-packing baseline
(one document per row) in ``benchmarks/packing_throughput.py``.

Emitted batches carry ``segment_ids`` (1..k per row, 0 = padding) and
within-segment ``positions``; the attention layers (and the
``kernels/packed_attention`` Pallas kernel) mask across segment boundaries,
so packed training is loss-equivalent to unpacked training.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

__all__ = ["PackedBatch", "SequencePacker", "pack_documents", "packing_efficiency"]


@dataclasses.dataclass
class PackedBatch:
    tokens: np.ndarray       # (B, S) int32
    labels: np.ndarray       # (B, S) int32, -1 where masked
    segment_ids: np.ndarray  # (B, S) int32, 0 = padding
    positions: np.ndarray    # (B, S) int32, within-segment

    @property
    def real_tokens(self) -> int:
        return int((self.segment_ids > 0).sum())

    @property
    def capacity(self) -> int:
        return int(self.tokens.size)


class _Row:
    """One open bin: a training row being filled with documents."""

    __slots__ = ("docs", "used", "capacity")

    def __init__(self, capacity: int):
        self.docs: List[np.ndarray] = []
        self.used = 0
        self.capacity = capacity

    @property
    def free(self) -> int:
        return self.capacity - self.used

    def add(self, doc: np.ndarray) -> None:
        self.docs.append(doc)
        self.used += len(doc)


class SequencePacker:
    """Online First-Fit packing of a token-document stream into rows.

    ``algorithm``: "first-fit" (paper default), "next-fit" (only the newest
    row — the cheap baseline), or "best-fit".  ``max_open_rows`` bounds
    latency and memory: when exceeded, the fullest row is closed (ready for
    emission), mirroring the IRM closing full bins.
    """

    def __init__(
        self,
        seq_len: int,
        batch_size: int,
        *,
        algorithm: str = "first-fit",
        max_open_rows: Optional[int] = None,
        min_fill_to_close: float = 1.0,
    ):
        if algorithm not in ("first-fit", "next-fit", "best-fit"):
            raise ValueError(f"unknown packing algorithm {algorithm!r}")
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.algorithm = algorithm
        self.max_open_rows = max_open_rows or 4 * batch_size
        self.min_fill_to_close = min_fill_to_close
        self._open: List[_Row] = []
        self._closed: List[_Row] = []
        # stats
        self.docs_in = 0
        self.tokens_in = 0
        self.rows_out = 0

    # ---- packing ---------------------------------------------------------------
    def _choose_row(self, n: int) -> Optional[int]:
        if self.algorithm == "next-fit":
            if self._open and self._open[-1].free >= n:
                return len(self._open) - 1
            return None
        if self.algorithm == "best-fit":
            best, best_free = None, self.seq_len + 1
            for i, row in enumerate(self._open):
                if n <= row.free < best_free:
                    best, best_free = i, row.free
            return best
        for i, row in enumerate(self._open):  # first-fit
            if row.free >= n:
                return i
        return None

    def feed(self, doc: Sequence[int]) -> None:
        """Pack one document (split into seq_len chunks if oversized)."""
        arr = np.asarray(doc, dtype=np.int32)
        self.docs_in += 1
        self.tokens_in += len(arr)
        for start in range(0, len(arr), self.seq_len):
            chunk = arr[start : start + self.seq_len]
            if len(chunk) == 0:
                continue
            idx = self._choose_row(len(chunk))
            if idx is None:
                if self.algorithm == "next-fit" and self._open:
                    # next-fit closes the previous row when it can't fit
                    self._closed.append(self._open.pop())
                self._open.append(_Row(self.seq_len))
                idx = len(self._open) - 1
            row = self._open[idx]
            row.add(chunk)
            if row.free == 0 or row.used >= self.min_fill_to_close * self.seq_len:
                self._closed.append(self._open.pop(idx))
        # bound the number of open rows (close the fullest)
        while len(self._open) > self.max_open_rows:
            fullest = max(range(len(self._open)), key=lambda i: self._open[i].used)
            self._closed.append(self._open.pop(fullest))

    # ---- emission -----------------------------------------------------------------
    def ready(self) -> bool:
        return len(self._closed) >= self.batch_size

    def flush(self) -> None:
        """Close all open rows (end of stream)."""
        self._closed.extend(self._open)
        self._open = []

    def pop_batch(self, *, pad_final: bool = False) -> Optional[PackedBatch]:
        if not self.ready():
            if not pad_final or not self._closed:
                return None
        rows = self._closed[: self.batch_size]
        self._closed = self._closed[self.batch_size :]
        while len(rows) < self.batch_size:  # pad_final: empty rows
            rows.append(_Row(self.seq_len))
        return self._emit(rows)

    def _emit(self, rows: List[_Row]) -> PackedBatch:
        B, S = self.batch_size, self.seq_len
        tokens = np.zeros((B, S), np.int32)
        labels = np.full((B, S), -1, np.int32)
        seg = np.zeros((B, S), np.int32)
        pos = np.zeros((B, S), np.int32)
        for b, row in enumerate(rows):
            off = 0
            for s_id, doc in enumerate(row.docs, start=1):
                n = len(doc)
                tokens[b, off : off + n] = doc
                seg[b, off : off + n] = s_id
                pos[b, off : off + n] = np.arange(n)
                # next-token labels within the document
                labels[b, off : off + n - 1] = doc[1:]
                off += n
        self.rows_out += B
        return PackedBatch(tokens=tokens, labels=labels, segment_ids=seg,
                           positions=pos)

    # ---- metrics --------------------------------------------------------------------
    @property
    def open_rows(self) -> int:
        return len(self._open)

    @property
    def closed_rows(self) -> int:
        return len(self._closed)


def pack_documents(
    docs: Iterable[Sequence[int]],
    seq_len: int,
    batch_size: int,
    *,
    algorithm: str = "first-fit",
) -> Iterator[PackedBatch]:
    """Pack a finite document collection into batches (flushes the tail)."""
    packer = SequencePacker(seq_len, batch_size, algorithm=algorithm)
    for doc in docs:
        packer.feed(doc)
        while packer.ready():
            yield packer.pop_batch()
    packer.flush()
    while True:
        batch = packer.pop_batch(pad_final=True)
        if batch is None:
            break
        yield batch


def packing_efficiency(batches: Iterable[PackedBatch]) -> float:
    """real tokens / capacity — the utilization metric (paper Figs. 4/8)."""
    real = cap = 0
    for b in batches:
        real += b.real_tokens
        cap += b.capacity
    return real / cap if cap else 0.0
