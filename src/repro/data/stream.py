"""Streaming training-data pipeline with IRM-managed packing.

The HarmonicIO loop, applied to training data:

  - documents stream into an ingest queue (the master's message queue),
  - the **load predictor** watches the queue length + ROC and decides how
    many packer shards should be active (PE auto-scaling),
  - the **profiler** tracks per-source document statistics (moving average
    of token counts — the item-size profile),
  - **First-Fit packing** fills training rows (bins) from the queue,
  - a background prefetch thread keeps a bounded batch queue ahead of the
    training loop (compute/ingest overlap).

The deterministic synchronous path (``__iter__`` with ``prefetch=0``) is
used by tests; training drivers enable the prefetch thread.
"""

from __future__ import annotations

import queue
import threading
from collections import deque
from typing import Dict, Iterable, Iterator, Optional

import numpy as np

from ..core.load_predictor import LoadPredictor, LoadPredictorConfig
from ..core.profiler import MasterProfiler, ProfilerConfig
from .packing import PackedBatch, SequencePacker

__all__ = ["StreamingPipeline"]


class StreamingPipeline:
    """Document iterator -> packed-batch iterator, IRM-instrumented."""

    def __init__(
        self,
        documents: Iterable[np.ndarray],
        seq_len: int,
        batch_size: int,
        *,
        algorithm: str = "first-fit",
        prefetch: int = 2,
        max_packer_shards: int = 8,
        source_name: str = "default",
    ):
        self.documents = iter(documents)
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.prefetch = prefetch
        self.max_packer_shards = max_packer_shards
        self.source_name = source_name

        self.packer = SequencePacker(seq_len, batch_size, algorithm=algorithm)
        self.profiler = MasterProfiler(
            ProfilerConfig(window=256, default_size=0.1)
        )
        self.predictor = LoadPredictor(
            LoadPredictorConfig(queue_low=512, queue_high=4096,
                                roc_low=256, roc_high=2048,
                                small_increase=1, large_increase=2,
                                read_interval=0.0, cooldown=0.0)
        )
        self.active_shards = 1
        self._ingest: deque = deque()
        self._tick = 0.0
        self.exhausted = False
        self.scaling_events: list = []

    # ---- IRM instrumentation --------------------------------------------------
    def _ingest_documents(self, n: int) -> None:
        """Pull up to n documents from the source into the ingest queue."""
        for _ in range(n):
            try:
                doc = next(self.documents)
            except StopIteration:
                self.exhausted = True
                return
            self._ingest.append(doc)
            # profile: document size as a fraction of a row (the item size)
            self.profiler.observe(
                self.source_name, min(1.0, len(doc) / self.seq_len)
            )

    def _autoscale(self) -> None:
        """Load-predictor decision -> number of active packer shards."""
        self._tick += 1.0
        decision = self.predictor.update(self._tick, float(len(self._ingest)))
        if decision.num_pes > 0:
            new = min(self.max_packer_shards, self.active_shards + decision.num_pes)
            if new != self.active_shards:
                self.scaling_events.append((self._tick, self.active_shards, new))
                self.active_shards = new
        elif len(self._ingest) == 0 and self.active_shards > 1:
            self.scaling_events.append((self._tick, self.active_shards, 1))
            self.active_shards = 1

    # ---- synchronous iteration ---------------------------------------------------
    def _next_batch(self) -> Optional[PackedBatch]:
        while not self.packer.ready():
            if not self._ingest and not self.exhausted:
                # each active shard ingests a chunk per tick (shard throughput)
                self._ingest_documents(64 * self.active_shards)
                self._autoscale()
            if self._ingest:
                self.packer.feed(self._ingest.popleft())
            elif self.exhausted:
                self.packer.flush()
                return self.packer.pop_batch(pad_final=True)
        return self.packer.pop_batch()

    def __iter__(self) -> Iterator[PackedBatch]:
        if self.prefetch <= 0:
            while True:
                batch = self._next_batch()
                if batch is None:
                    return
                yield batch
        else:
            yield from self._prefetch_iter()

    # ---- background prefetch -------------------------------------------------------
    def _prefetch_iter(self) -> Iterator[PackedBatch]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        _SENTINEL = object()

        def worker() -> None:
            try:
                while True:
                    batch = self._next_batch()
                    if batch is None:
                        break
                    q.put(batch)
            finally:
                q.put(_SENTINEL)

        t = threading.Thread(target=worker, daemon=True, name="packer-prefetch")
        t.start()
        while True:
            item = q.get()
            if item is _SENTINEL:
                t.join()
                return
            yield item

    # ---- metrics ---------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {
            "docs_in": self.packer.docs_in,
            "tokens_in": self.packer.tokens_in,
            "rows_out": self.packer.rows_out,
            "mean_doc_fill": self.profiler.estimate(self.source_name),
            "active_shards": self.active_shards,
            "ingest_queue": len(self._ingest),
        }
