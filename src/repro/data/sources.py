"""Document sources for the streaming data pipeline.

``synthetic_documents`` models a scientific-corpus length distribution
(log-normal, heavy upper tail — the "large individual objects" regime the
paper targets, in token form).  ``bimodal_documents`` mixes short chat-like
and long article-like documents, the adversarial case for naive padding.
"""

from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

__all__ = ["synthetic_documents", "bimodal_documents"]


def synthetic_documents(
    vocab_size: int,
    *,
    mean_len: float = 700.0,
    sigma: float = 0.9,
    max_len: int = 16384,
    seed: int = 0,
    limit: Optional[int] = None,
    zipf_a: float = 1.3,
) -> Iterator[np.ndarray]:
    """Log-normal document lengths; Zipf-distributed token ids.

    The Zipf unigram distribution gives the stream *learnable* structure
    (uniform tokens would make ln(V) the optimal loss — nothing to train
    on); documents also repeat a sampled 8-gram motif, so a small model's
    loss visibly drops within a few hundred steps.
    """
    rng = np.random.default_rng(seed)
    mu = np.log(mean_len) - sigma ** 2 / 2
    n = 0
    while limit is None or n < limit:
        length = int(np.clip(rng.lognormal(mu, sigma), 8, max_len))
        toks = rng.zipf(zipf_a, size=length) % vocab_size
        # per-document repeated motif (local predictable structure)
        if length >= 32:
            motif = toks[:8].copy()
            starts = rng.integers(8, length - 8, size=max(1, length // 64))
            for s in starts:
                toks[s : s + 8] = motif
        yield toks.astype(np.int32)
        n += 1


def bimodal_documents(
    vocab_size: int,
    *,
    short_len: int = 128,
    long_len: int = 3000,
    long_fraction: float = 0.2,
    jitter: float = 0.3,
    seed: int = 0,
    limit: Optional[int] = None,
) -> Iterator[np.ndarray]:
    rng = np.random.default_rng(seed)
    n = 0
    while limit is None or n < limit:
        base = long_len if rng.random() < long_fraction else short_len
        length = max(8, int(base * rng.uniform(1 - jitter, 1 + jitter)))
        yield rng.integers(0, vocab_size, size=length).astype(np.int32)
        n += 1
