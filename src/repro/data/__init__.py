"""Streaming data pipeline: document sources, First-Fit packing, prefetch."""

from .packing import PackedBatch, SequencePacker, pack_documents, packing_efficiency
from .sources import bimodal_documents, synthetic_documents
from .stream import StreamingPipeline

__all__ = [
    "PackedBatch",
    "SequencePacker",
    "pack_documents",
    "packing_efficiency",
    "bimodal_documents",
    "synthetic_documents",
    "StreamingPipeline",
]
