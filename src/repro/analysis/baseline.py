"""Baseline / suppression file for the invariant checker.

The committed baseline (``analysis-baseline.json`` at the repo root)
lists findings that are acknowledged and deliberately not fixed yet.  It
ships **empty**: every rule's real findings were fixed in the PR that
introduced the checker, and the CI gate fails on any unsuppressed
finding, so new violations cannot land without either a fix or an
explicit, reviewable suppression entry.

A suppression matches on ``(rule, path, symbol, message)`` — not the
line number — so edits elsewhere in a file cannot silently detach it,
while any change to the finding itself (different message, moved
function) makes the suppression stale.  Stale suppressions are reported
so the baseline can only shrink back to empty, never rot.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Tuple

from .model import Finding

__all__ = ["load_baseline", "apply_baseline", "DEFAULT_BASELINE_NAME"]

DEFAULT_BASELINE_NAME = "analysis-baseline.json"


def load_baseline(path: Path) -> List[Dict[str, str]]:
    """Read the suppression list; a missing file is an empty baseline."""
    if not path.is_file():
        return []
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    suppressions = data.get("suppressions", [])
    if not isinstance(suppressions, list):
        raise ValueError(f"{path}: 'suppressions' must be a list")
    return suppressions


def _suppression_key(entry: Dict[str, str]) -> str:
    return (
        f"{entry.get('rule', '')}:{entry.get('path', '')}:"
        f"{entry.get('symbol', '')}:{entry.get('message', '')}"
    )


def apply_baseline(
    findings: List[Finding], suppressions: List[Dict[str, str]]
) -> Tuple[List[Finding], List[Finding], List[Dict[str, str]]]:
    """Split findings into (active, suppressed) and report stale entries."""
    keys = {_suppression_key(e): e for e in suppressions}
    active: List[Finding] = []
    suppressed: List[Finding] = []
    used = set()
    for f in findings:
        k = f.key()
        if k in keys:
            suppressed.append(f)
            used.add(k)
        else:
            active.append(f)
    stale = [e for k, e in keys.items() if k not in used]
    return active, suppressed, stale
