"""CLI for the invariant checker: ``python -m repro.analysis``.

Exit codes: 0 = clean (no unsuppressed findings, no stale suppressions),
1 = findings (or stale baseline entries), 2 = usage/config error.

Typical invocations::

    PYTHONPATH=src python -m repro.analysis                  # text report
    PYTHONPATH=src python -m repro.analysis --format json --out report.json
    PYTHONPATH=src python -m repro.analysis --rules R1,R2    # subset
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import (
    DEFAULT_BASELINE_NAME,
    RULES,
    apply_baseline,
    load_baseline,
    run_analysis,
)


def _find_root(start: Path) -> Path:
    """The repo root: the nearest ancestor holding ``src/repro``."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit(
        f"error: no src/repro tree found at or above {start} "
        f"(pass --root explicitly)"
    )


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=(
            "AST-based invariant checker: concurrency (R1, R2), frozen "
            "reference (R3), wire contract (R4), determinism (R5)."
        ),
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=None,
        help="repo root (default: nearest ancestor of CWD with src/repro)",
    )
    parser.add_argument(
        "--rules",
        default=None,
        help=f"comma-separated subset of {','.join(RULES)} (default: all)",
    )
    parser.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="report format (default: text)",
    )
    parser.add_argument(
        "--out",
        type=Path,
        default=None,
        help="also write the report to this file (same format)",
    )
    parser.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help=f"suppression file (default: <root>/{DEFAULT_BASELINE_NAME})",
    )
    parser.add_argument(
        "--list-rules",
        action="store_true",
        help="print the rule catalogue and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule_id, (_, desc) in RULES.items():
            print(f"{rule_id}  {desc}")
        return 0

    root = args.root.resolve() if args.root else _find_root(Path.cwd())
    if not (root / "src" / "repro").is_dir():
        print(f"error: {root} has no src/repro tree", file=sys.stderr)
        return 2
    rules = args.rules.split(",") if args.rules else None
    try:
        findings = run_analysis(root, rules=rules)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    baseline_path = args.baseline or (root / DEFAULT_BASELINE_NAME)
    suppressions = load_baseline(baseline_path)
    active, suppressed, stale = apply_baseline(findings, suppressions)

    counts: dict = {}
    for f in active:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    report = {
        "version": 1,
        "root": str(root),
        "rules": {rule_id: desc for rule_id, (_, desc) in RULES.items()},
        "findings": [f.to_json() for f in active],
        "suppressed": len(suppressed),
        "stale_suppressions": stale,
        "counts": counts,
        "ok": not active and not stale,
    }

    if args.format == "json":
        text = json.dumps(report, indent=2)
    else:
        lines = []
        for f in active:
            lines.append(f"{f.path}:{f.line}: [{f.rule}] "
                         f"{f.symbol + ': ' if f.symbol else ''}{f.message}")
        for entry in stale:
            lines.append(
                f"{baseline_path.name}: stale suppression {entry} — the "
                f"finding no longer exists; delete the entry"
            )
        if not lines:
            lines.append(
                f"analysis clean: {len(findings)} finding(s) total, "
                f"{len(suppressed)} suppressed, rules {','.join(RULES)}"
            )
        else:
            lines.append(
                f"{len(active)} finding(s) ({len(suppressed)} suppressed, "
                f"{len(stale)} stale suppression(s))"
            )
        text = "\n".join(lines)

    print(text)
    if args.out is not None:
        args.out.write_text(
            text + ("\n" if not text.endswith("\n") else ""), encoding="utf-8"
        )
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
