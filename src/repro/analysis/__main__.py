"""CLI for the invariant checker: ``python -m repro.analysis``.

Exit codes: 0 = clean (no unsuppressed findings, no stale suppressions),
1 = findings (or stale baseline entries), 2 = usage/config error.

Typical invocations::

    PYTHONPATH=src python -m repro.analysis                  # text report
    PYTHONPATH=src python -m repro.analysis --format json --out report.json
    PYTHONPATH=src python -m repro.analysis --rules R1,R2    # subset
    PYTHONPATH=src python -m repro.analysis --changed-only   # pre-commit
    PYTHONPATH=src python -m repro.analysis --rules R8 --events runs/obs

``--changed-only [REF]`` keeps only findings in files changed vs REF
(default HEAD: staged + unstaged + untracked) — the pre-commit fast
path.  Rules still see the whole tree (cross-file invariants need it);
only the *reporting* is filtered, and stale-suppression errors are not
reported since unchanged files are out of scope.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import Optional, Set

from . import (
    DEFAULT_BASELINE_NAME,
    RULES,
    apply_baseline,
    load_baseline,
    run_analysis,
)


def _find_root(start: Path) -> Path:
    """The repo root: the nearest ancestor holding ``src/repro``."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit(
        f"error: no src/repro tree found at or above {start} "
        f"(pass --root explicitly)"
    )


def _changed_files(root: Path, ref: str) -> Optional[Set[str]]:
    """Repo-relative paths changed vs ``ref`` plus untracked files, or
    None if git is unavailable / ``root`` is not a work tree."""
    changed: Set[str] = set()
    for cmd in (
        ["git", "diff", "--name-only", ref, "--"],
        ["git", "ls-files", "--others", "--exclude-standard"],
    ):
        try:
            out = subprocess.run(
                cmd, cwd=root, capture_output=True, text=True, check=True
            ).stdout
        except (OSError, subprocess.CalledProcessError):
            return None
        changed.update(line.strip() for line in out.splitlines()
                       if line.strip())
    return changed


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=(
            "AST-based invariant checker: concurrency (R1, R2), frozen "
            "reference (R3), wire contract (R4), determinism (R5), event "
            "schema (R6), protocol model (R7), trace conformance (R8)."
        ),
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=None,
        help="repo root (default: nearest ancestor of CWD with src/repro)",
    )
    parser.add_argument(
        "--rules",
        default=None,
        help=f"comma-separated subset of {','.join(RULES)} (default: all)",
    )
    parser.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="report format (default: text)",
    )
    parser.add_argument(
        "--out",
        type=Path,
        default=None,
        help="also write the report to this file (same format)",
    )
    parser.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help=f"suppression file (default: <root>/{DEFAULT_BASELINE_NAME})",
    )
    parser.add_argument(
        "--events",
        type=Path,
        action="append",
        default=None,
        metavar="PATH",
        help="events.jsonl file or directory for R8 trace conformance "
             "(repeatable; without it R8 is a no-op)",
    )
    parser.add_argument(
        "--changed-only",
        nargs="?",
        const="HEAD",
        default=None,
        metavar="REF",
        help="report only findings in files changed vs REF (default "
             "HEAD) plus untracked files — the pre-commit fast path",
    )
    parser.add_argument(
        "--list-rules",
        action="store_true",
        help="print the rule catalogue and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule_id, (_, desc) in RULES.items():
            print(f"{rule_id}  {desc}")
        return 0

    root = args.root.resolve() if args.root else _find_root(Path.cwd())
    if not (root / "src" / "repro").is_dir():
        print(f"error: {root} has no src/repro tree", file=sys.stderr)
        return 2
    rules = args.rules.split(",") if args.rules else None
    try:
        findings = run_analysis(root, rules=rules, events=args.events)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    baseline_path = args.baseline or (root / DEFAULT_BASELINE_NAME)
    suppressions = load_baseline(baseline_path)
    active, suppressed, stale = apply_baseline(findings, suppressions)

    if args.changed_only is not None:
        changed = _changed_files(root, args.changed_only)
        if changed is None:
            print(
                f"error: --changed-only needs a git work tree at {root} "
                f"and a resolvable ref {args.changed_only!r}",
                file=sys.stderr,
            )
            return 2
        active = [f for f in active if f.path in changed]
        # unchanged files are out of scope, so a suppression pointing at
        # one is not actionable here — full runs still report staleness
        stale = []

    counts: dict = {}
    for f in active:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    # the report is machine-diffable across checkouts: every path in it,
    # including the root itself, is repo-relative
    report = {
        "version": 1,
        "root": ".",
        "rules": {rule_id: desc for rule_id, (_, desc) in RULES.items()},
        "findings": [f.to_json() for f in active],
        "suppressed": len(suppressed),
        "stale_suppressions": stale,
        "counts": counts,
        "ok": not active and not stale,
    }

    if args.format == "json":
        text = json.dumps(report, indent=2)
    else:
        lines = []
        for f in active:
            lines.append(f"{f.path}:{f.line}: [{f.rule}] "
                         f"{f.symbol + ': ' if f.symbol else ''}{f.message}")
        for entry in stale:
            lines.append(
                f"{baseline_path.name}: stale suppression {entry} — the "
                f"finding no longer exists; delete the entry"
            )
        if not lines:
            lines.append(
                f"analysis clean: {len(findings)} finding(s) total, "
                f"{len(suppressed)} suppressed, rules {','.join(RULES)}"
            )
        else:
            lines.append(
                f"{len(active)} finding(s) ({len(suppressed)} suppressed, "
                f"{len(stale)} stale suppression(s))"
            )
        text = "\n".join(lines)

    print(text)
    if args.out is not None:
        args.out.write_text(
            text + ("\n" if not text.endswith("\n") else ""), encoding="utf-8"
        )
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
