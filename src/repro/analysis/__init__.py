"""``repro.analysis`` — AST-based invariant checker for the runtime's
concurrency, determinism, and wire contracts.

PR 7 split the runtime across real OS processes and threads; the
correctness of that split rests on invariants that used to live only as
prose in docs/ARCHITECTURE.md.  This package machine-checks them on
every commit (CI job ``analysis``; also wrapped into tier-1 by
``tests/test_analysis.py``):

=====  ====================================================================
Rule   Guarantee protected
=====  ====================================================================
R1     blocking-in-async: nothing reachable from the runtime's ``async
       def`` bodies may block the event loop (``@worker_side`` code and
       annotated ``@loop_only(blocking=…)`` sections excepted)
R2     affinity: the multiproc data channel is single-consumer
       (``@loop_only`` readers only) and master-side mirrors /
       ``Master`` queues mutate only on the loop thread, never
       worker-side
R3     frozen reference: ``core/sim_reference.py`` is pinned by content
       hash and importable only from the equivalence/parity allowlist
R4     wire contract: every class pickled across the transport has its
       field set registered in ``wire_manifest.json`` and round-tripped
       by ``tests/test_wire_contract.py``
R5     determinism: no wall-clock reads, ambient RNG, or set-order
       iteration in ``core/`` sim paths
R6     event schema: every ``bus.emit`` call site in ``src/`` matches the
       pinned field set in ``obs/event_manifest.json``, no manifest entry
       is stale, and every entry is exercised by the schema test
R7     protocol model: the master↔worker state machines extracted from
       the runtime's ASTs match ``protocol/protocol_manifest.json``, and
       the committed machines pass an exhaustive bounded model check
       (at-least-once delivery, no duplicate completion, kill-harvest
       safety) over every interleaving with SIGKILL injection
R8     trace conformance: recorded ``events.jsonl`` logs replay cleanly
       against the protocol machines (only runs when ``--events`` paths
       are given; CI feeds it the smoke runs' logs)
=====  ====================================================================

Run it with ``python -m repro.analysis`` (see ``__main__.py``).  The
checker is stdlib-only — it parses the tree, it never imports it.
"""

from __future__ import annotations

from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional

from .baseline import DEFAULT_BASELINE_NAME, apply_baseline, load_baseline
from .model import ANALYZED_TREES, Finding, RepoIndex
from .rules_concurrency import check_affinity, check_blocking_in_async
from .rules_contracts import check_frozen_reference, check_wire_contract
from .rules_determinism import check_determinism
from .rules_obs import check_event_schema
from .protocol.rules import check_protocol_model, check_trace_conformance

__all__ = [
    "RULES",
    "Finding",
    "RepoIndex",
    "run_analysis",
    "apply_baseline",
    "load_baseline",
    "DEFAULT_BASELINE_NAME",
    "ANALYZED_TREES",
]

#: rule id -> (checker, one-line description); order is report order.
RULES: Dict[str, tuple] = {
    "R1": (
        check_blocking_in_async,
        "no blocking calls reachable from runtime async code",
    ),
    "R2": (
        check_affinity,
        "single-consumer data channel + loop-thread-only mirror/queue mutation",
    ),
    "R3": (
        check_frozen_reference,
        "core/sim_reference.py content-hash pin + import allowlist",
    ),
    "R4": (
        check_wire_contract,
        "transport-pickled field sets registered and contract-tested",
    ),
    "R5": (
        check_determinism,
        "no wall-clock, ambient RNG, or set-order iteration in core/",
    ),
    "R6": (
        check_event_schema,
        "bus-emitted event types pinned in the event-schema manifest + tested",
    ),
    "R7": (
        check_protocol_model,
        "protocol machines match the manifest and model-check clean",
    ),
    "R8": (
        check_trace_conformance,
        "recorded event logs replay cleanly against the protocol machines",
    ),
}


def run_analysis(
    root: Path,
    rules: Optional[Iterable[str]] = None,
    index: Optional[RepoIndex] = None,
    events: Optional[Iterable[Path]] = None,
) -> List[Finding]:
    """Run the selected rules (default: all) over the tree at ``root``.

    Returns findings sorted by (rule, path, line).  Parse failures in any
    analyzed file are reported under the pseudo-rule ``parse`` regardless
    of the selection — an unparseable file is never a clean file.

    ``events`` is R8's input: paths to ``events.jsonl`` files (or
    directories holding them) to replay against the protocol machines.
    With no paths, R8 is a clean no-op.
    """
    root = Path(root)
    if index is None:
        index = RepoIndex(root)
    selected = list(rules) if rules is not None else list(RULES)
    unknown = [r for r in selected if r not in RULES]
    if unknown:
        raise ValueError(f"unknown rules {unknown}; available: {list(RULES)}")
    findings: List[Finding] = list(index.parse_findings)
    event_paths = list(events) if events is not None else None
    for rule_id in selected:
        checker: Callable = RULES[rule_id][0]
        if rule_id == "R8":
            findings.extend(checker(index, root, event_paths))
        else:
            findings.extend(checker(index, root))
    return sorted(findings, key=lambda f: (f.rule, f.path, f.line, f.message))
