"""CLI for the protocol model checker.

Usage::

    PYTHONPATH=src python -m repro.analysis.protocol extract [--write|--diff]
    PYTHONPATH=src python -m repro.analysis.protocol check [--mutate EVENT]
    PYTHONPATH=src python -m repro.analysis.protocol conformance LOG [LOG...]

``extract`` rebuilds the machines from the tree (``--write`` updates the
committed manifest, ``--diff`` exits 1 on drift and can dump a drift
report with ``--out``); ``check`` exhaustively explores the bounded
configuration and prints the counterexample trace on a violation
(``--mutate msg.requeued`` demonstrates one); ``conformance`` replays
event logs.  Exit codes: 0 clean, 1 findings/violations, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from ..model import RepoIndex
from .conformance import load_events_file, replay_events
from .explore import BoundedConfig, drop_transition, explore, render_trace
from .extract import extract_protocol
from .machines import PROTOCOL_MANIFEST_PATH, diff_manifests
from .rules import iter_event_logs


def _find_root(start: Path) -> Path:
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit(
        f"error: no src/repro tree found at or above {start} "
        f"(pass --root explicitly)"
    )


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.protocol",
        description="extract, model-check, and replay the delivery protocol",
    )
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: nearest ancestor with "
                         "src/repro)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("extract", help="rebuild machines from the tree")
    p.add_argument("--write", action="store_true",
                   help=f"update {PROTOCOL_MANIFEST_PATH}")
    p.add_argument("--diff", action="store_true",
                   help="diff against the committed manifest (exit 1 on "
                        "drift)")
    p.add_argument("--out", type=Path, default=None,
                   help="write the drift/extraction report (JSON) here")

    p = sub.add_parser("check", help="exhaustive bounded model check")
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--pes", type=int, default=1,
                   help="PEs per worker (default 1 → 2 PEs total)")
    p.add_argument("--messages", type=int, default=3)
    p.add_argument("--kills", type=int, default=1)
    p.add_argument("--mutate", default=None, metavar="EVENT",
                   help="drop this transition first (seeded-mutation "
                        "demo, e.g. msg.requeued)")
    p.add_argument("--unsafe-harvest", action="store_true",
                   help="model a kill that harvests the pre-drain mirror "
                        "(the harvest/completion race)")

    p = sub.add_parser("conformance", help="replay event logs")
    p.add_argument("events", nargs="+", type=Path,
                   help="events.jsonl files or directories holding them")

    args = ap.parse_args(argv)
    root = args.root.resolve() if args.root else _find_root(Path.cwd())
    manifest_file = root / PROTOCOL_MANIFEST_PATH

    if args.cmd == "extract":
        index = RepoIndex(root)
        manifest, findings = extract_protocol(index, root)
        for f in findings:
            print(f"{f.path}:{f.line}: [{f.rule}] {f.message}",
                  file=sys.stderr)
        if args.write:
            manifest_file.parent.mkdir(parents=True, exist_ok=True)
            manifest_file.write_text(
                json.dumps(manifest, indent=2, sort_keys=False) + "\n",
                encoding="utf-8",
            )
            print(f"wrote {PROTOCOL_MANIFEST_PATH}")
            return 1 if findings else 0
        if args.diff:
            if not manifest_file.is_file():
                drift = ["committed manifest is missing"]
            else:
                drift = diff_manifests(
                    manifest,
                    json.loads(manifest_file.read_text(encoding="utf-8")),
                )
            report = {
                "drift": drift,
                "extraction_findings": [f.to_json() for f in findings],
                "ok": not drift and not findings,
            }
            if args.out is not None:
                args.out.write_text(json.dumps(report, indent=2) + "\n",
                                    encoding="utf-8")
            for line in drift:
                print(f"drift: {line}")
            print("clean: code and committed manifest agree" if report["ok"]
                  else f"{len(drift)} drift line(s), "
                       f"{len(findings)} extraction finding(s)")
            return 0 if report["ok"] else 1
        print(json.dumps(manifest, indent=2))
        return 1 if findings else 0

    if args.cmd == "check":
        if not manifest_file.is_file():
            print(f"error: {PROTOCOL_MANIFEST_PATH} missing — run "
                  f"extract --write first", file=sys.stderr)
            return 2
        manifest = json.loads(manifest_file.read_text(encoding="utf-8"))
        if args.mutate:
            manifest = drop_transition(manifest, args.mutate)
            print(f"mutated model: dropped every {args.mutate!r} edge")
        cfg = BoundedConfig(workers=args.workers, pes_per_worker=args.pes,
                            messages=args.messages, kills=args.kills)
        result = explore(manifest, cfg,
                         unsafe_harvest=args.unsafe_harvest)
        print(f"explored {result.states} states / "
              f"{result.transitions} transitions "
              f"({cfg.workers} workers x {cfg.pes_per_worker} PE x "
              f"{cfg.messages} messages, {cfg.kills} kill(s))")
        for v in result.violations:
            print(render_trace(v))
        if result.ok:
            print("all delivery invariants hold on every interleaving")
        return 0 if result.ok else 1

    if args.cmd == "conformance":
        if not manifest_file.is_file():
            print(f"error: {PROTOCOL_MANIFEST_PATH} missing", file=sys.stderr)
            return 2
        manifest = json.loads(manifest_file.read_text(encoding="utf-8"))
        logs = iter_event_logs(args.events)
        if not logs:
            print("error: no events.jsonl logs found", file=sys.stderr)
            return 2
        bad = 0
        for log in logs:
            events, errors = load_events_file(log)
            summary = replay_events(events, manifest)
            for err in errors:
                print(f"{log}: {err}", file=sys.stderr)
                bad += 1
            for v in summary.violations:
                print(f"{log}: {v}", file=sys.stderr)
                bad += 1
            print(f"{log}: {summary.events} events, "
                  f"{summary.completed} completed, "
                  f"{summary.requeued} requeued, "
                  f"{summary.backlog} left queued, "
                  f"{len(summary.violations)} violation(s)")
        return 1 if bad else 0

    return 2  # pragma: no cover


if __name__ == "__main__":
    sys.exit(main())
