"""Protocol extraction (rule R7's front half).

Walks the ASTs of the four runtime modules that carry the delivery
protocol — ``runtime/master.py``, ``runtime/worker.py``,
``runtime/lifecycle.py``, ``runtime/transport.py`` — and recovers the
per-entity state machines:

- **states** come from the lifecycle enums (``core.sim.PEState`` /
  ``WorkerState``, parsed not imported) plus the synthetic ``created``
  initial;
- **transitions** come from the ``@transition`` declarations the runtime
  carries next to the code (``runtime.annotations``).  Every declaration
  is verified against evidence in the same function: a ``bus.emit`` of
  the declared event, or a mirror assignment / enum reference of the
  declared destination state.  Conversely, every protocol ``bus.emit``
  site and every ``.state = Enum.MEMBER`` mirror assignment must be
  covered by a declaration — a transition the extractor cannot see is a
  finding, not a silent gap;
- **wire frames** come from every queue ``put``/``put_nowait`` whose
  payload literal starts with a ``_EV_*`` / ``_CMD_*`` tag and every
  dispatch comparison against one, giving each frame its producer and
  consumer sites; data-channel reads outside ``@loop_only`` code break
  the single-consumer invariant and are findings.

The assembled machines are serialized canonically and diffed against the
committed ``protocol_manifest.json`` — drift is a finding, exactly like
R4's wire contract.
"""

from __future__ import annotations

import ast
import json
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from ..model import Finding, FunctionInfo, ModuleIndex, RepoIndex
from ..rules_obs import EVENT_MANIFEST_PATH, _emit_sites
from .machines import (
    ENTITY_SPEC,
    PROTOCOL_MANIFEST_PATH,
    Machine,
    Transition,
    diff_manifests,
    machines_to_manifest,
)

__all__ = ["PROTOCOL_MODULES", "extract_protocol", "extract_findings"]

#: The modules that carry the delivery protocol, in walk order.
PROTOCOL_MODULES = (
    "src/repro/runtime/master.py",
    "src/repro/runtime/worker.py",
    "src/repro/runtime/lifecycle.py",
    "src/repro/runtime/transport.py",
)

_SIM_PATH = "src/repro/core/sim.py"
_FRAME_PREFIXES = ("_EV_", "_CMD_")

_R7 = "R7"


def _finding(path: str, line: int, symbol: str, message: str) -> Finding:
    return Finding(rule=_R7, path=path, line=line, symbol=symbol,
                   message=message)


# ---------------------------------------------------------------------------
# state vocabulary: the lifecycle enums, parsed from core/sim.py
# ---------------------------------------------------------------------------

def _enum_states(index: RepoIndex) -> Dict[str, Set[str]]:
    """{"PEState": {"starting", ...}, "WorkerState": {...}} from the
    enum class bodies (simple ``NAME = ...`` assignments, lowercased)."""
    out: Dict[str, Set[str]] = {}
    mod = index.module(_SIM_PATH)
    if mod is None:
        return out
    for cls_name, cls in mod.classes().items():
        if cls_name not in ("PEState", "WorkerState"):
            continue
        members: Set[str] = set()
        for node in cls.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and not tgt.id.startswith("_"):
                        members.add(tgt.id.lower())
        out[cls_name] = members
    return out


_ENUM_FOR_ENTITY = {"pe": "PEState", "worker": "WorkerState"}


# ---------------------------------------------------------------------------
# per-function facts
# ---------------------------------------------------------------------------

def _decl_transitions(fn: FunctionInfo) -> List[Tuple[dict, int]]:
    """The ``@transition(...)`` declarations on ``fn`` (with lines)."""
    out: List[Tuple[dict, int]] = []
    for dec in getattr(fn.node, "decorator_list", []):
        if not (isinstance(dec, ast.Call) and (
            (isinstance(dec.func, ast.Name) and dec.func.id == "transition")
            or (isinstance(dec.func, ast.Attribute)
                and dec.func.attr == "transition")
        )):
            continue
        decl: dict = {"entity": None, "event": None, "src": None,
                      "dst": None, "failing": False, "scope": None}
        pos = ("entity", "event", "src", "dst")
        ok = True
        for i, arg in enumerate(dec.args):
            if i >= len(pos) or not isinstance(arg, ast.Constant):
                ok = False
                break
            decl[pos[i]] = arg.value
        for kw in dec.keywords:
            if kw.arg in decl and isinstance(kw.value, ast.Constant):
                decl[kw.arg] = kw.value.value
            else:
                ok = False
        decl["_literal"] = ok
        out.append((decl, dec.lineno))
    return out


def _emit_events(node: ast.AST) -> List[Tuple[str, int]]:
    """(event type, line) of every literal ``bus.emit`` under ``node``."""
    wrapper = ast.Module(body=[node], type_ignores=[])  # _emit_sites walks
    out: List[Tuple[str, int]] = []
    for call, _recv in _emit_sites(wrapper):
        if call.args and isinstance(call.args[0], ast.Constant) and \
                isinstance(call.args[0].value, str):
            out.append((call.args[0].value, call.lineno))
    return out


def _enum_refs(node: ast.AST) -> Set[Tuple[str, str]]:
    """Every ``PEState.X`` / ``WorkerState.X`` reference under ``node``."""
    out: Set[Tuple[str, str]] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name) \
                and n.value.id in ("PEState", "WorkerState"):
            out.add((n.value.id, n.attr))
    return out


def _mirror_assignments(tree: ast.Module) -> List[Tuple[str, str, int]]:
    """Every ``<recv>.state = Enum.MEMBER`` mirror assignment in the
    module (receiver other than ``self`` — constructors set the *initial*
    state, which is not a transition).  Returns (enum, member, line)."""
    out: List[Tuple[str, str, int]] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        if not (isinstance(val, ast.Attribute)
                and isinstance(val.value, ast.Name)
                and val.value.id in ("PEState", "WorkerState")):
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Attribute) and tgt.attr == "state" \
                    and not (isinstance(tgt.value, ast.Name)
                             and tgt.value.id == "self"):
                out.append((val.value.id, val.attr, node.lineno))
    return out


def _enclosing_functions(mod: ModuleIndex, line: int) -> List[FunctionInfo]:
    """Every function whose span contains ``line`` (outermost first)."""
    out = [
        fn for fn in mod.functions
        if fn.node.lineno <= line <= (fn.node.end_lineno or fn.node.lineno)
    ]
    out.sort(key=lambda fn: fn.node.lineno)
    return out


# ---------------------------------------------------------------------------
# wire frames
# ---------------------------------------------------------------------------

def _frame_names(node: ast.expr) -> List[str]:
    if isinstance(node, ast.Name) and node.id.startswith(_FRAME_PREFIXES):
        return [node.id]
    if isinstance(node, ast.Tuple):
        out: List[str] = []
        for elt in node.elts:
            out.extend(_frame_names(elt))
        return out
    return []


def _receiver_tail(node: ast.expr) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _site(mod: ModuleIndex, line: int) -> str:
    fns = _enclosing_functions(mod, line)
    qual = fns[-1].qualname if fns else "<module>"
    return f"{mod.path}:{qual}"


def _wire_facts(mod: ModuleIndex) -> Tuple[
    Dict[str, Set[str]], Dict[str, Set[str]], List[Tuple[str, int]]
]:
    """(producers, consumers, data_reads) for one module.

    producers/consumers map frame tag name -> site set; data_reads are
    (site, line) of every ``data_q.get``/``get_nowait`` call.
    """
    producers: Dict[str, Set[str]] = {}
    consumers: Dict[str, Set[str]] = {}
    data_reads: List[Tuple[str, int]] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            meth = node.func.attr
            if meth in ("put", "put_nowait") and node.args:
                for name in _frame_names(node.args[0]):
                    producers.setdefault(name, set()).add(
                        _site(mod, node.lineno)
                    )
            elif meth in ("get", "get_nowait") and \
                    "data_q" in _receiver_tail(node.func.value):
                data_reads.append((_site(mod, node.lineno), node.lineno))
        elif isinstance(node, ast.Compare):
            names: List[str] = []
            for side in (node.left, *node.comparators):
                names.extend(_frame_names(side))
            for name in names:
                consumers.setdefault(name, set()).add(
                    _site(mod, node.lineno)
                )
    return producers, consumers, data_reads


# ---------------------------------------------------------------------------
# the extraction pass
# ---------------------------------------------------------------------------

def extract_protocol(
    index: RepoIndex, root: Path
) -> Tuple[dict, List[Finding]]:
    """Extract the protocol manifest from the tree; returns
    (manifest, findings).  Findings cover unverifiable declarations and
    uncovered emit/mirror sites — everything *but* drift against the
    committed manifest (``extract_findings`` adds that)."""
    findings: List[Finding] = []
    enums = _enum_states(index)

    # event vocabulary: R6's manifest (root-relative, like rule R6 reads it)
    vocab: Optional[Set[str]] = None
    ev_file = Path(root) / EVENT_MANIFEST_PATH
    if ev_file.is_file():
        try:
            vocab = set(json.loads(
                ev_file.read_text(encoding="utf-8"))["events"])
        except (json.JSONDecodeError, KeyError):
            findings.append(_finding(
                EVENT_MANIFEST_PATH, 1, "",
                "event manifest unreadable — protocol extraction has no "
                "event vocabulary",
            ))
    else:
        findings.append(_finding(
            EVENT_MANIFEST_PATH, 1, "",
            "event-schema manifest missing — protocol extraction has no "
            "event vocabulary",
        ))

    ignore = {"irm.pack"}
    declared: Dict[Tuple[str, str, str], dict] = {}  # (entity,event,dst)
    covered_events: Dict[str, Set[str]] = {}  # path -> {event@fn-qualname}
    all_producers: Dict[str, Set[str]] = {}
    all_consumers: Dict[str, Set[str]] = {}
    all_data_reads: List[Tuple[ModuleIndex, str, int]] = []

    for mod_path in PROTOCOL_MODULES:
        mod = index.module(mod_path)
        if mod is None:
            continue

        # -- declarations + their evidence --
        for fn in mod.functions:
            for decl, line in _decl_transitions(fn):
                symbol = fn.qualname
                if not decl.pop("_literal", True) or not all(
                    isinstance(decl[k], str)
                    for k in ("entity", "event", "src", "dst")
                ):
                    findings.append(_finding(
                        mod.path, line, symbol,
                        "@transition arguments must be string literals",
                    ))
                    continue
                entity, event = decl["entity"], decl["event"]
                if entity not in ENTITY_SPEC:
                    findings.append(_finding(
                        mod.path, line, symbol,
                        f"@transition entity {entity!r} is unknown "
                        f"(expected one of {sorted(ENTITY_SPEC)})",
                    ))
                    continue
                internal = "." not in event
                if not internal and vocab is not None and event not in vocab:
                    findings.append(_finding(
                        mod.path, line, symbol,
                        f"@transition event {event!r} is not registered in "
                        f"{EVENT_MANIFEST_PATH}",
                    ))
                    continue
                # state vocabulary check against the lifecycle enums
                enum_name = _ENUM_FOR_ENTITY.get(entity)
                spec = ENTITY_SPEC[entity]
                if enum_name and enum_name in enums:
                    legal = enums[enum_name] | {spec["initial"]}
                    for st in (*decl["src"].split("|"), decl["dst"]):
                        if st not in legal:
                            findings.append(_finding(
                                mod.path, line, symbol,
                                f"@transition state {st!r} is not a "
                                f"{enum_name} member (have "
                                f"{sorted(legal)})",
                            ))
                # evidence: an emit of the event, or a reference to the
                # destination enum member (mirror assignment / guard)
                emits = {ev for ev, _ in _emit_events(fn.node)}
                refs = _enum_refs(fn.node)
                has_emit = event in emits
                has_state = enum_name is not None and any(
                    en == enum_name and member.lower() == decl["dst"]
                    for en, member in refs
                )
                if not (has_emit or has_state):
                    findings.append(_finding(
                        mod.path, line, symbol,
                        f"stale @transition: no bus.emit({event!r}) and no "
                        f"{decl['dst']!r} state reference in this function "
                        f"— the declaration has no evidence in the code",
                    ))
                    continue
                key = (entity, event, decl["dst"])
                site = f"{mod.path}:{fn.qualname}"
                merged = declared.get(key)
                if merged is None:
                    declared[key] = {
                        "src": set(decl["src"].split("|")),
                        "failing": bool(decl["failing"]),
                        "scope": decl["scope"],
                        "sites": {site},
                    }
                else:
                    if (bool(decl["failing"]), decl["scope"]) != (
                        merged["failing"], merged["scope"]
                    ):
                        findings.append(_finding(
                            mod.path, line, symbol,
                            f"conflicting @transition flags for "
                            f"{entity}/{event}->{decl['dst']} across "
                            f"declaration sites",
                        ))
                    merged["src"].update(decl["src"].split("|"))
                    merged["sites"].add(site)
                covered_events.setdefault(mod.path, set()).add(
                    f"{event}@{fn.qualname}"
                )

        # -- obligation 1: every protocol emit site is declared --
        for event, line in _emit_events(mod.tree):
            if event in ignore or (vocab is not None and event not in vocab):
                continue  # non-protocol / R6's problem
            entity = event.split(".", 1)[0]
            if entity not in ENTITY_SPEC:
                continue
            fns = _enclosing_functions(mod, line)
            cov = covered_events.get(mod.path, set())
            if not any(f"{event}@{fn.qualname}" in cov for fn in fns):
                symbol = fns[-1].qualname if fns else ""
                findings.append(_finding(
                    mod.path, line, symbol,
                    f"emit of {event!r} is not covered by a @transition "
                    f"declaration — the extractor cannot see this "
                    f"transition; declare it on the enclosing function",
                ))

        # -- obligation 2: every mirror assignment is declared --
        for enum_name, member, line in _mirror_assignments(mod.tree):
            entity = {"PEState": "pe", "WorkerState": "worker"}[enum_name]
            dst = member.lower()
            fns = _enclosing_functions(mod, line)
            ok = False
            for fn in fns:
                for decl, _l in _decl_transitions(fn):
                    if decl.get("entity") == entity and decl.get("dst") == dst:
                        ok = True
            if not ok:
                symbol = fns[-1].qualname if fns else ""
                findings.append(_finding(
                    mod.path, line, symbol,
                    f"mirror assignment .state = {enum_name}.{member} is "
                    f"not covered by a @transition(entity={entity!r}, ..., "
                    f"dst={dst!r}) on the enclosing function",
                ))

        # -- wire frames --
        prod, cons, reads = _wire_facts(mod)
        for name, sites in prod.items():
            all_producers.setdefault(name, set()).update(sites)
        for name, sites in cons.items():
            all_consumers.setdefault(name, set()).update(sites)
        all_data_reads.extend((mod, s, line) for s, line in reads)

    # single-consumer: every data-channel read runs in @loop_only code
    for mod, site, line in all_data_reads:
        fns = _enclosing_functions(mod, line)
        if not fns or not fns[-1].loop_only:
            findings.append(_finding(
                mod.path, line, fns[-1].qualname if fns else "",
                "data-channel read outside a @loop_only function breaks "
                "the single-consumer invariant",
            ))

    machines: Dict[str, Machine] = {}
    for entity, spec in ENTITY_SPEC.items():
        transitions = [
            Transition(
                entity=entity,
                event=event,
                src=tuple(sorted(d["src"])),
                dst=dst,
                failing=d["failing"],
                scope=d["scope"],
                sites=tuple(sorted(d["sites"])),
            )
            for (ent, event, dst), d in declared.items()
            if ent == entity
        ]
        if not transitions:
            continue
        machines[entity] = Machine(
            entity=entity,
            key=tuple(spec["key"]),
            initial=str(spec["initial"]),
            terminal=tuple(spec["terminal"]),
            transitions=transitions,
        )

    wire = {
        "frames": {
            name: {
                "channel": "data" if name.startswith("_EV_") else "cmd",
                "producers": sorted(all_producers.get(name, ())),
                "consumers": sorted(all_consumers.get(name, ())),
            }
            for name in sorted(set(all_producers) | set(all_consumers))
        },
        "data_readers": sorted({
            s for _m, s, _l in all_data_reads
        }),
    }
    return machines_to_manifest(machines, wire), findings


def extract_findings(index: RepoIndex, root: Path) -> List[Finding]:
    """Extraction findings + drift against the committed manifest."""
    manifest, findings = extract_protocol(index, root)
    committed_file = Path(root) / PROTOCOL_MANIFEST_PATH
    if not committed_file.is_file():
        findings.append(_finding(
            PROTOCOL_MANIFEST_PATH, 1, "",
            "protocol manifest is missing from the tree — regenerate with "
            "python -m repro.analysis.protocol extract --write",
        ))
        return findings
    try:
        committed = json.loads(committed_file.read_text(encoding="utf-8"))
    except json.JSONDecodeError as exc:
        findings.append(_finding(
            PROTOCOL_MANIFEST_PATH, 1, "",
            f"protocol manifest is not valid JSON: {exc.msg}",
        ))
        return findings
    for line in diff_manifests(manifest, committed):
        findings.append(_finding(
            PROTOCOL_MANIFEST_PATH, 1, "",
            f"protocol drift: {line} — regenerate with python -m "
            f"repro.analysis.protocol extract --write",
        ))
    return findings
