"""Rules R7 (protocol model) and R8 (trace conformance).

R7 = extraction + drift + bounded model check, all static:

- every ``@transition`` declaration in the four protocol modules is
  verified against AST evidence, and every protocol ``bus.emit`` /
  mirror assignment is covered by a declaration (``extract.py``);
- the assembled machines must equal the committed
  ``protocol_manifest.json`` (drift findings, like R4);
- the committed machines are then *model-checked*: the bounded
  2-worker × 1-PE × 3-message configuration with one injectable SIGKILL
  is exhaustively explored and the delivery invariants (at-least-once,
  no duplicate completion, pull-from-queue-only, harvest never races a
  completion) must hold on every interleaving.  A violation carries its
  counterexample trace in the finding message.

R8 replays recorded ``events.jsonl`` logs against the same machines —
it only fires when the CLI is given ``--events``; with no logs to check
it is a clean no-op (CI feeds it the smoke runs' logs).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional, Sequence

from ..model import Finding, RepoIndex
from .conformance import load_events_file, replay_events
from .explore import BoundedConfig, explore
from .extract import extract_findings
from .machines import PROTOCOL_MANIFEST_PATH

__all__ = ["check_protocol_model", "check_trace_conformance",
           "iter_event_logs"]


def check_protocol_model(index: RepoIndex, root) -> List[Finding]:
    """R7: extraction ↔ manifest ↔ bounded model check."""
    findings = extract_findings(index, Path(root))
    manifest_file = Path(root) / PROTOCOL_MANIFEST_PATH
    if not manifest_file.is_file():
        return findings  # extract_findings already flagged it
    try:
        committed = json.loads(manifest_file.read_text(encoding="utf-8"))
    except json.JSONDecodeError:
        return findings  # already flagged
    if not committed.get("entities"):
        return findings
    result = explore(committed, BoundedConfig())
    for v in result.violations:
        trace = "; ".join(v.trace[-8:])
        findings.append(Finding(
            rule="R7",
            path=PROTOCOL_MANIFEST_PATH,
            line=1,
            symbol=v.invariant,
            message=(
                f"model-check violation [{v.invariant}]: {v.message} "
                f"(counterexample tail: {trace}; full trace via python -m "
                f"repro.analysis.protocol check)"
            ),
        ))
    return findings


def iter_event_logs(paths: Sequence[Path]) -> List[Path]:
    """Expand files/directories into concrete events.jsonl paths."""
    logs: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            logs.extend(sorted(p.rglob("events.jsonl")))
        else:
            logs.append(p)
    return logs


def check_trace_conformance(
    index: RepoIndex, root, events: Optional[Sequence[Path]] = None
) -> List[Finding]:
    """R8: replay the given event logs against the committed machines.

    With no ``--events`` paths this is a clean no-op; a missing or
    unreadable log is a finding, never a crash.
    """
    if not events:
        return []
    findings: List[Finding] = []
    root = Path(root)
    manifest_file = root / PROTOCOL_MANIFEST_PATH
    if not manifest_file.is_file():
        return [Finding(
            rule="R8", path=PROTOCOL_MANIFEST_PATH, line=1, symbol="",
            message="protocol manifest is missing — cannot replay logs",
        )]
    try:
        manifest = json.loads(manifest_file.read_text(encoding="utf-8"))
    except json.JSONDecodeError as exc:
        return [Finding(
            rule="R8", path=PROTOCOL_MANIFEST_PATH, line=1, symbol="",
            message=f"protocol manifest is not valid JSON: {exc.msg}",
        )]

    logs = iter_event_logs(events)
    if not logs:
        findings.append(Finding(
            rule="R8", path=str(events[0]), line=0, symbol="",
            message="no events.jsonl logs found under the given --events "
                    "paths",
        ))
    for log in logs:
        try:
            rel = log.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = str(log)
        evs, errors = load_events_file(log)
        for err in errors:
            findings.append(Finding(
                rule="R8", path=rel, line=0, symbol="",
                message=f"unparseable log content: {err}",
            ))
        summary = replay_events(evs, manifest)
        for v in summary.violations:
            findings.append(Finding(
                rule="R8", path=rel, line=max(v.seq, 0),
                symbol=f"{v.entity}:{','.join(str(k) for k in v.key)}",
                message=f"trace conformance: {v}",
            ))
    return findings
