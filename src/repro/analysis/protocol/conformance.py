"""Trace conformance (rule R8's replay core).

Replays a recorded ``events.jsonl`` log against the protocol machines:
every event advances the machine of each entity that carries it, keyed
by the event's identity fields (``msg_id`` for messages, ``worker`` for
slots, ``(worker, pe)`` for PEs).  Violations are happens-before bugs
the event schema alone cannot see — a ``msg.pulled`` with no preceding
``msg.enqueued``/``msg.requeued``, a second completion for the same
message, events for a worker slot after its failing ``worker.kill``.

Internal transitions (``ready`` — no dot in the event name) never
appear in logs; the replay closes over them as ε-edges, so a
zero-boot-delay worker that was born active or a PE whose readiness
event is unobserved does not fail conformance.

End-of-log semantics: a message still ``pulled``/``started`` when the
log ends is in-flight limbo — delivery was lost, a violation.  Messages
still ``enqueued``/``requeued``/unseen are *backlog*, not a violation:
the live driver legitimately exits early under ``starvation_grace``
with work still queued.  The backlog count is reported in the summary.

Shared by ``python -m repro.analysis --rules R8 --events <dir>`` and
``python -m repro.obs conformance <log>``.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .machines import Machine, machines_from_manifest

__all__ = ["ConformanceViolation", "ReplaySummary", "replay_events",
           "load_events_file"]


@dataclasses.dataclass
class ConformanceViolation:
    seq: int
    event: str
    entity: str
    key: tuple
    message: str

    def __str__(self) -> str:
        key = ",".join(str(k) for k in self.key)
        return (f"seq {self.seq}: {self.event} [{self.entity} {key}] "
                f"{self.message}")


@dataclasses.dataclass
class ReplaySummary:
    events: int = 0
    violations: List[ConformanceViolation] = dataclasses.field(
        default_factory=list)
    #: messages the log ends with still queued (legal: starvation-grace
    #: early exit) — reported, not flagged
    backlog: int = 0
    completed: int = 0
    requeued: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations


def load_events_file(path: Path) -> Tuple[List[dict], List[str]]:
    """(events, errors) from a JSONL log; bad lines are errors, not
    crashes — a truncated log from a killed run must still replay."""
    events: List[dict] = []
    errors: List[str] = []
    try:
        text = Path(path).read_text(encoding="utf-8")
    except OSError as exc:
        return [], [f"unreadable log {path}: {exc}"]
    for n, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError:
            errors.append(f"{path}:{n}: not valid JSON — skipped")
            continue
        if not isinstance(ev, dict) or "ev" not in ev:
            errors.append(f"{path}:{n}: not an event envelope — skipped")
            continue
        events.append(ev)
    return events, errors


def _epsilon_reach(machine: Machine, state: str, targets: Set[str]
                   ) -> Optional[str]:
    """Follow internal ε-edges from ``state`` to any state in
    ``targets``; returns the reached state or None."""
    seen = {state}
    frontier = [state]
    while frontier:
        cur = frontier.pop()
        if cur in targets:
            return cur
        for tr in machine.internal_edges():
            if cur in tr.src and tr.dst not in seen:
                seen.add(tr.dst)
                frontier.append(tr.dst)
    return None


def replay_events(
    events: Iterable[dict], manifest: dict, strict_end: bool = True
) -> ReplaySummary:
    """Replay a log against the manifest's machines."""
    machines = machines_from_manifest(manifest)
    ignore = set(manifest.get("ignore_events", ()))
    summary = ReplaySummary()

    # entity -> key -> state; dead instances reject every further event
    states: Dict[str, Dict[tuple, str]] = {m: {} for m in machines}
    dead: Dict[str, Set[tuple]] = {m: set() for m in machines}
    # pe ownership, for scope="worker" transitions
    pes_of_worker: Dict[object, Set[tuple]] = {}

    known_events: Dict[str, List[str]] = {}
    for name, machine in machines.items():
        for ev in machine.events():
            known_events.setdefault(ev, []).append(name)

    for ev in events:
        etype = ev.get("ev")
        seq = int(ev.get("seq", summary.events))
        summary.events += 1
        if etype in ignore or etype not in known_events:
            continue
        if etype == "msg.completed":
            summary.completed += 1
        elif etype == "msg.requeued":
            summary.requeued += 1
        for entity in known_events[etype]:
            machine = machines[entity]
            transitions = machine.by_event(etype)
            scoped = [tr for tr in transitions if tr.scope == "worker"]
            if scoped and entity == "pe":
                # apply to every PE owned by the event's worker; PEs not
                # in a source state (already stopped) are skipped
                widx = ev.get("worker")
                for pe_key in sorted(pes_of_worker.get(widx, ()),
                                     key=str):
                    st = states[entity].get(pe_key, machine.initial)
                    for tr in scoped:
                        landed = st if st in tr.src else _epsilon_reach(
                            machine, st, set(tr.src))
                        if landed is not None:
                            states[entity][pe_key] = tr.dst
                            break
                continue
            try:
                key = tuple(ev[f] for f in machine.key)
            except KeyError as exc:
                summary.violations.append(ConformanceViolation(
                    seq, etype, entity, (),
                    f"event lacks identity field {exc.args[0]!r}",
                ))
                continue
            st = states[entity].get(key)
            if key in dead[entity]:
                summary.violations.append(ConformanceViolation(
                    seq, etype, entity, key,
                    f"event for a failed {entity} instance — a killed "
                    f"slot must never produce further events",
                ))
                continue
            if st is None:
                st = machine.initial
            if st in machine.terminal:
                summary.violations.append(ConformanceViolation(
                    seq, etype, entity, key,
                    f"event after terminal state {st!r}"
                    + (" — duplicate completion"
                       if etype == "msg.completed" else ""),
                ))
                continue
            applied = False
            for tr in transitions:
                landed = st if st in tr.src else _epsilon_reach(
                    machine, st, set(tr.src))
                if landed is None:
                    continue
                states[entity][key] = tr.dst
                if tr.failing:
                    dead[entity].add(key)
                applied = True
                break
            if not applied:
                allowed = sorted({s for tr in transitions for s in tr.src})
                summary.violations.append(ConformanceViolation(
                    seq, etype, entity, key,
                    f"illegal from state {st!r} (allowed from {allowed})",
                ))
                continue
            if entity == "pe":
                pes_of_worker.setdefault(ev.get("worker"), set()).add(key)

    if strict_end and "msg" in machines:
        for key, st in sorted(states["msg"].items(), key=str):
            if st in ("pulled", "started"):
                summary.violations.append(ConformanceViolation(
                    -1, "<end-of-log>", "msg", key,
                    f"log ends with the message in-flight (state {st!r}) "
                    f"— neither completed nor requeued: delivery lost",
                ))
            elif st not in ("completed",):
                summary.backlog += 1
    return summary
