"""Entity state machines and their committed manifest.

The delivery protocol is modeled as three state machines — one per
entity kind the runtime tracks:

``msg``
    a stream message: ``created → enqueued → pulled → started →
    completed``, with ``requeued`` re-entering the pull edge (the
    at-least-once path a worker kill takes);
``worker``
    a worker slot: ``created → booting → active → off`` (scale-down) or
    ``→ off`` via the failing ``worker.kill`` edge (the slot is dead and
    never reboots);
``pe``
    a processing element: ``created → starting → idle ⇄ busy → stopped``.

Transitions are *declared in the runtime itself* with the
``@transition`` decorator (``runtime.annotations``); ``extract.py``
verifies each declaration against AST evidence, assembles the machines,
and diffs them against the committed ``protocol_manifest.json`` next to
this module (rule R7).  The same machines drive the explicit-state model
checker (``explore.py``) and the event-log replay (``conformance.py``,
rule R8) — one model, three consumers.

A transition whose ``event`` contains no dot (e.g. ``ready``) is
*internal*: a state change that produces no observability event.  The
replay treats internal edges as ε-transitions; the explorer schedules
them as ordinary steps.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Transition",
    "Machine",
    "ENTITY_SPEC",
    "PROTOCOL_MANIFEST_PATH",
    "machines_to_manifest",
    "machines_from_manifest",
    "load_committed_manifest",
    "diff_manifests",
]

#: Repo-relative path of the committed protocol manifest.
PROTOCOL_MANIFEST_PATH = "src/repro/analysis/protocol/protocol_manifest.json"

#: Per-entity structure that is not itself extracted: the event fields
#: that key an instance, the initial/terminal states, and which
#: ``core.sim`` enum (if any) the state names must come from.
ENTITY_SPEC: Dict[str, Dict[str, object]] = {
    "msg": {
        "key": ("msg_id",),
        "initial": "created",
        "terminal": ("completed",),
        "enum": None,
    },
    "worker": {
        "key": ("worker",),
        "initial": "created",
        "terminal": (),
        "enum": "WorkerState",
    },
    "pe": {
        "key": ("worker", "pe"),
        "initial": "created",
        "terminal": ("stopped",),
        "enum": "PEState",
    },
}


@dataclasses.dataclass(frozen=True)
class Transition:
    """One edge of an entity machine (possibly declared at many sites)."""

    entity: str
    event: str            # pinned event type, or internal name (no dot)
    src: Tuple[str, ...]  # sorted source states
    dst: str
    failing: bool = False
    scope: Optional[str] = None   # None or "worker" (all PEs of the worker)
    sites: Tuple[str, ...] = ()   # "path:qualname" declaration sites

    @property
    def internal(self) -> bool:
        return "." not in self.event

    def to_json(self) -> Dict[str, object]:
        return {
            "event": self.event,
            "src": list(self.src),
            "dst": self.dst,
            "failing": self.failing,
            "scope": self.scope,
            "sites": list(self.sites),
        }


@dataclasses.dataclass
class Machine:
    """One entity's state machine."""

    entity: str
    key: Tuple[str, ...]
    initial: str
    terminal: Tuple[str, ...]
    transitions: List[Transition]

    @property
    def states(self) -> List[str]:
        out = {self.initial, *self.terminal}
        for tr in self.transitions:
            out.update(tr.src)
            out.add(tr.dst)
        return sorted(out)

    def by_event(self, event: str) -> List[Transition]:
        return [tr for tr in self.transitions if tr.event == event]

    def events(self) -> List[str]:
        return sorted({tr.event for tr in self.transitions if not tr.internal})

    def internal_edges(self) -> List[Transition]:
        return [tr for tr in self.transitions if tr.internal]

    def to_json(self) -> Dict[str, object]:
        return {
            "key": list(self.key),
            "initial": self.initial,
            "terminal": list(self.terminal),
            "states": self.states,
            "transitions": [
                tr.to_json()
                for tr in sorted(
                    self.transitions, key=lambda t: (t.event, t.dst, t.src)
                )
            ],
        }


def machines_to_manifest(
    machines: Dict[str, Machine], wire: Optional[dict] = None
) -> dict:
    """Serialize machines (+ the wire-frame section) canonically."""
    return {
        "_comment": (
            "Extracted master-worker protocol (rule R7). Regenerate with: "
            "PYTHONPATH=src python -m repro.analysis.protocol extract --write"
        ),
        "version": 1,
        "entities": {
            name: machines[name].to_json() for name in sorted(machines)
        },
        "wire": wire or {},
        "ignore_events": ["irm.pack"],
    }


def machines_from_manifest(manifest: dict) -> Dict[str, Machine]:
    machines: Dict[str, Machine] = {}
    for name, ent in manifest.get("entities", {}).items():
        machines[name] = Machine(
            entity=name,
            key=tuple(ent["key"]),
            initial=ent["initial"],
            terminal=tuple(ent["terminal"]),
            transitions=[
                Transition(
                    entity=name,
                    event=tr["event"],
                    src=tuple(tr["src"]),
                    dst=tr["dst"],
                    failing=bool(tr.get("failing", False)),
                    scope=tr.get("scope"),
                    sites=tuple(tr.get("sites", ())),
                )
                for tr in ent["transitions"]
            ],
        )
    return machines


def load_committed_manifest() -> dict:
    """The manifest shipped inside this package (runtime consumers —
    the obs ``conformance`` subcommand — load it without needing a repo
    checkout; rule R7 reads the root-relative copy instead so fixture
    trees can pin their own)."""
    here = Path(__file__).resolve().parent
    with open(here / "protocol_manifest.json", encoding="utf-8") as fh:
        return json.load(fh)


def _transition_key(tr: dict) -> Tuple[str, str]:
    return (tr["event"], tr["dst"])


def diff_manifests(extracted: dict, committed: dict) -> List[str]:
    """Human-readable drift lines between two manifests ([] if none)."""
    out: List[str] = []
    ext_e = extracted.get("entities", {})
    com_e = committed.get("entities", {})
    for name in sorted(set(ext_e) - set(com_e)):
        out.append(f"entity {name!r} extracted from code but not committed")
    for name in sorted(set(com_e) - set(ext_e)):
        out.append(f"entity {name!r} committed but no longer extracted")
    for name in sorted(set(ext_e) & set(com_e)):
        ext_t = {_transition_key(t): t for t in ext_e[name]["transitions"]}
        com_t = {_transition_key(t): t for t in com_e[name]["transitions"]}
        for k in sorted(set(ext_t) - set(com_t)):
            out.append(
                f"{name}: transition {k[0]!r}->{k[1]!r} declared in code "
                f"but not committed"
            )
        for k in sorted(set(com_t) - set(ext_t)):
            out.append(
                f"{name}: transition {k[0]!r}->{k[1]!r} committed but no "
                f"longer declared in code"
            )
        for k in sorted(set(ext_t) & set(com_t)):
            for field in ("src", "failing", "scope", "sites"):
                if ext_t[k].get(field) != com_t[k].get(field):
                    out.append(
                        f"{name}: transition {k[0]!r}->{k[1]!r} field "
                        f"{field!r} drifted: code {ext_t[k].get(field)!r} "
                        f"vs committed {com_t[k].get(field)!r}"
                    )
        for field in ("key", "initial", "terminal"):
            if list(ext_e[name].get(field, [])) != list(
                com_e[name].get(field, [])
            ):
                out.append(
                    f"{name}: {field} drifted: code "
                    f"{ext_e[name].get(field)!r} vs committed "
                    f"{com_e[name].get(field)!r}"
                )
    if extracted.get("wire") != committed.get("wire"):
        ext_w, com_w = extracted.get("wire", {}), committed.get("wire", {})
        for section in sorted(set(ext_w) | set(com_w)):
            if ext_w.get(section) != com_w.get(section):
                out.append(
                    f"wire section {section!r} drifted: code "
                    f"{json.dumps(ext_w.get(section), sort_keys=True)} vs "
                    f"committed "
                    f"{json.dumps(com_w.get(section), sort_keys=True)}"
                )
    return out
