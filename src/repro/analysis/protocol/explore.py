"""Explicit-state model checker for the delivery protocol.

Explores *every* interleaving of a bounded configuration (default: 2
workers × 1 PE each × 3 messages, one SIGKILL injectable at any step) of
the message×worker×PE product machine, where the legal moves are read
from the protocol manifest (``machines.py``) — the same machines rule R7
extracts from the runtime and rule R8 replays against event logs.

The model is the **master-side mirror view**, which is what the harvest
path actually works from: a completion a worker flushed before dying
travels the data channel as a frame; ``kill`` first drains the victim's
frames (each nondeterministically applied or lost with the severed
pipe), then harvests whatever the mirror still shows in flight.  The
in-process transport's atomic completion is the interleaving where
``flush`` and ``apply`` run back-to-back, so one model covers both
transports.

Checked invariants (fixed — deliberately *not* read from the manifest,
so a manifest mutation is caught as a violation rather than silently
redefining correctness):

I1  at-least-once / no-loss: every terminal state has every message
    completed exactly once; no reachable state has no enabled action
    while work remains.
I2  no duplicate completion: a message never completes twice.
I3  a message is only pulled out of ``enqueued`` / ``requeued``.
I4  kill-harvest never races a completion: a harvested message is in
    ``pulled``/``started`` — never ``completed`` — at harvest time.

Counterexamples are returned as step-by-step interleaving traces
(``Violation.trace``).  Scale-down (``worker.deactivate``) and PE idle
timeout (``pe.exit``) are excluded from the explored actions: neither
can fire in the bounded configuration (the explorer drives messages
back-to-back, so no PE idles out), and both remain in the machines for
R8's replay.

Seeded-mutation hooks, used by the tests to prove the checker can fail:
``drop_transition(manifest, event)`` removes an edge (dropping
``msg.requeued`` makes the kill path provably lose work), and
``explore(..., unsafe_harvest=True)`` models a kill that harvests from
the pre-drain mirror (the historical harvest/completion race), which I2
and I4 catch with a duplicate-completion trace.
"""

from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from .machines import Machine, machines_from_manifest

__all__ = ["BoundedConfig", "Violation", "ExploreResult", "explore",
           "drop_transition", "render_trace"]


@dataclasses.dataclass(frozen=True)
class BoundedConfig:
    workers: int = 2
    pes_per_worker: int = 1
    messages: int = 3
    kills: int = 1


@dataclasses.dataclass
class Violation:
    invariant: str           # "I1".."I4"
    message: str
    trace: List[str]         # action labels from the initial state


@dataclasses.dataclass
class ExploreResult:
    states: int
    transitions: int
    violations: List[Violation]

    @property
    def ok(self) -> bool:
        return not self.violations


def drop_transition(manifest: dict, event: str, entity: str = None) -> dict:
    """A deep-copied manifest with every ``event`` edge removed (the
    seeded-mutation hook: the checker must produce a counterexample)."""
    mut = json.loads(json.dumps(manifest))
    for name, ent in mut.get("entities", {}).items():
        if entity is not None and name != entity:
            continue
        ent["transitions"] = [
            tr for tr in ent["transitions"] if tr["event"] != event
        ]
    return mut


# ---------------------------------------------------------------------------
# state encoding
#
# workers: tuple of state strings ("created"/"booting"/"active"/"off";
#          a killed slot additionally lands in `dead`)
# pes:     tuple of (state, holder_msg_or_-1, flushed_bool); pe i lives
#          on worker i // pes_per_worker
# msgs:    tuple of (state, done_count)
# kills_left, dead: frozenset of killed worker indices
# ---------------------------------------------------------------------------

State = Tuple[tuple, tuple, tuple, int, FrozenSet[int]]


def _allowed(machine: Optional[Machine], event: str, state: str
             ) -> Optional[str]:
    """dst if the machine allows ``event`` from ``state``, else None."""
    if machine is None:
        return None
    for tr in machine.by_event(event):
        if state in tr.src:
            return tr.dst
    return None


def explore(
    manifest: dict,
    config: BoundedConfig = BoundedConfig(),
    unsafe_harvest: bool = False,
    max_states: int = 2_000_000,
) -> ExploreResult:
    """Breadth-first exploration of every interleaving; stops at the
    first invariant violation (with its counterexample trace) or when
    the reachable space is exhausted."""
    machines = machines_from_manifest(manifest)
    m_msg = machines.get("msg")
    m_wrk = machines.get("worker")
    m_pe = machines.get("pe")
    cfg = config
    n_pes = cfg.workers * cfg.pes_per_worker

    def worker_of(p: int) -> int:
        return p // cfg.pes_per_worker

    init: State = (
        tuple(["created"] * cfg.workers),
        tuple([("created", -1, False)] * n_pes),
        tuple([("created", 0)] * cfg.messages),
        cfg.kills,
        frozenset(),
    )

    parents: Dict[State, Tuple[Optional[State], str]] = {init: (None, "")}
    seen = {init}
    frontier = deque([init])
    n_transitions = 0

    def trace_of(state: State, last: Optional[str] = None) -> List[str]:
        steps: List[str] = []
        cur: Optional[State] = state
        while cur is not None:
            prev, label = parents[cur]
            if label:
                steps.append(label)
            cur = prev
        steps.reverse()
        if last:
            steps.append(last)
        return steps

    def successors(state: State):
        """Yield (label, next_state) — or a Violation raised via list."""
        workers, pes, msgs, kills_left, dead = state

        # worker boot / activate
        for w in range(cfg.workers):
            if w in dead:
                continue
            dst = _allowed(m_wrk, "worker.boot", workers[w])
            if dst is not None:
                nw = list(workers)
                nw[w] = dst
                yield f"boot worker {w}", (tuple(nw), pes, msgs,
                                           kills_left, dead)
            dst = _allowed(m_wrk, "worker.active", workers[w])
            if dst is not None:
                nw = list(workers)
                nw[w] = dst
                yield f"activate worker {w}", (tuple(nw), pes, msgs,
                                               kills_left, dead)

        # message arrival
        for i in range(cfg.messages):
            dst = _allowed(m_msg, "msg.enqueued", msgs[i][0])
            if dst is not None:
                nm = list(msgs)
                nm[i] = (dst, msgs[i][1])
                yield f"enqueue msg {i}", (workers, pes, tuple(nm),
                                           kills_left, dead)

        # PE lifecycle + the pull-execute loop
        for p in range(n_pes):
            w = worker_of(p)
            st, holder, flushed = pes[p]
            if w in dead:
                continue
            # spawn (placement gates on an ACTIVE worker)
            dst = _allowed(m_pe, "pe.spawn", st)
            if dst is not None and workers[w] == "active":
                np_ = list(pes)
                np_[p] = (dst, -1, False)
                yield f"spawn pe {p} on worker {w}", (
                    workers, tuple(np_), msgs, kills_left, dead)
            # internal readiness (ε edges scheduled as ordinary steps)
            for tr in (m_pe.internal_edges() if m_pe else ()):
                if st in tr.src:
                    np_ = list(pes)
                    np_[p] = (tr.dst, holder, flushed)
                    yield f"pe {p} {tr.event} ({st}->{tr.dst})", (
                        workers, tuple(np_), msgs, kills_left, dead)
            # pull: any eligible message (superset of FIFO order)
            if holder == -1:
                pe_dst = _allowed(m_pe, "msg.pulled", st)
                if pe_dst is not None and workers[w] == "active":
                    for i in range(cfg.messages):
                        msg_dst = _allowed(m_msg, "msg.pulled", msgs[i][0])
                        if msg_dst is None:
                            continue
                        if msgs[i][0] not in ("enqueued", "requeued"):
                            raise _Stop(Violation(
                                "I3",
                                f"msg {i} pulled out of state "
                                f"{msgs[i][0]!r} — only enqueued/requeued "
                                f"messages may be pulled",
                                trace_of(state, f"pull msg {i} at pe {p}"),
                            ))
                        np_ = list(pes)
                        np_[p] = (pe_dst, i, False)
                        nm = list(msgs)
                        nm[i] = (msg_dst, msgs[i][1])
                        yield f"pull msg {i} at pe {p}", (
                            workers, tuple(np_), tuple(nm),
                            kills_left, dead)
            else:
                i = holder
                # start executing
                msg_dst = _allowed(m_msg, "msg.started", msgs[i][0])
                if msg_dst is not None:
                    nm = list(msgs)
                    nm[i] = (msg_dst, msgs[i][1])
                    yield f"start msg {i} at pe {p}", (
                        workers, pes, tuple(nm), kills_left, dead)
                # flush the completion frame onto the data channel
                if not flushed and msgs[i][0] == "started":
                    np_ = list(pes)
                    np_[p] = (st, holder, True)
                    yield f"flush completion of msg {i} from pe {p}", (
                        workers, tuple(np_), msgs, kills_left, dead)
                # master applies the frame (poller / inproc bookkeeping)
                if flushed:
                    msg_dst = _allowed(m_msg, "msg.completed", msgs[i][0])
                    pe_dst = _allowed(m_pe, "msg.completed", st)
                    if msg_dst is not None and pe_dst is not None:
                        done = msgs[i][1] + 1
                        if done > 1:
                            raise _Stop(Violation(
                                "I2",
                                f"msg {i} completed {done} times",
                                trace_of(state,
                                         f"apply completion of msg {i}"),
                            ))
                        np_ = list(pes)
                        np_[p] = (pe_dst, -1, False)
                        nm = list(msgs)
                        nm[i] = (msg_dst, done)
                        yield f"apply completion of msg {i} from pe {p}", (
                            workers, tuple(np_), tuple(nm),
                            kills_left, dead)

        # SIGKILL injection
        if kills_left > 0:
            for w in range(cfg.workers):
                if w in dead or workers[w] in ("created", "off"):
                    continue
                yield from _kill_branches(state, w)

    class _Stop(Exception):
        def __init__(self, violation: Violation):
            self.violation = violation

    def _kill_branches(state: State, w: int):
        workers, pes, msgs, kills_left, dead = state
        my_pes = [p for p in range(n_pes) if worker_of(p) == w]
        flushed_pes = [p for p in my_pes if pes[p][2]]
        # the mirror the harvest works from: post-drain normally,
        # pre-drain under the seeded unsafe_harvest mutation
        for mask in range(1 << len(flushed_pes)):
            applied = {flushed_pes[b] for b in range(len(flushed_pes))
                       if mask & (1 << b)}
            np_ = list(pes)
            nm = list(msgs)
            labels = []
            harvest_list = (
                [(p, pes[p][1]) for p in my_pes if pes[p][1] != -1]
                if unsafe_harvest else None
            )
            bad: Optional[Violation] = None
            for p in applied:  # drained frames that survived the pipe
                i = np_[p][1]
                done = nm[i][1] + 1
                if done > 1:
                    bad = Violation(
                        "I2", f"msg {i} completed {done} times",
                        trace_of(state, f"kill worker {w} "
                                        f"(drain applies pe {p})"))
                    break
                dst = _allowed(m_msg, "msg.completed", nm[i][0])
                nm[i] = (dst if dst is not None else nm[i][0], done)
                np_[p] = (np_[p][0], -1, False)
                labels.append(f"apply pe {p}")
            if bad is not None:
                raise _Stop(bad)
            if harvest_list is None:
                harvest_list = [(p, np_[p][1]) for p in my_pes
                                if np_[p][1] != -1]
            for p, i in harvest_list:  # harvest the rest of the mirror
                if nm[i][0] == "completed":
                    raise _Stop(Violation(
                        "I4",
                        f"kill-harvest of worker {w} raced msg {i}'s "
                        f"completion: harvested while already completed",
                        trace_of(state, f"kill worker {w} (harvest "
                                        f"races completion of msg {i})"),
                    ))
                dst = _allowed(m_msg, "msg.requeued", nm[i][0])
                if dst is None:
                    raise _Stop(Violation(
                        "I1",
                        f"kill of worker {w} found msg {i} in state "
                        f"{nm[i][0]!r} with no requeue edge — the "
                        f"message is lost (at-least-once broken)",
                        trace_of(state, f"kill worker {w} (msg {i} "
                                        f"unharvestable)"),
                    ))
                nm[i] = (dst, nm[i][1])
                labels.append(f"requeue msg {i}")
            for p in my_pes:
                np_[p] = ("stopped", -1, False)
            nw = list(workers)
            nw[w] = "off"
            drop = sorted(set(flushed_pes) - applied)
            label = f"kill worker {w}"
            extra = labels + ([f"drop pe {p} frame" for p in drop])
            if extra:
                label += " (" + ", ".join(extra) + ")"
            yield label, (tuple(nw), tuple(np_), tuple(nm),
                          kills_left - 1, dead | {w})

    while frontier:
        state = frontier.popleft()
        any_succ = False
        try:
            for label, nxt in successors(state):
                n_transitions += 1
                any_succ = True
                if nxt not in seen:
                    if len(seen) >= max_states:
                        return ExploreResult(
                            len(seen), n_transitions,
                            [Violation(
                                "bound",
                                f"state-space bound {max_states} hit — "
                                f"shrink the configuration",
                                [])],
                        )
                    seen.add(nxt)
                    parents[nxt] = (state, label)
                    frontier.append(nxt)
        except _Stop as stop:
            return ExploreResult(len(seen), n_transitions, [stop.violation])
        if not any_succ:
            # terminal state: I1 — all work done, exactly once
            msgs = state[2]
            for i, (st, done) in enumerate(msgs):
                if st != "completed" or done != 1:
                    return ExploreResult(
                        len(seen), n_transitions,
                        [Violation(
                            "I1",
                            f"terminal state with msg {i} in state "
                            f"{st!r} (completed {done}x) — work lost "
                            f"or stuck",
                            trace_of(state),
                        )],
                    )
    return ExploreResult(len(seen), n_transitions, [])


def render_trace(violation: Violation) -> str:
    lines = [f"[{violation.invariant}] {violation.message}",
             "counterexample interleaving:"]
    for n, step in enumerate(violation.trace, 1):
        lines.append(f"  step {n:>3}: {step}")
    return "\n".join(lines)
