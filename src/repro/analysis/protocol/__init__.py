"""Protocol model checker: extraction (R7), explicit-state exploration,
and trace conformance (R8) for the master↔worker delivery protocol.

One model, three consumers:

- ``extract.py`` recovers the per-entity state machines (message,
  worker slot, PE) from the runtime's ASTs — ``@transition``
  declarations verified against emit sites, mirror assignments, and
  wire-frame literals — and pins them in ``protocol_manifest.json``;
- ``explore.py`` exhaustively explores a bounded configuration of the
  product machine with SIGKILL injection, proving the delivery
  invariants over *every* interleaving;
- ``conformance.py`` replays recorded ``events.jsonl`` logs against the
  same machines, catching happens-before violations offline.

CLI: ``python -m repro.analysis.protocol {extract,check,conformance}``.
"""

from .conformance import (
    ConformanceViolation,
    ReplaySummary,
    load_events_file,
    replay_events,
)
from .explore import (
    BoundedConfig,
    ExploreResult,
    Violation,
    drop_transition,
    explore,
    render_trace,
)
from .extract import PROTOCOL_MODULES, extract_findings, extract_protocol
from .machines import (
    ENTITY_SPEC,
    PROTOCOL_MANIFEST_PATH,
    Machine,
    Transition,
    diff_manifests,
    load_committed_manifest,
    machines_from_manifest,
    machines_to_manifest,
)
from .rules import check_protocol_model, check_trace_conformance

__all__ = [
    "BoundedConfig",
    "ConformanceViolation",
    "ENTITY_SPEC",
    "ExploreResult",
    "Machine",
    "PROTOCOL_MANIFEST_PATH",
    "PROTOCOL_MODULES",
    "ReplaySummary",
    "Transition",
    "Violation",
    "check_protocol_model",
    "check_trace_conformance",
    "diff_manifests",
    "drop_transition",
    "explore",
    "extract_findings",
    "extract_protocol",
    "load_committed_manifest",
    "load_events_file",
    "machines_from_manifest",
    "machines_to_manifest",
    "render_trace",
    "replay_events",
]
