"""R6 (event-schema manifest): every observability event the runtime can
emit is pinned.

PR 9 added the observability plane: an :class:`~repro.obs.bus.EventBus`
threaded through all three backends, with every ``bus.emit(...)`` call
producing an event whose payload schema must be byte-identical across
sim, inproc-live, and multiproc.  The runtime half of that pin is the
cross-backend schema-equality test; this rule is the static half.  It
checks, for every ``.emit`` call on a bus-shaped receiver in ``src/``:

- the event type is a string literal (a computed type cannot be pinned),
- the type is registered in ``repro/obs/event_manifest.json`` (drift:
  a new event emitted without updating the manifest),
- the keyword fields at the call site are exactly the manifest's field
  set for that type (payloads are keyword-only, so the AST *is* the
  schema),

and, mirroring R4's stale/exercised semantics:

- every manifest entry has at least one live emit site (stale manifest),
- every manifest entry appears in the schema test named by the
  manifest's ``schema_test`` key, so a schema regression on any type
  fails a test rather than sailing through.
"""

from __future__ import annotations

import ast
import json
from pathlib import Path
from typing import Dict, List, Set, Tuple

from .model import Finding, RepoIndex
from .rules_contracts import _test_tokens

__all__ = ["check_event_schema", "EVENT_MANIFEST_PATH"]

#: Repo-relative path of the pinned event-schema manifest.
EVENT_MANIFEST_PATH = "src/repro/obs/event_manifest.json"


def _is_bus_receiver(node: ast.expr) -> bool:
    """True for receivers that are observably the event bus: a bare name
    containing ``bus`` (``bus``, ``self.bus`` unwraps to attr below) or an
    attribute access ending in ``.bus`` (``self.master.bus``)."""
    if isinstance(node, ast.Name):
        return "bus" in node.id
    if isinstance(node, ast.Attribute):
        return node.attr == "bus" or "bus" in node.attr
    return False


def _emit_sites(tree: ast.Module) -> List[Tuple[ast.Call, ast.expr]]:
    out: List[Tuple[ast.Call, ast.expr]] = []
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "emit"
            and _is_bus_receiver(node.func.value)
        ):
            out.append((node, node.func.value))
    return out


def check_event_schema(index: RepoIndex, root) -> List[Finding]:
    """R6: bus.emit call sites ↔ event manifest ↔ schema test."""
    findings: List[Finding] = []
    manifest_file = Path(root) / EVENT_MANIFEST_PATH
    if not manifest_file.is_file():
        return [
            Finding(
                rule="R6",
                path=EVENT_MANIFEST_PATH,
                line=1,
                symbol="",
                message="event-schema manifest is missing from the tree",
            )
        ]
    manifest = json.loads(manifest_file.read_text(encoding="utf-8"))
    events: Dict[str, List[str]] = manifest["events"]
    emitted_types: Set[str] = set()

    for mod in index.modules.values():
        if not mod.path.startswith("src/"):
            continue
        for call, _recv in _emit_sites(mod.tree):
            if not call.args or not (
                isinstance(call.args[0], ast.Constant)
                and isinstance(call.args[0].value, str)
            ):
                findings.append(
                    Finding(
                        rule="R6",
                        path=mod.path,
                        line=call.lineno,
                        symbol="",
                        message=(
                            "bus.emit with a non-literal event type — the "
                            "schema pin needs a string constant"
                        ),
                    )
                )
                continue
            ev = call.args[0].value
            emitted_types.add(ev)
            if ev not in events:
                findings.append(
                    Finding(
                        rule="R6",
                        path=mod.path,
                        line=call.lineno,
                        symbol="",
                        message=(
                            f"event type {ev!r} is emitted but not registered "
                            f"in {EVENT_MANIFEST_PATH} — register its field "
                            f"set AND exercise it in "
                            f"{manifest['schema_test']}"
                        ),
                    )
                )
                continue
            star = [kw for kw in call.keywords if kw.arg is None]
            if star:
                findings.append(
                    Finding(
                        rule="R6",
                        path=mod.path,
                        line=call.lineno,
                        symbol="",
                        message=(
                            f"bus.emit({ev!r}, **...) — payload fields must "
                            f"be explicit keywords so the schema is checkable"
                        ),
                    )
                )
                continue
            actual = {kw.arg for kw in call.keywords if kw.arg}
            declared = set(events[ev])
            for extra in sorted(actual - declared):
                findings.append(
                    Finding(
                        rule="R6",
                        path=mod.path,
                        line=call.lineno,
                        symbol="",
                        message=(
                            f"event-schema drift: field {extra!r} of {ev!r} "
                            f"is emitted here but not in the manifest entry"
                        ),
                    )
                )
            for missing in sorted(declared - actual):
                findings.append(
                    Finding(
                        rule="R6",
                        path=mod.path,
                        line=call.lineno,
                        symbol="",
                        message=(
                            f"event-schema drift: {ev!r} emitted without "
                            f"manifest field {missing!r} — every backend must "
                            f"emit the full pinned field set"
                        ),
                    )
                )

    for ev in sorted(set(events) - emitted_types):
        findings.append(
            Finding(
                rule="R6",
                path=EVENT_MANIFEST_PATH,
                line=1,
                symbol=ev,
                message=(
                    f"stale event manifest: {ev!r} is registered but no "
                    f"bus.emit site in src/ produces it"
                ),
            )
        )

    test_path = manifest["schema_test"]
    test_mod = index.module(test_path)
    if test_mod is None:
        findings.append(
            Finding(
                rule="R6",
                path=test_path,
                line=1,
                symbol="",
                message="event-schema test file is missing",
            )
        )
    else:
        tokens = _test_tokens(test_mod.tree)
        for ev in sorted(events):
            if ev not in tokens:
                findings.append(
                    Finding(
                        rule="R6",
                        path=test_path,
                        line=1,
                        symbol=ev,
                        message=(
                            f"event type {ev!r} is never exercised by the "
                            f"schema test — a payload regression on it would "
                            f"go unnoticed"
                        ),
                    )
                )
    return findings
