"""Call-graph approximation for reachability from ``async def`` roots.

The concurrency rules need to answer one question: *which functions can
run on the event-loop thread as part of an async call chain?*  A precise
answer needs type inference; the checker instead uses a name-based
over-approximation that is cheap, deterministic, and errs toward
reporting (a finding in an over-approximated branch is still a blocking
primitive in loop-adjacent code — the fix is an annotation stating why
that is safe).

Edges: for every ``ast.Call`` in a function body (excluding nested
``def`` bodies — those are separate nodes reached only if actually
called), take the called name (``foo`` / ``obj.foo``) and connect to
every ``src/`` function with that name.  Traversal stops at:

- ``@worker_side`` functions — they run on another thread/process; the
  *call itself* is reported by R1 (loop code must not call into
  worker-side code), but their bodies are never scanned;
- calls dispatched through well-known thread/process entry points
  (``run_in_executor``, ``Thread(target=...)``, ``Process(target=...)``)
  — the callee escapes the loop thread by construction.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

from .model import FunctionInfo, RepoIndex

__all__ = ["called_names", "reachable_from_async", "body_calls"]

#: Call names whose *arguments* are thread/process entry points, not
#: loop-thread calls — edges through them are not followed.
_ESCAPE_DISPATCHERS = {"run_in_executor", "Thread", "Process", "create_task"}


def body_calls(fn: FunctionInfo) -> Iterator[ast.Call]:
    """Every ``ast.Call`` lexically in ``fn``, excluding nested ``def``s."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn.node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue  # separate call-graph node
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))


def called_names(fn: FunctionInfo) -> List[Tuple[str, int]]:
    """(callee name, line) for every call edge leaving ``fn``."""
    out: List[Tuple[str, int]] = []
    for call in body_calls(fn):
        func = call.func
        if isinstance(func, ast.Attribute):
            name = func.attr
        elif isinstance(func, ast.Name):
            name = func.id
        else:
            continue
        if name in _ESCAPE_DISPATCHERS:
            continue
        out.append((name, call.lineno))
    return out


def reachable_from_async(
    index: RepoIndex,
    root_prefix: str,
    resolve_prefixes: Tuple[str, ...] = (),
) -> Tuple[Dict[str, FunctionInfo], List[Tuple[FunctionInfo, FunctionInfo, int]]]:
    """Functions reachable on the loop thread from async roots.

    Roots are every ``async def`` under ``root_prefix`` (e.g.
    ``src/repro/runtime/``).  ``resolve_prefixes`` limits which files
    call edges may land in (the control-plane packages) so the name-based
    resolution cannot wander into unrelated same-named functions in other
    subsystems.  Returns ``(reached, worker_side_calls)``: ``reached``
    maps ``path:qualname`` to the function (bodies the R1 scan must
    cover), and ``worker_side_calls`` lists every resolved edge from
    reached code into a ``@worker_side`` function as
    ``(caller, callee, call line)`` — each is an R1 boundary violation.
    """
    roots = [
        fn
        for fn in index.src_functions(root_prefix)
        if fn.is_async and not fn.worker_side
    ]
    reached: Dict[str, FunctionInfo] = {}
    boundary: List[Tuple[FunctionInfo, FunctionInfo, int]] = []
    seen_edges: Set[Tuple[str, str]] = set()
    stack = list(roots)
    while stack:
        fn = stack.pop()
        key = f"{fn.path}:{fn.qualname}"
        if key in reached:
            continue
        reached[key] = fn
        for name, line in called_names(fn):
            for callee in index.resolve_call(name):
                if resolve_prefixes and not callee.path.startswith(resolve_prefixes):
                    continue
                ckey = f"{callee.path}:{callee.qualname}"
                if (key, ckey) in seen_edges:
                    continue
                seen_edges.add((key, ckey))
                if callee.worker_side:
                    boundary.append((fn, callee, line))
                    continue  # never scan worker-side bodies
                if ckey not in reached:
                    stack.append(callee)
    return reached, boundary
